"""Unit + property tests for the quantization reference library."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant


class TestQuantizeLq:
    def test_constant_region_exact(self):
        x = jnp.full((2, 8), 3.25)
        fq = quant.fake_quant_lq(x, 2, 4)
        np.testing.assert_array_equal(np.asarray(fq), np.asarray(x))

    def test_codes_in_range(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
        for bits in (1, 2, 4, 6, 8):
            codes, _, _ = quant.quantize_lq(x, bits, 8)
            assert int(codes.min()) >= 0
            assert int(codes.max()) <= (1 << bits) - 1

    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(3, 40)).astype(np.float32))
        for bits, g in [(8, 8), (4, 10), (2, 5)]:
            codes, scales, mins = quant.quantize_lq(x, bits, g)
            fq = quant.dequantize_lq(codes, scales, mins, g)
            err = np.abs(np.asarray(fq - x))
            smax = float(scales.max())
            assert err.max() <= smax / 2 + 1e-6

    def test_bad_bits_raises(self):
        with pytest.raises(ValueError):
            quant.quantize_lq(jnp.zeros((2, 4)), 0, 2)
        with pytest.raises(ValueError):
            quant.quantize_lq(jnp.zeros((2, 4)), 8, 0)

    def test_ragged_tail_region(self):
        # K=7, g=3: the tail region has one element; min/max exclude padding.
        x = jnp.asarray([[1.0, 2.0, 3.0, -4.0, 0.0, 4.0, 100.0]])
        codes, scales, mins = quant.quantize_lq(x, 2, 3)
        # last region = [100.0] alone: constant -> exact reconstruction
        fq = quant.dequantize_lq(codes, scales, mins, 3)
        assert float(fq[0, -1]) == 100.0

    @settings(max_examples=40, deadline=None)
    @given(
        rows=st.integers(1, 6),
        k=st.integers(1, 40),
        bits=st.sampled_from([1, 2, 4, 6, 8]),
        seed=st.integers(0, 2**31 - 1),
        data=st.data(),
    )
    def test_property_roundtrip(self, rows, k, bits, seed, data):
        g = data.draw(st.integers(1, k))
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(scale=3.0, size=(rows, k)).astype(np.float32))
        codes, scales, mins = quant.quantize_lq(x, bits, g)
        assert codes.shape == x.shape
        fq = quant.dequantize_lq(codes, scales, mins, g)
        err = np.abs(np.asarray(fq - x))
        # per-element bound via the element's own region scale
        r = int(np.ceil(k / g))
        for i in range(rows):
            for j in range(k):
                s = float(scales[i, j // g])
                assert err[i, j] <= s / 2 + 1e-5 * max(s, 1.0), (i, j, s)
        assert scales.shape == (rows, r)

    def test_dq_is_whole_tensor(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
        codes, scale, mn = quant.quantize_dq(x, 8)
        assert codes.shape == x.shape
        assert float(mn) == float(x.min())

    def test_lq_step_never_exceeds_dq_step(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
        _, s_dq, _ = quant.quantize_dq(x, 4)
        _, s_lq, _ = quant.quantize_lq(x, 4, 8)
        assert float(s_lq.max()) <= float(s_dq) + 1e-7


class TestLqMatmulReference:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 6),
        k=st.integers(1, 24),
        n=st.integers(1, 6),
        bits=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
        data=st.data(),
    )
    def test_eq7_equals_fakequant_matmul(self, m, k, n, bits, seed, data):
        g = data.draw(st.integers(1, k))
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        r1 = quant.lq_matmul_reference(a, w, bits, bits, g)
        aq = quant.fake_quant_lq(a, bits, g)
        wq = quant.fake_quant_lq(w.T, bits, g).T
        r2 = aq @ wq
        scale = float(jnp.abs(r2).max()) + 1e-6
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=2e-4 * scale, rtol=2e-4)

    def test_8bit_close_to_exact(self):
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.normal(size=(8, 75)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(75, 12)).astype(np.float32))
        approx = quant.lq_matmul_reference(a, w, 8, 8, 75)
        exact = a @ w
        rel = float(jnp.abs(approx - exact).max() / jnp.abs(exact).max())
        assert rel < 0.01
