"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes / bit widths / region sizes; every kernel runs in
interpret mode (the CPU plugin cannot execute Mosaic custom-calls).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import lq_matmul, lut_gemm, quantize, ref


class TestQuantizeKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 48),
        r=st.integers(1, 6),
        g=st.sampled_from([1, 2, 4, 8]),
        bits=st.sampled_from([1, 2, 4, 6, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, r, g, bits, seed):
        k = r * g  # kernel requires g | K
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        kc, ks, km = quantize.quantize_lq(x, bits=bits, g=g)
        rc, rs, rm = ref.ref_quantize(x, bits, g)
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(km), np.asarray(rm), rtol=1e-6)

    def test_rejects_non_dividing_region(self):
        with pytest.raises(ValueError):
            quantize.quantize_lq(jnp.zeros((4, 10)), bits=8, g=3)


class TestLqMatmulKernel:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 40),
        n=st.integers(1, 24),
        r=st.integers(1, 4),
        g=st.sampled_from([2, 4, 8]),
        bits=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_eq7_reference(self, m, n, r, g, bits, seed):
        k = r * g
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        qa, sa, ma = quant.quantize_lq(a, bits, g)
        qw, sw, mw = quant.quantize_lq(w.T, bits, g)
        out = lq_matmul.lq_matmul(qa, sa, ma, qw, sw, mw, g=g)
        want = ref.ref_lq_matmul(a, w, bits, bits, g)
        scale = float(jnp.abs(want).max()) + 1e-6
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), atol=3e-4 * scale, rtol=3e-4
        )

    def test_tile_fitting_odd_sizes(self):
        # M=33, N=17 force fit_tile to pick non-default tiles.
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(33, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(8, 17)).astype(np.float32))
        qa, sa, ma = quant.quantize_lq(a, 8, 4)
        qw, sw, mw = quant.quantize_lq(w.T, 8, 4)
        out = lq_matmul.lq_matmul(qa, sa, ma, qw, sw, mw, g=4)
        assert out.shape == (33, 17)

    def test_rejects_bad_region(self):
        with pytest.raises(ValueError):
            lq_matmul.lq_matmul(
                jnp.zeros((4, 10), jnp.int32),
                jnp.zeros((4, 2)),
                jnp.zeros((4, 2)),
                jnp.zeros((4, 10), jnp.int32),
                jnp.zeros((4, 2)),
                jnp.zeros((4, 2)),
                g=3,
            )


class TestLutGemmKernel:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 32),
        k=st.integers(1, 64),
        n=st.integers(1, 32),
        bits=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exact_integer_equality(self, m, k, n, bits, seed):
        rng = np.random.default_rng(seed)
        qa = jnp.asarray(rng.integers(0, 1 << bits, size=(m, k)).astype(np.int32))
        qw = jnp.asarray(rng.integers(0, 256, size=(k, n)).astype(np.int32))
        got = lut_gemm.lut_gemm(qa, qw, bits=bits)
        want = ref.ref_int_gemm(qa, qw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bucketing_formulation_matches(self):
        rng = np.random.default_rng(1)
        qa = jnp.asarray(rng.integers(0, 4, size=(8, 24)).astype(np.int32))
        qw = jnp.asarray(rng.integers(0, 256, size=(24, 8)).astype(np.int32))
        np.testing.assert_array_equal(
            np.asarray(ref.ref_lut_gemm(qa, qw, 2)), np.asarray(ref.ref_int_gemm(qa, qw))
        )
