"""Dataset generator tests: determinism, balance, separability."""

import numpy as np

from compile import datagen


def test_shapes_and_range():
    x, y = datagen.generate(32, seed=1)
    assert x.shape == (32, 3, datagen.IMG, datagen.IMG)
    assert x.dtype == np.float32
    assert y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(y) <= set(range(datagen.NUM_CLASSES))


def test_deterministic():
    x1, y1 = datagen.generate(16, seed=7)
    x2, y2 = datagen.generate(16, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = datagen.generate(16, seed=8)
    assert not np.array_equal(x1, x3)


def test_balanced_classes():
    _, y = datagen.generate(160, seed=0)
    counts = np.bincount(y, minlength=16)
    assert (counts == 10).all(), counts


def test_color_scheme_separates_halves():
    # Classes 0-7 are warm (R > B on the shape), 8-15 cool (B > R).
    x, y = datagen.generate(64, seed=3)
    for img, label in zip(x, y):
        # Use the brightest-minus-background proxy: compare channel means on
        # high-saturation pixels.
        sat = np.abs(img[0] - img[2])
        mask = sat > 0.3
        if mask.sum() < 10:
            continue
        warm = img[0][mask].mean() > img[2][mask].mean()
        assert warm == (label < 8), (label, warm)


def test_all_shapes_render_nonempty():
    rng = np.random.default_rng(0)
    for s in datagen.SHAPES:
        m = datagen.shape_mask(s, rng)
        frac = m.mean()
        assert 0.02 < frac < 0.85, (s, frac)
