"""Fixture-generator self-checks (the rust side consumes these via
`rust/tests/quant_parity.rs`; here we pin the python-side invariants)."""

import numpy as np

from compile import fixtures, quant


def test_cases_cover_paper_bit_widths():
    bits = {c[2] for c in fixtures.CASES}
    assert {8, 6, 4, 2, 1} <= bits


def test_cases_include_ragged_regions():
    assert any(k % g != 0 for (_, k, _, g, _) in fixtures.CASES)


def test_fixture_determinism(tmp_path):
    import subprocess
    import sys

    out1 = tmp_path / "a.npz"
    out2 = tmp_path / "b.npz"
    for out in (out1, out2):
        subprocess.run(
            [sys.executable, "-m", "compile.fixtures", "--out", str(out)],
            check=True,
        )
    a = np.load(out1)
    b = np.load(out2)
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k])


def test_gemm_fixture_matches_recomputation():
    rng = np.random.default_rng(100)  # seed 0 == case 0
    rows, k, bits, g = fixtures.CASES[0][:4]
    x = rng.normal(scale=2.0, size=(rows, k)).astype(np.float32)
    codes, scales, mins = quant.quantize_lq(x, bits, g)
    codes2, scales2, mins2 = quant.quantize_lq(x, bits, g)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(scales2))
    np.testing.assert_array_equal(np.asarray(mins), np.asarray(mins2))
