"""L2 model tests: shapes, quantized-path agreement, im2col layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("name", sorted(M.MODELS))
class TestForward:
    def test_shapes(self, name, rng):
        p = M.init_params(name)
        x = jnp.asarray(rng.normal(size=(2, *M.IN_SHAPE)).astype(np.float32))
        y = M.forward(p, x, name)
        assert y.shape == (2, M.NUM_CLASSES)
        assert bool(jnp.isfinite(y).all())

    def test_quant8_close_to_f32(self, name, rng):
        p = M.init_params(name)
        x = jnp.asarray(rng.uniform(size=(2, *M.IN_SHAPE)).astype(np.float32))
        f = M.forward(p, x, name)
        q = M.forward_quant(p, x, name, scheme="lq", bits_a=8)
        rel = float(jnp.abs(f - q).max() / (jnp.abs(f).max() + 1e-6))
        assert rel < 0.1, rel

    def test_pallas_path_matches_fakequant(self, name, rng):
        p = M.init_params(name)
        x = jnp.asarray(rng.uniform(size=(1, *M.IN_SHAPE)).astype(np.float32))
        q = M.forward_quant(p, x, name, scheme="lq", bits_a=8, bits_w=8)
        k = M.forward_pallas(p, x, name, bits=8)
        rel = float(jnp.abs(q - k).max() / (jnp.abs(q).max() + 1e-6))
        assert rel < 0.05, rel

    def test_param_order_covers_params(self, name, rng):
        p = M.init_params(name)
        assert sorted(M.param_order(name)) == sorted(p.keys())


class TestIm2col:
    def test_matches_lax_conv(self, rng):
        # im2col + GEMM must equal lax.conv for the same weights.
        b, c, h, o, k = 2, 3, 8, 4, 3
        x = jnp.asarray(rng.normal(size=(b, c, h, h)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(o, c, k, k)).astype(np.float32))
        bias = jnp.zeros((o,))
        direct = M.conv2d(x, w, bias, 1, 1)
        cols, (bb, ho, wo) = M.im2col(x, k, 1, 1)
        gemm = (cols @ w.reshape(o, -1).T).reshape(bb, ho, wo, o).transpose(0, 3, 1, 2)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(gemm), atol=1e-4)

    def test_patch_column_order_channel_major(self, rng):
        # One-hot input pins the (C, kh, kw) column order the rust side mirrors.
        x = jnp.zeros((1, 2, 4, 4)).at[0, 1, 1, 2].set(7.0)
        cols, _ = M.im2col(x, 3, 1, 1)
        # output position (1,2) has the hot pixel at patch center:
        # column = (ci * k + kh) * k + kw = (1*3+1)*3+1 = 13
        row = cols[1 * 4 + 2]
        assert float(row[13]) == 7.0


class TestGradients:
    def test_loss_differentiable(self, rng):
        p = M.init_params("minialexnet")
        x = jnp.asarray(rng.normal(size=(4, *M.IN_SHAPE)).astype(np.float32))
        y = jnp.asarray([0, 1, 2, 3])

        def loss(params):
            lp = M.log_softmax(M.forward(params, x, "minialexnet"))
            return -lp[jnp.arange(4), y].mean()

        g = jax.grad(loss)(p)
        assert set(g) == set(p)
        total = sum(float(jnp.abs(v).sum()) for v in g.values())
        assert np.isfinite(total) and total > 0
