"""AOT lowering tests: HLO text generation round-trips through the
xla_client parser (the same path `make artifacts` uses)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def test_to_hlo_text_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_lower_variant_f32_small():
    params = M.init_params("minialexnet")
    text = aot.lower_variant("minialexnet", "f32", 1, 0, 0, params)
    assert "ENTRY" in text
    # input parameter: 1x3x32x32
    assert "f32[1,3,32,32]" in text
    # output: tuple with (1, 16) logits
    assert "f32[1,16]" in text


def test_lower_variant_lq_contains_quantization():
    params = M.init_params("minialexnet")
    text = aot.lower_variant("minialexnet", "lq", 1, 8, 0, params)
    # The runtime quantization pass lowers to round/clamp ops in HLO (they
    # may be wrapped in called computations, so check for either form).
    assert "round-nearest-even" in text or "round" in text
    assert "clamp" in text or "minimum" in text or "maximum" in text


def test_lower_variant_rejects_unknown():
    params = M.init_params("minialexnet")
    with pytest.raises(ValueError):
        aot.lower_variant("minialexnet", "nope", 1, 0, 0, params)


def test_param_order_matches_lowering_arity():
    params = M.init_params("minivgg")
    order = M.param_order("minivgg")
    text = aot.lower_variant("minivgg", "f32", 1, 0, 0, params)
    # The ENTRY computation takes len(order) weight params + 1 input (nested
    # computations have their own parameters, so count ENTRY only).
    entry = text[text.index("ENTRY"):]
    n_params = entry.count("parameter(")
    assert n_params == len(order) + 1, (n_params, len(order))
