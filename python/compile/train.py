"""Build-time trainer for the Mini models on the synthetic dataset.

Runs ONCE under `make artifacts` (skipped when the weight files already
exist). SGD with momentum + cosine decay on softmax cross-entropy. The
resulting weights are written as plain npz (name -> array, the names from
model.param_order) which the rust side loads with `Literal::read_npz`.

    python -m compile.train --model minialexnet --out ../artifacts/weights_minialexnet.npz
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import datagen, model as M


def cross_entropy(params, x, y, model_name):
    logits = M.forward(params, x, model_name)
    logp = M.log_softmax(logits)
    return -logp[jnp.arange(y.shape[0]), y].mean()


def accuracy(params, x, y, model_name, batch=256):
    hits = 0
    for i in range(0, x.shape[0], batch):
        logits = M.forward_jit(params, x[i : i + batch], model=model_name)
        hits += int((jnp.argmax(logits, -1) == y[i : i + batch]).sum())
    return hits / x.shape[0]


def train(model_name: str, epochs: int, lr: float, momentum: float, batch: int,
          seed: int, train_n: int, val_n: int):
    xt, yt = datagen.generate(train_n, seed=2018)
    xv, yv = datagen.generate(val_n, seed=2019)
    xt, yt, xv, yv = map(jnp.asarray, (xt, yt, xv, yv))
    params = M.init_params(model_name, seed=seed)
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}

    grad_fn = jax.jit(
        jax.value_and_grad(cross_entropy), static_argnames=("model_name",)
    )

    @jax.jit
    def sgd(params, vel, grads, lr):
        vel = {k: momentum * vel[k] - lr * grads[k] for k in params}
        params = {k: params[k] + vel[k] for k in params}
        return params, vel

    steps_per_epoch = train_n // batch
    total_steps = epochs * steps_per_epoch
    rng = np.random.default_rng(seed)
    step = 0
    for ep in range(epochs):
        order = rng.permutation(train_n)
        t0 = time.time()
        losses = []
        for i in range(steps_per_epoch):
            idx = order[i * batch : (i + 1) * batch]
            cur_lr = lr * 0.5 * (1 + np.cos(np.pi * step / total_steps))
            loss, grads = grad_fn(params, xt[idx], yt[idx], model_name=model_name)
            params, vel = sgd(params, vel, grads, cur_lr)
            losses.append(float(loss))
            step += 1
        va = accuracy(params, xv, yv, model_name)
        print(
            f"[{model_name}] epoch {ep + 1}/{epochs} loss={np.mean(losses):.4f} "
            f"val_top1={va:.4f} ({time.time() - t0:.1f}s)",
            flush=True,
        )
    return params, {"val_top1": va, "epochs": epochs, "train_n": train_n}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=sorted(M.MODELS), required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-n", type=int, default=8000)
    ap.add_argument("--val-n", type=int, default=1000)
    args = ap.parse_args()

    params, meta = train(
        args.model, args.epochs, args.lr, args.momentum, args.batch, args.seed,
        args.train_n, args.val_n,
    )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    np.savez(args.out, **{k: np.asarray(v) for k, v in params.items()})
    with open(args.out.replace(".npz", ".meta.json"), "w") as f:
        json.dump({"model": args.model, **meta}, f, indent=2)
    print(f"wrote {args.out} (val_top1={meta['val_top1']:.4f})")


if __name__ == "__main__":
    main()
