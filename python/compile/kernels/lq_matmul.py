"""Pallas kernel: region-quantized matmul (the paper's eq. 7 hot path).

Computes `out[M, N] ~= A[M, K] @ W[K, N]` from *pre-quantized* operands:
integer codes plus per-region (scale, min) pairs, with regions of `g`
consecutive elements along K. The integer partial sums are accumulated per
region and the affine correction is applied per region — exactly the
fixed-point pipeline an IoT device (or the rust `fixedpoint` module) runs.

TPU shaping (see DESIGN.md §Hardware-Adaptation): the grid tiles M and N;
each grid step holds one (bm, K) code stripe of A and one (bn, K) stripe of
W^T in VMEM together with their (bm, R) / (bn, R) scale/min side-cars, so the
dequantization correction fuses into the MXU-feeding contraction instead of a
second pass over HBM. The region axis is aligned with K so per-region sums
are a reshape + reduce, not a gather.

Constraints: K % g == 0, bm | M, bn | N (callers pad). interpret=True always:
the CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fit_tile(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (>= 1)."""
    want = max(1, min(want, n))
    for t in range(want, 0, -1):
        if n % t == 0:
            return t
    return 1


def _kernel(qa_ref, sa_ref, ma_ref, qw_ref, sw_ref, mw_ref, out_ref, *, g: int):
    """One (bm, bn) output tile; full K resident.

    qa: (bm, K) int32 codes      sa, ma: (bm, R) f32
    qw: (bn, K) int32 codes      sw, mw: (bn, R) f32   (W^T layout)
    """
    qa = qa_ref[...].astype(jnp.float32)
    qw = qw_ref[...].astype(jnp.float32)
    bm, k = qa.shape
    bn = qw.shape[0]
    r = k // g
    qa_r = qa.reshape(bm, r, g)
    qw_r = qw.reshape(bn, r, g)
    sa, ma = sa_ref[...], ma_ref[...]          # (bm, R)
    sw, mw = sw_ref[...], mw_ref[...]          # (bn, R)
    # Integer partial sums per region (MXU-friendly contraction over g).
    s_qq = jax.lax.dot_general(
        qa_r, qw_r, (((2,), (2,)), ((1,), (1,)))
    )                                          # (R, bm, bn)
    s_qa = qa_r.sum(-1)                        # (bm, R)
    s_qw = qw_r.sum(-1)                        # (bn, R)
    # Affine correction, applied per region then reduced over R (eq. 7).
    term_qq = jnp.einsum("mr,nr,rmn->mn", sa, sw, s_qq)
    term_qa = (sa * s_qa) @ mw.T               # (bm, bn)
    term_qw = ma @ (sw * s_qw).T               # (bm, bn)
    term_mm = float(g) * (ma @ mw.T)
    out_ref[...] = term_qq + term_qa + term_qw + term_mm


@functools.partial(jax.jit, static_argnames=("g", "bm", "bn"))
def lq_matmul(qa, sa, ma, qw_t, sw, mw, *, g: int, bm: int = 32, bn: int = 32):
    """Region-quantized matmul.

    Args:
      qa:   (M, K) int32 activation codes.
      sa:   (M, R) f32 activation scales, R = K // g.
      ma:   (M, R) f32 activation region minima.
      qw_t: (N, K) int32 weight codes (transposed layout).
      sw:   (N, R) f32 weight scales.
      mw:   (N, R) f32 weight region minima.
      g:    region size along K; must divide K.
      bm, bn: output tile sizes (M % bm == 0, N % bn == 0; callers pad).

    Returns (M, N) f32, equal to ref.ref_lq_matmul up to f32 rounding.
    """
    m, k = qa.shape
    n = qw_t.shape[0]
    if k % g:
        raise ValueError(f"K={k} not divisible by region size g={g}")
    r = k // g
    bm = fit_tile(m, bm)
    bn = fit_tile(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(qa, sa, ma, qw_t, sw, mw)
