"""Pallas kernel: look-up-table GEMM for extremely-low-bit activations (§V).

With 2-bit activation codes there are only 4 possible multiplicands, so the
paper replaces multiply-accumulate with table-indexed adds (Fig. 5). We
implement the code-bucketing formulation: for each activation code value c,
bucket-sum the weights whose paired activation equals c (adds / selects
only), then combine `sum_c c * bucket_c` with a handful of multiplies per
output — `2^bits - 1` multiplies instead of K.

On TPU the "table" is the VMEM-resident bucket accumulator; the select+add
maps onto the VPU (vector unit) rather than burning MXU cycles on 2-bit
operands the MXU cannot exploit. The op-count accounting that reproduces
Table 3 lives in rust (`nn/opcount.rs`); this kernel is the functional
counterpart, exact-integer-equal to `ref.ref_int_gemm`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.lq_matmul import fit_tile


def _kernel(qa_ref, qw_ref, out_ref, *, bits: int):
    qa = qa_ref[...]                           # (bm, K) int32 codes
    qw = qw_ref[...]                           # (K, bn) int32
    acc = jnp.zeros((qa.shape[0], qw.shape[1]), dtype=jnp.int32)
    # One pass per nonzero code value: a select (VPU) + integer matmul with a
    # 0/1 mask == the bucket add. c is a python int -> unrolled at trace time.
    for c in range(1, 1 << bits):
        sel = (qa == c).astype(jnp.int32)
        acc = acc + c * jax.lax.dot_general(
            sel.astype(jnp.float32),
            qw.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
        ).astype(jnp.int32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn"))
def lut_gemm(qa, qw, *, bits: int = 2, bm: int = 32, bn: int = 32):
    """Integer GEMM via code bucketing: out[m,n] = sum_k qa[m,k] * qw[k,n].

    qa: (M, K) int32 activation codes in [0, 2^bits).
    qw: (K, N) int32 weight codes (any int range).
    Exact integer result; bit-for-bit equal to ref.ref_int_gemm.
    """
    m, k = qa.shape
    n = qw.shape[1]
    bm = fit_tile(m, bm)
    bn = fit_tile(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(qa, qw)
