"""Pallas kernel: runtime local-region quantization of activations.

The paper quantizes weights offline but inputs *at runtime* (§V.B: "the
inputs have to be converted into fixed point in runtime"), so activation
quantization sits on the hot path and gets its own kernel.

Layout: x is (M, K); regions are `g` consecutive elements along K (the
im2col receptive-field axis, matching the paper's kernel-sized regions).
Output codes are int32 in [0, 2^bits - 1] plus per-region (scale, min)
side-cars of shape (M, R).

TPU shaping: grid over M stripes; each grid step keeps a (bm, K) stripe in
VMEM, computes the per-region min/max with a reshape+reduce (region axis
aligned to K), and writes codes in place — one HBM round trip total.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.lq_matmul import fit_tile


def _kernel(x_ref, codes_ref, scale_ref, min_ref, *, bits: int, g: int):
    x = x_ref[...]                             # (bm, K)
    bm, k = x.shape
    r = k // g
    levels = float((1 << bits) - 1)
    xr = x.reshape(bm, r, g)
    mn = xr.min(axis=-1)                       # (bm, R)
    mx = xr.max(axis=-1)
    span = mx - mn
    scale = jnp.where(span > 0, span / levels, 1.0)
    codes = jnp.clip(jnp.round((xr - mn[..., None]) / scale[..., None]), 0.0, levels)
    codes_ref[...] = codes.reshape(bm, k).astype(jnp.int32)
    scale_ref[...] = scale
    min_ref[...] = mn


@functools.partial(jax.jit, static_argnames=("bits", "g", "bm"))
def quantize_lq(x, *, bits: int, g: int, bm: int = 64):
    """LQ-quantize `x` (M, K) along K with region size g (g must divide K).

    Returns (codes int32 (M,K), scales f32 (M,R), mins f32 (M,R)); matches
    ref.ref_quantize exactly.
    """
    m, k = x.shape
    if k % g:
        raise ValueError(f"K={k} not divisible by region size g={g}")
    r = k // g
    bm = fit_tile(m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, g=g),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m, r), jnp.float32),
            jax.ShapeDtypeStruct((m, r), jnp.float32),
        ],
        interpret=True,
    )(x)
