"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written with
plain jnp ops and no Pallas. pytest pins kernel == ref (assert_allclose), and
hypothesis sweeps shapes / bit widths / region sizes. The rust fixed-point
GEMMs are pinned against the same semantics through shared npz fixtures.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import quant


def ref_matmul(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """f32 oracle for the plain tiled matmul kernel."""
    return a @ w


def ref_quantize(x: jnp.ndarray, bits: int, g: int):
    """Oracle for the activation-quantization kernel: LQ along the last axis."""
    return quant.quantize_lq(x, bits, g)


def ref_lq_matmul(a: jnp.ndarray, w: jnp.ndarray, bits_a: int, bits_w: int, g: int):
    """Oracle for the region-quantized matmul kernel (eq. 7)."""
    return quant.lq_matmul_reference(a, w, bits_a, bits_w, g)


def ref_lq_matmul_fakequant(a, w, bits_a, bits_w, g):
    """Equivalent formulation: fake-quant both operands, then f32 matmul.

    Mathematically identical to ref_lq_matmul (eq. 7 is the expansion of the
    product of the affine reconstructions); used as a cross-check in tests.
    """
    aq = quant.fake_quant_lq(a, bits_a, g)
    wq = quant.fake_quant_lq(w.T, bits_w, g).T
    return aq @ wq


def ref_int_gemm(qa: jnp.ndarray, qw: jnp.ndarray) -> jnp.ndarray:
    """Integer GEMM oracle for the LUT kernel: sum_k qa[m,k] * qw[k,n]."""
    return qa.astype(jnp.int32) @ qw.astype(jnp.int32)


def ref_lut_gemm(qa: jnp.ndarray, qw: jnp.ndarray, bits_a: int) -> jnp.ndarray:
    """Oracle for the LUT (code-bucketing) GEMM — exact integer equality.

    The paper's §V scheme: for c in {0..2^bits-1}, bucket-sum the weights
    whose paired activation code is c (adds only), then combine with
    c * bucket (a handful of multiplies per region; c=0 contributes nothing,
    c=1 needs no multiply). Produces exactly sum_k qa*qw.
    """
    levels = 1 << bits_a
    out = jnp.zeros((qa.shape[0], qw.shape[1]), dtype=jnp.int32)
    for c in range(1, levels):
        sel = (qa == c).astype(jnp.int32)          # (M, K)
        bucket = sel @ qw.astype(jnp.int32)        # adds only
        out = out + c * bucket
    return out
