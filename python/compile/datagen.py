"""Synthetic image-classification dataset (ImageNet stand-in).

The paper evaluates on ImageNet LSVRC-2012 with pretrained Caffe AlexNet /
VGG-16 — neither the data nor the models are available here, so we substitute
a procedurally generated 16-class shape dataset (see DESIGN.md
§Substitutions). What matters for the reproduction is that the task exercises
a deep conv stack whose activation dynamic range degrades under coarse
quantization, which this dataset does.

16 classes = 8 shapes x 2 color schemes, rendered at random position / scale /
rotation over a textured background with additive noise. Images are CHW f32
in [0, 1]. Deterministic for a given seed.

Run as a module to write artifacts/data/{train,val}.npz:
    python -m compile.datagen --out-dir ../artifacts/data
"""

from __future__ import annotations

import argparse
import os

import numpy as np

IMG = 32           # image side
CHANNELS = 3
NUM_CLASSES = 16
SHAPES = ["disk", "ring", "square", "frame", "triangle", "cross", "hbars", "checker"]


def _coords(n: int):
    ax = np.linspace(-1.0, 1.0, n, dtype=np.float32)
    return np.meshgrid(ax, ax, indexing="xy")


def _rotate(x, y, theta):
    c, s = np.cos(theta), np.sin(theta)
    return c * x + s * y, -s * x + c * y


def shape_mask(shape: str, rng: np.random.Generator) -> np.ndarray:
    """Binary mask (IMG, IMG) of the given shape at random pose."""
    x, y = _coords(IMG)
    cx, cy = rng.uniform(-0.3, 0.3, size=2)
    scale = rng.uniform(0.45, 0.8)
    theta = rng.uniform(0, np.pi)
    xr, yr = _rotate((x - cx) / scale, (y - cy) / scale, theta)
    r = np.sqrt(xr**2 + yr**2)
    if shape == "disk":
        m = r < 0.8
    elif shape == "ring":
        m = (r < 0.8) & (r > 0.45)
    elif shape == "square":
        m = (np.abs(xr) < 0.7) & (np.abs(yr) < 0.7)
    elif shape == "frame":
        m = ((np.abs(xr) < 0.75) & (np.abs(yr) < 0.75)) & ~(
            (np.abs(xr) < 0.42) & (np.abs(yr) < 0.42)
        )
    elif shape == "triangle":
        m = (yr > -0.55) & (yr < 1.3 * xr + 0.55) & (yr < -1.3 * xr + 0.55)
    elif shape == "cross":
        m = (np.abs(xr) < 0.22) | (np.abs(yr) < 0.22)
        m &= (np.abs(xr) < 0.8) & (np.abs(yr) < 0.8)
    elif shape == "hbars":
        m = (np.sin(yr * 3 * np.pi) > 0.25) & (np.abs(xr) < 0.8) & (np.abs(yr) < 0.8)
    elif shape == "checker":
        m = (np.sin(xr * 2.5 * np.pi) * np.sin(yr * 2.5 * np.pi) > 0.1) & (r < 0.95)
    else:
        raise ValueError(f"unknown shape {shape!r}")
    return m.astype(np.float32)


def render(label: int, rng: np.random.Generator) -> np.ndarray:
    """Render one CHW image for `label` in [0, NUM_CLASSES)."""
    shape = SHAPES[label % len(SHAPES)]
    warm = label // len(SHAPES) == 0  # color scheme bit
    mask = shape_mask(shape, rng)
    # Textured background: low-frequency gradient + noise.
    x, y = _coords(IMG)
    gx, gy = rng.uniform(-0.4, 0.4, size=2)
    bg = 0.45 + gx * x + gy * y
    img = np.empty((CHANNELS, IMG, IMG), dtype=np.float32)
    if warm:
        fg = np.array([rng.uniform(0.75, 1.0), rng.uniform(0.25, 0.55), rng.uniform(0.0, 0.25)])
    else:
        fg = np.array([rng.uniform(0.0, 0.25), rng.uniform(0.35, 0.65), rng.uniform(0.75, 1.0)])
    for c in range(CHANNELS):
        img[c] = bg * (1.0 - mask) + fg[c] * mask
    img += rng.normal(0.0, 0.06, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def generate(n: int, seed: int):
    """Generate (x, y): x f32 (n, C, IMG, IMG), y int32 (n,). Balanced classes."""
    rng = np.random.default_rng(seed)
    y = np.arange(n, dtype=np.int32) % NUM_CLASSES
    rng.shuffle(y)
    x = np.stack([render(int(lbl), rng) for lbl in y])
    return x.astype(np.float32), y


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts/data")
    ap.add_argument("--train", type=int, default=8000)
    ap.add_argument("--val", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=2018)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    xt, yt = generate(args.train, args.seed)
    xv, yv = generate(args.val, args.seed + 1)
    np.savez(os.path.join(args.out_dir, "train.npz"), x=xt, y=yt)
    np.savez(os.path.join(args.out_dir, "val.npz"), x=xv, y=yv)
    print(f"wrote {args.train} train / {args.val} val images to {args.out_dir}")


if __name__ == "__main__":
    main()
