"""Reference quantization library: dynamic fixed point (DQ) and the paper's
local-based quantization (LQ).

This is the *semantic source of truth* shared by the Pallas kernels (L1), the
JAX models (L2) and the rust `quant` module (S1) — the rust side mirrors these
functions and the parity is pinned by tests on both sides.

Terminology (paper §IV):
  - A tensor is quantized along its last axis in *regions* of `g` consecutive
    elements. Each region k has its own step
        s_k = (max_k - min_k) / (2^n - 1)                     (eq. 5 / 7)
    and quantization function
        Q_k(x)   = round((x - min_k) / s_k)   in [0, 2^n - 1]
        Q_k^-1(q) = q * s_k + min_k
  - DQ (dynamic fixed point, Courbariaux et al. 2014) is the degenerate case
    g = (whole tensor): one globally-shared step per layer.
  - LQ uses small g (the paper defaults to the conv kernel's receptive-field
    size, e.g. 11*11*3 = 363 for AlexNet conv1, and §VI.F shrinks it further).

All functions are pure jnp and jit-friendly.
"""

from __future__ import annotations

import jax.numpy as jnp


def pad_to_multiple(x: jnp.ndarray, g: int) -> jnp.ndarray:
    """Pad the last axis of `x` with zeros up to a multiple of `g`."""
    k = x.shape[-1]
    rem = (-k) % g
    if rem == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad)


def region_minmax(x: jnp.ndarray, g: int):
    """Per-region (min, max) along the last axis.

    Padding elements (when g does not divide K) are *excluded*: the tail
    region's min/max is computed over its real elements only.

    Returns arrays of shape x.shape[:-1] + (ceil(K/g),).
    """
    k = x.shape[-1]
    rem = (-k) % g
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    xmin = jnp.pad(x, pad, constant_values=jnp.inf)
    xmax = jnp.pad(x, pad, constant_values=-jnp.inf)
    r = xmin.shape[-1] // g
    xmin = xmin.reshape(x.shape[:-1] + (r, g)).min(axis=-1)
    xmax = xmax.reshape(x.shape[:-1] + (r, g)).max(axis=-1)
    return xmin, xmax


def quantize_lq(x: jnp.ndarray, bits: int, g: int):
    """Local-region quantization of `x` along the last axis.

    Returns (codes, scales, mins):
      codes  int32, same shape as x (padded region tail is quantized too but
             callers slice back to K),
      scales f32 of shape x.shape[:-1] + (R,)   -- s_k, never zero,
      mins   f32 of shape x.shape[:-1] + (R,)   -- x_min per region.
    """
    if bits < 1 or bits > 16:
        raise ValueError(f"bits must be in [1, 16], got {bits}")
    if g < 1:
        raise ValueError(f"region size must be >= 1, got {g}")
    levels = (1 << bits) - 1
    mn, mx = region_minmax(x, g)
    span = mx - mn
    # Flat regions (span == 0) quantize everything to code 0 with scale 1 so
    # dequantization reproduces the constant exactly via the `min` term.
    scale = jnp.where(span > 0, span / levels, 1.0)
    xp = pad_to_multiple(x, g)
    r = xp.shape[-1] // g
    xr = xp.reshape(xp.shape[:-1] + (r, g))
    codes = jnp.clip(
        jnp.round((xr - mn[..., None]) / scale[..., None]), 0, levels
    ).astype(jnp.int32)
    codes = codes.reshape(xp.shape)[..., : x.shape[-1]]
    return codes, scale.astype(jnp.float32), mn.astype(jnp.float32)


def dequantize_lq(codes: jnp.ndarray, scales: jnp.ndarray, mins: jnp.ndarray, g: int):
    """Inverse of :func:`quantize_lq` (up to the rounding error <= s_k/2)."""
    cp = pad_to_multiple(codes.astype(jnp.float32), g)
    r = cp.shape[-1] // g
    cr = cp.reshape(cp.shape[:-1] + (r, g))
    x = cr * scales[..., None] + mins[..., None]
    return x.reshape(cp.shape)[..., : codes.shape[-1]]


def fake_quant_lq(x: jnp.ndarray, bits: int, g: int) -> jnp.ndarray:
    """Quantize-dequantize round trip: the value the fixed-point pipeline sees."""
    codes, scales, mins = quantize_lq(x, bits, g)
    return dequantize_lq(codes, scales, mins, g)


def quantize_dq(x: jnp.ndarray, bits: int):
    """Dynamic fixed point: one region spanning the whole tensor (paper §IV.B)."""
    flat = x.reshape(1, -1)
    codes, scales, mins = quantize_lq(flat, bits, flat.shape[-1])
    return codes.reshape(x.shape), scales[0, 0], mins[0, 0]


def fake_quant_dq(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    codes, scale, mn = quantize_dq(x, bits)
    return codes.astype(jnp.float32) * scale + mn


def lq_matmul_reference(a: jnp.ndarray, w: jnp.ndarray, bits_a: int, bits_w: int, g: int):
    """Eq. (7): integer-accumulated matmul with per-region affine correction.

    a: (M, K) activations, regions of size g along K (per row).
    w: (K, N) weights, regions of size g along K (per column).

    dot(a_i, w_j) = sum_r [ sa_ir*sw_rj * S_qq + sa_ir*mw_rj * S_qa
                          + sw_rj*ma_ir * S_qw + g_r * ma_ir*mw_rj ]
    where S_qq = sum_{k in r} qa_ik qw_kj, etc. This is *exactly* what the
    integer hardware pipeline computes, so it is the oracle for the Pallas
    kernel and the rust fixed-point GEMMs.
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    qa, sa, ma = quantize_lq(a, bits_a, g)          # (M,K) (M,R) (M,R)
    qw, sw, mw = quantize_lq(w.T, bits_w, g)        # (N,K) (N,R) (N,R)
    kp = pad_to_multiple(qa, g).shape[-1]
    r = kp // g
    # Padding positions (beyond K) must contribute nothing to any partial
    # sum: zero their codes and count only real elements in the min*min term.
    valid = (jnp.arange(kp) < k).astype(jnp.float32).reshape(1, r, g)
    qa_r = pad_to_multiple(qa, g).reshape(m, r, g).astype(jnp.float32) * valid
    qw_r = pad_to_multiple(qw, g).reshape(n, r, g).astype(jnp.float32) * valid
    # Per-region partial integer sums.
    s_qq = jnp.einsum("mrg,nrg->mnr", qa_r, qw_r)
    s_qa = qa_r.sum(-1)                              # (M,R)
    s_qw = qw_r.sum(-1)                              # (N,R)
    # Count of *real* (unpadded) elements per region for the min*min term.
    gcount = jnp.minimum(g, k - jnp.arange(r) * g).astype(jnp.float32)  # (R,)
    out = (
        jnp.einsum("mr,nr,mnr->mn", sa, sw, s_qq)
        + jnp.einsum("mr,nr,mr->mn", sa, mw, s_qa)
        + jnp.einsum("nr,mr,nr->mn", sw, ma, s_qw)
        + jnp.einsum("r,mr,nr->mn", gcount, ma, mw)
    )
    return out
