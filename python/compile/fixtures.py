"""Cross-language parity fixtures.

Dumps quantization inputs/outputs from the python reference implementation to
`artifacts/fixtures.npz`; `rust/tests/quant_parity.rs` recomputes them with
the rust `quant` module and asserts bit-exact code equality (and fp-tolerance
scale/min/GEMM equality). This pins the two implementations of the paper's
scheme to each other.

    python -m compile.fixtures --out ../artifacts/fixtures.npz
"""

from __future__ import annotations

import argparse

import numpy as np

from compile import quant

CASES = [
    # (rows, k, bits, g, seed)
    (4, 32, 8, 8, 0),
    (3, 75, 8, 75, 1),     # kernel-sized region (AlexNet-conv-like)
    (5, 48, 2, 12, 2),
    (2, 33, 4, 8, 3),      # ragged tail region
    (6, 16, 6, 16, 4),
    (1, 7, 1, 3, 5),       # 1-bit, ragged
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/fixtures.npz")
    args = ap.parse_args()

    arrays = {}
    meta = []
    for i, (rows, k, bits, g, seed) in enumerate(CASES):
        rng = np.random.default_rng(100 + seed)
        x = rng.normal(scale=2.0, size=(rows, k)).astype(np.float32)
        codes, scales, mins = quant.quantize_lq(x, bits, g)
        arrays[f"case{i}_x"] = x
        arrays[f"case{i}_codes"] = np.asarray(codes, dtype=np.int32)
        arrays[f"case{i}_scales"] = np.asarray(scales, dtype=np.float32)
        arrays[f"case{i}_mins"] = np.asarray(mins, dtype=np.float32)
        meta.append([rows, k, bits, g])
        # GEMM fixture: x (rows,k) against a weight matrix (k, n)
        n = 6
        w = rng.normal(size=(k, n)).astype(np.float32)
        out = quant.lq_matmul_reference(x, w, bits, 8, g)
        arrays[f"case{i}_w"] = w
        arrays[f"case{i}_gemm"] = np.asarray(out, dtype=np.float32)
    arrays["meta"] = np.asarray(meta, dtype=np.int32)
    np.savez(args.out, **arrays)
    print(f"wrote {len(CASES)} parity cases to {args.out}")


if __name__ == "__main__":
    main()
