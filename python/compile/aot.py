"""AOT lowering: JAX models -> HLO TEXT artifacts for the rust runtime.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a single fused module `fn(w_0, ..., w_{P-1}, x) -> (logits,)`
with the weights as *runtime parameters* (in model.param_order order), so the
rust coordinator can substitute arbitrarily quantized/dequantized weights
without re-lowering. Variants:

  {model}_f32_b{B}.hlo.txt    fp32 forward            (serving baseline)
  {model}_lq{bits}_b{B}.hlo.txt  Pallas LQ forward    (kernels in the HLO:
                                 runtime activation quantization + eq. 7 GEMM)

`manifest.json` records every artifact with parameter names/shapes so the
rust side is fully data-driven.

    python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(model_name: str, variant: str, batch: int, bits: int, region: int,
                  params: dict) -> str:
    order = M.param_order(model_name)

    if variant == "f32":
        def fn(*args):
            p = dict(zip(order, args[:-1]))
            return (M.forward(p, args[-1], model_name),)
    elif variant == "lq":
        def fn(*args):
            p = dict(zip(order, args[:-1]))
            return (M.forward_pallas(p, args[-1], model_name, bits=bits, region=region),)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    specs = [jax.ShapeDtypeStruct(np.asarray(params[k]).shape, np.float32) for k in order]
    specs.append(jax.ShapeDtypeStruct((batch,) + M.IN_SHAPE, np.float32))
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=sorted(M.MODELS))
    ap.add_argument("--batches", nargs="*", type=int, default=[1, 8, 32])
    ap.add_argument("--lq-bits", nargs="*", type=int, default=[8, 2])
    ap.add_argument("--lq-batches", nargs="*", type=int, default=[1, 8])
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"artifacts": [], "models": {}}
    for model_name in args.models:
        wpath = os.path.join(args.out_dir, f"weights_{model_name}.npz")
        if not os.path.exists(wpath):
            raise SystemExit(f"missing {wpath}; run `python -m compile.train` first")
        params = dict(np.load(wpath))
        order = M.param_order(model_name)
        manifest["models"][model_name] = {
            "weights": os.path.basename(wpath),
            "param_order": order,
            "param_shapes": {k: list(params[k].shape) for k in order},
            "input_shape": list(M.IN_SHAPE),
            "num_classes": M.NUM_CLASSES,
        }

        jobs = [("f32", b, 0, 0) for b in args.batches]
        jobs += [("lq", b, bits, 0) for bits in args.lq_bits for b in args.lq_batches]
        for variant, batch, bits, region in jobs:
            tag = f"{model_name}_{variant}" + (f"{bits}" if variant == "lq" else "")
            name = f"{tag}_b{batch}"
            text = lower_variant(model_name, variant, batch, bits, region, params)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "file": fname,
                    "model": model_name,
                    "variant": variant,
                    "bits": bits,
                    "batch": batch,
                    "region": region,
                }
            )
            print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
