"""L2: JAX model definitions — MiniAlexNet and MiniVGG (NCHW).

These are the scaled-down stand-ins for the paper's Caffe AlexNet / VGG-16
(see DESIGN.md §Substitutions); the *full* architectures live in the rust
side (`nn/arch.rs`) for the analytic experiments (Table 3 op counts).

Three forward paths over the same parameters:
  - :func:`forward`         — fp32 reference (used for training and the f32
                              serving artifacts).
  - :func:`forward_quant`   — fake-quant DQ/LQ path in plain jnp (python-side
                              accuracy checks; the big sweeps run in rust).
  - :func:`forward_pallas`  — the L1 path: im2col + Pallas quantize +
                              lq_matmul kernels; lowered into the quantized
                              serving artifacts so the kernels ship in HLO.

Convolutions in the quantized paths use im2col + GEMM, which is exactly the
formulation the paper's Edison implementation uses ("matrix correlation based
convolution ... offloaded to MKL") and the one the rust fixed-point kernels
mirror. Parameters are a flat dict name -> array; PARAM_ORDER fixes the
positional order used by the AOT artifacts.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import quant
from compile.kernels import lq_matmul as k_lq
from compile.kernels import quantize as k_quant

Params = Dict[str, jnp.ndarray]

NUM_CLASSES = 16
IN_SHAPE = (3, 32, 32)


class ConvSpec:
    """One conv layer: out channels, kernel, stride, padding, + pool flag."""

    def __init__(self, name, cin, cout, k, stride=1, pad=None, pool=False):
        self.name = name
        self.cin = cin
        self.cout = cout
        self.k = k
        self.stride = stride
        self.pad = (k // 2) if pad is None else pad
        self.pool = pool

    @property
    def patch(self) -> int:
        """im2col K dimension == the paper's default LQ region size."""
        return self.cin * self.k * self.k


class FcSpec:
    def __init__(self, name, cin, cout, relu=True):
        self.name = name
        self.cin = cin
        self.cout = cout
        self.relu = relu


def minialexnet() -> Tuple[List, List]:
    convs = [
        ConvSpec("conv1", 3, 32, 5, pool=True),
        ConvSpec("conv2", 32, 64, 5, pool=True),
        ConvSpec("conv3", 64, 128, 3, pool=True),
    ]
    fcs = [FcSpec("fc1", 128 * 4 * 4, 256), FcSpec("fc2", 256, NUM_CLASSES, relu=False)]
    return convs, fcs


def minivgg() -> Tuple[List, List]:
    convs = [
        ConvSpec("conv1_1", 3, 32, 3), ConvSpec("conv1_2", 32, 32, 3, pool=True),
        ConvSpec("conv2_1", 32, 64, 3), ConvSpec("conv2_2", 64, 64, 3, pool=True),
        ConvSpec("conv3_1", 64, 128, 3), ConvSpec("conv3_2", 128, 128, 3, pool=True),
    ]
    fcs = [FcSpec("fc1", 128 * 4 * 4, 256), FcSpec("fc2", 256, NUM_CLASSES, relu=False)]
    return convs, fcs


MODELS = {"minialexnet": minialexnet, "minivgg": minivgg}


def param_order(model: str) -> List[str]:
    """Fixed positional parameter order for the AOT artifacts + rust loader."""
    convs, fcs = MODELS[model]()
    names = []
    for c in convs:
        names += [f"{c.name}.w", f"{c.name}.b"]
    for f in fcs:
        names += [f"{f.name}.w", f"{f.name}.b"]
    return names


def init_params(model: str, seed: int = 0) -> Params:
    """He-init conv (O, C, Kh, Kw) and fc (In, Out) parameters."""
    convs, fcs = MODELS[model]()
    rng = np.random.default_rng(seed)
    p: Params = {}
    for c in convs:
        fan_in = c.cin * c.k * c.k
        p[f"{c.name}.w"] = jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / fan_in), size=(c.cout, c.cin, c.k, c.k)),
            dtype=jnp.float32,
        )
        p[f"{c.name}.b"] = jnp.zeros((c.cout,), jnp.float32)
    for f in fcs:
        p[f"{f.name}.w"] = jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / f.cin), size=(f.cin, f.cout)), dtype=jnp.float32
        )
        p[f"{f.name}.b"] = jnp.zeros((f.cout,), jnp.float32)
    return p


# ---------------------------------------------------------------- layers --


def conv2d(x, w, b, stride: int, pad: int):
    """fp32 conv, NCHW x (B,C,H,W), w (O,C,Kh,Kw)."""
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def im2col(x, k: int, stride: int, pad: int):
    """(B,C,H,W) -> (B*Ho*Wo, C*k*k) patch matrix, channel-major patches.

    Column order matches rust `fixedpoint::im2col` and the paper's region
    layout: one row = one receptive field = one LQ region (g = C*k*k).
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    # gather k*k shifted views; axis order (B, Ho, Wo, C, kh, kw)
    cols = jnp.stack(
        [
            xp[:, :, i : i + stride * ho : stride, j : j + stride * wo : stride]
            for i in range(k)
            for j in range(k)
        ],
        axis=-1,
    )  # (B, C, Ho, Wo, k*k)
    cols = cols.transpose(0, 2, 3, 1, 4)  # (B, Ho, Wo, C, k*k)
    return cols.reshape(b * ho * wo, c * k * k), (b, ho, wo)


def maxpool2(x):
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def log_softmax(z):
    z = z - z.max(axis=-1, keepdims=True)
    return z - jnp.log(jnp.exp(z).sum(axis=-1, keepdims=True))


# -------------------------------------------------------------- forwards --


def forward(params: Params, x: jnp.ndarray, model: str) -> jnp.ndarray:
    """fp32 reference forward: logits (B, NUM_CLASSES)."""
    convs, fcs = MODELS[model]()
    for c in convs:
        x = conv2d(x, params[f"{c.name}.w"], params[f"{c.name}.b"], c.stride, c.pad)
        x = jax.nn.relu(x)
        if c.pool:
            x = maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    for f in fcs:
        x = x @ params[f"{f.name}.w"] + params[f"{f.name}.b"]
        if f.relu:
            x = jax.nn.relu(x)
    return x


def _quant_fn(scheme: str, bits: int, g: int):
    if scheme == "lq":
        return lambda t: quant.fake_quant_lq(t, bits, g)
    if scheme == "dq":
        return lambda t: quant.fake_quant_dq(t, bits)
    raise ValueError(f"unknown scheme {scheme!r}")


def forward_quant(
    params: Params,
    x: jnp.ndarray,
    model: str,
    *,
    scheme: str = "lq",
    bits_w: int = 8,
    bits_a: int = 8,
    region: int = 0,
) -> jnp.ndarray:
    """Fake-quant forward (paper §VI protocol).

    Weights are quantized per-kernel (offline, static 8-bit in the paper);
    activations are quantized at runtime with `scheme` in {dq, lq}. `region`
    is the LQ region size; 0 means "the conv patch size" (paper default).
    Conv layers run as im2col + GEMM so the quantization region layout is the
    GEMM reduction axis, exactly like the kernels and the rust engine.
    """
    convs, fcs = MODELS[model]()
    for c in convs:
        w = params[f"{c.name}.w"].reshape(c.cout, c.patch)  # (O, K) rows=kernels
        wq = quant.fake_quant_lq(w, bits_w, c.patch if region == 0 else min(region, c.patch))
        a, (b, ho, wo) = im2col(x, c.k, c.stride, c.pad)
        g = c.patch if region == 0 else min(region, c.patch)
        aq = _quant_fn(scheme, bits_a, g)(a)
        out = aq @ wq.T + params[f"{c.name}.b"]
        x = jax.nn.relu(out).reshape(b, ho, wo, c.cout).transpose(0, 3, 1, 2)
        if c.pool:
            x = maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    for f in fcs:
        w = params[f"{f.name}.w"]
        g = w.shape[0] if region == 0 else min(region, w.shape[0])
        wq = quant.fake_quant_lq(w.T, bits_w, g).T
        xq = _quant_fn(scheme, bits_a, g)(x)
        x = xq @ wq + params[f"{f.name}.b"]
        if f.relu:
            x = jax.nn.relu(x)
    return x


def _lq_gemm_pallas(a, w_t, bits: int, g: int):
    """Quantize `a` at runtime (Pallas) and contract with offline-quantized
    weights (Pallas lq_matmul). w_t is (N, K).

    Tile choice: on a real TPU the BlockSpec tiles would be VMEM-bounded
    (DESIGN.md §Perf); the shipped artifacts execute interpret-lowered HLO on
    the CPU PJRT plugin, where each grid step becomes a while-loop iteration
    with dynamic-slice traffic — so we collapse the grid with tiles as large
    as the operands (measured 434 -> 17 ms for the b8 MiniAlexNet forward,
    EXPERIMENTS.md §Perf)."""
    m = a.shape[0]
    n = w_t.shape[0]
    qa, sa, ma = k_quant.quantize_lq(a, bits=bits, g=g, bm=m)
    qw, sw, mw = quant.quantize_lq(w_t, 8, g)  # weights: static 8-bit offline
    return k_lq.lq_matmul(qa, sa, ma, qw, sw, mw, g=g, bm=m, bn=n)


def _pick_region(k: int, want: int) -> int:
    """Largest divisor of k that is <= want (kernels need g | K)."""
    return k_lq.fit_tile(k, want)


def forward_pallas(
    params: Params, x: jnp.ndarray, model: str, *, bits: int = 8, region: int = 0
) -> jnp.ndarray:
    """The L1 path: every GEMM goes through the Pallas quantize + lq_matmul
    kernels. This is what `aot.py` lowers into the *_lq*.hlo.txt artifacts, so
    the shipped HLO contains the kernels' computation."""
    convs, fcs = MODELS[model]()
    for c in convs:
        w = params[f"{c.name}.w"].reshape(c.cout, c.patch)
        a, (b, ho, wo) = im2col(x, c.k, c.stride, c.pad)
        g = c.patch if region == 0 else _pick_region(c.patch, region)
        out = _lq_gemm_pallas(a, w, bits, g) + params[f"{c.name}.b"]
        x = jax.nn.relu(out).reshape(b, ho, wo, c.cout).transpose(0, 3, 1, 2)
        if c.pool:
            x = maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    for f in fcs:
        k = f.cin
        g = k if region == 0 else _pick_region(k, region)
        x = _lq_gemm_pallas(x, params[f"{f.name}.w"].T, bits, g) + params[f"{f.name}.b"]
        if f.relu:
            x = jax.nn.relu(x)
    return x


forward_jit = functools.partial(jax.jit, static_argnames=("model",))(forward)
