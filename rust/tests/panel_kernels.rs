//! Property tests pinning the shared weight-panel GEMM core bit-close to the
//! seed's naive general-region formulation, across every axis the panel
//! layout complicates: multiple regions per row, odd K tails (K not a
//! multiple of the region or the NR tile), bit widths 1-8, thread counts
//! 1/3, and N crossing tile boundaries. Every SIMD dispatch arm this host
//! supports (`simd::supported_kernels()` — on aarch64 that covers both the
//! NEON `umlal` tile and, when built with `--features dotprod` on capable
//! hardware, the `udot` tile; on x86-64 the AVX2 / VNNI tiles) must agree
//! **bit-exactly** with the forced-scalar arm — integer accumulation is
//! exact and the f32 correction is shared, so any difference is a kernel
//! bug, not rounding. The bit-serial popcount GEMM gets the same treatment:
//! every arm's plane-dot, over every {1,2,4}-bit width pair, must equal the
//! forced-scalar u8 panel oracle bit-exactly (flat and bit-packed
//! activations). Plus the fused `im2col_quantized` vs `im2col` +
//! `quantize_matrix` equivalence (including parallel vs single-threaded
//! bit-identity), and the engine-level regression that prepared panels are
//! cached (pointer identity across forward passes).

use std::collections::HashMap;

use lqr::fixedpoint::gemm_packed::PackedMatrix;
use lqr::fixedpoint::simd;
use lqr::fixedpoint::{
    gemm_bitserial_packed_with, gemm_bitserial_with, gemm_lut_panel, gemm_lut_panel_with,
    gemm_panel, gemm_panel_packed, gemm_panel_packed_with, gemm_panel_with, gemm_quantized_naive,
    im2col, im2col_quantized, WeightPanel,
};
use lqr::nn::forward::Scheme;
use lqr::nn::{Arch, Engine, Layer, Precision};
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::Tensor;
use lqr::util::prop;
use lqr::util::rng::Rng;

/// Random shapes that deliberately stress panel edges: M crossing MR blocks,
/// N crossing NR tiles, K with short tail regions.
fn gen_case(rng: &mut Rng) -> (usize, usize, usize, RegionSpec) {
    let m = rng.index(1, 22);
    let n = rng.index(1, 52);
    let k = rng.index(1, 90);
    let region = match rng.below(4) {
        0 => RegionSpec::PerRow,
        1 => RegionSpec::PerTensor,
        // Sizes that rarely divide K: forces rpr > 1 with a ragged tail.
        _ => RegionSpec::Size(rng.index(1, k + 1)),
    };
    (m, n, k, region)
}

fn rel_close(got: &Tensor, want: &Tensor, ctx: &str) {
    let tol = 1e-5 * want.max_abs().max(1.0);
    assert!(
        got.max_abs_diff(want) <= tol,
        "{ctx}: diff {} > tol {tol}",
        got.max_abs_diff(want)
    );
}

#[test]
fn panel_gemm_matches_naive_oracle() {
    prop::check_named("panel-vs-naive", 0xBEE5, 80, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [1u8, 2, 4, 8][rng.below(4) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, bits, region);
        let want = gemm_quantized_naive(&aq, &wq, 1);
        let wp = WeightPanel::from_quantized(&wq);
        for threads in [1usize, 3] {
            let got = gemm_panel(&aq, &wp, threads);
            let ctx = format!("m={m} n={n} k={k} bits={bits} region={region} threads={threads}");
            rel_close(&got, &want, &ctx);
        }
    });
}

#[test]
fn packed_panel_matches_naive_oracle() {
    prop::check_named("packed-panel-vs-naive", 0xBEE6, 60, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [2u8, 4, 8][rng.below(3) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, bits, region);
        let want = gemm_quantized_naive(&aq, &wq, 1);
        let ap = PackedMatrix::from_quantized(&aq);
        let wp = WeightPanel::from_packed(&PackedMatrix::from_quantized(&wq));
        for threads in [1usize, 3] {
            let got = gemm_panel_packed(&ap, &wp, threads);
            let ctx =
                format!("packed m={m} n={n} k={k} bits={bits} region={region} threads={threads}");
            rel_close(&got, &want, &ctx);
        }
    });
}

#[test]
fn lut_panel_matches_naive_oracle() {
    prop::check_named("lut-panel-vs-naive", 0xBEE7, 60, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [1u8, 2, 4][rng.below(3) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, 8, region); // paper: weights stay 8-bit
        let want = gemm_quantized_naive(&aq, &wq, 1);
        let wp = WeightPanel::from_quantized(&wq);
        for threads in [1usize, 3] {
            let got = gemm_lut_panel(&aq, &wp, threads);
            let ctx =
                format!("lut m={m} n={n} k={k} bits={bits} region={region} threads={threads}");
            rel_close(&got, &want, &ctx);
        }
    });
}

#[test]
fn every_supported_simd_arm_matches_forced_scalar_bit_exactly() {
    let scalar = simd::scalar_kernel();
    // Not just the dispatched arm: on an aarch64 host this pins both the
    // NEON umlal tile and (with `--features dotprod` on capable hardware)
    // the udot tile; on x86-64 the AVX2 and (with `--features avx512`) the
    // VNNI tiles. The dispatcher's own pick is always in the list.
    prop::check_named("simd-vs-scalar-panel", 0x51D5, 64, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = rng.index(1, 9) as u8; // every width 1..=8
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, bits, region);
        let wp = WeightPanel::from_quantized(&wq);
        let want = gemm_panel_with(&aq, &wp, 1, scalar);
        // Every dispatch arm sits bit-exactly on the seed naive oracle: the
        // integer dot is exact and the f32 correction order is shared.
        let naive = gemm_quantized_naive(&aq, &wq, 1);
        assert_eq!(
            want.data(),
            naive.data(),
            "scalar panel vs naive: m={m} n={n} k={k} bits={bits} region={region}"
        );
        for kernel in simd::supported_kernels() {
            for threads in [1usize, 3] {
                let got = gemm_panel_with(&aq, &wp, threads, kernel);
                assert_eq!(
                    got.data(),
                    want.data(),
                    "kernel {} vs scalar: m={m} n={n} k={k} bits={bits} region={region} threads={threads}",
                    kernel.name
                );
            }
        }
    });
}

#[test]
fn every_supported_simd_arm_matches_forced_scalar_packed() {
    let scalar = simd::scalar_kernel();
    prop::check_named("simd-vs-scalar-packed", 0x51D6, 40, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = rng.index(1, 9) as u8;
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let ap = PackedMatrix::from_quantized(&quantize_matrix(&a, bits, region));
        let wp = WeightPanel::from_packed(&PackedMatrix::from_quantized(&quantize_matrix(
            &w, bits, region,
        )));
        let want = gemm_panel_packed_with(&ap, &wp, 1, scalar);
        for kernel in simd::supported_kernels() {
            let got = gemm_panel_packed_with(&ap, &wp, 3, kernel);
            assert_eq!(
                got.data(),
                want.data(),
                "packed kernel {}: m={m} n={n} k={k} bits={bits} region={region}",
                kernel.name
            );
        }
    });
}

#[test]
fn every_supported_bucket_arm_matches_forced_scalar_lut() {
    let scalar = simd::scalar_kernel();
    prop::check_named("simd-vs-scalar-lut", 0x51D7, 40, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [1u8, 2, 3, 4][rng.below(4) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, 8, region); // paper: weights stay 8-bit
        let wp = WeightPanel::from_quantized(&wq);
        let want = gemm_lut_panel_with(&aq, &wp, 1, scalar);
        for kernel in simd::supported_kernels() {
            let got = gemm_lut_panel_with(&aq, &wp, 3, kernel);
            assert_eq!(
                got.data(),
                want.data(),
                "lut kernel {}: m={m} n={n} k={k} bits={bits} region={region}",
                kernel.name
            );
        }
    });
}

#[test]
fn bitserial_matches_u8_scalar_oracle_on_every_arm() {
    // The bit-serial popcount GEMM must agree **bit-exactly** with the
    // forced-scalar u8 panel path — the integer dot is the same number
    // either way (sum of weighted plane popcounts == sum of code products)
    // and the eq. 7 epilogue applies the identical f32 expression in the
    // identical region order. Every supported dispatch arm (scalar
    // count_ones, AVX2 nibble-LUT popcount, NEON vcntq — plus whatever the
    // VNNI/udot kernels reuse), every width pair in {1,2,4}^2, shapes with
    // multiple regions per row and ragged word tails (K % 64 != 0), thread
    // counts 1/3, and bit-packed activation streams riding the same planes.
    let scalar = simd::scalar_kernel();
    prop::check_named("bitserial-vs-u8-oracle", 0x51D9, 48, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits_a = [1u8, 2, 4][rng.below(3) as usize];
        let bits_w = [1u8, 2, 4][rng.below(3) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits_a, region);
        let wq = quantize_matrix(&w, bits_w, region);
        let wp = WeightPanel::from_quantized(&wq);
        assert!(wp.bit_planes().is_some(), "<=4-bit panel must carry bit planes");
        let want = gemm_panel_with(&aq, &wp, 1, scalar);
        let ap = PackedMatrix::from_quantized(&aq);
        for kernel in simd::supported_kernels() {
            for threads in [1usize, 3] {
                let got = gemm_bitserial_with(&aq, &wp, threads, kernel);
                assert_eq!(
                    got.data(),
                    want.data(),
                    "bitserial {} vs u8 scalar: m={m} n={n} k={k} a{bits_a}/w{bits_w} region={region} threads={threads}",
                    kernel.name
                );
            }
            let got_packed = gemm_bitserial_packed_with(&ap, &wp, 3, kernel);
            assert_eq!(
                got_packed.data(),
                want.data(),
                "bitserial-packed {}: m={m} n={n} k={k} a{bits_a}/w{bits_w} region={region}",
                kernel.name
            );
        }
    });
}

#[test]
fn im2col_quantized_equals_unfused_pipeline() {
    // The fused lowering must reproduce im2col + quantize_matrix exactly:
    // codes, scales, mins and code sums — across padding-heavy geometries,
    // strides, every bit width and all three region schemes. And the
    // parallel path (rows chunked over scope_chunks) must be bit-identical
    // to the single-threaded one: per-row work is independent and the DQ
    // prepass merge is exact, so threads never change a single byte.
    prop::check_named("im2col-fused-quant", 0xF05D, 48, |rng, _| {
        let b = rng.index(1, 3);
        let c = rng.index(1, 4);
        let h = rng.index(3, 10);
        let k = rng.index(1, h.min(5) + 1);
        let stride = rng.index(1, 4);
        let pad = rng.index(0, k); // up to k-1: every border patch clips
        let bits = rng.index(1, 9) as u8;
        let patch = c * k * k;
        let region = match rng.below(3) {
            0 => RegionSpec::PerRow,
            1 => RegionSpec::PerTensor,
            _ => RegionSpec::Size(rng.index(1, patch + 1)),
        };
        let x = Tensor::new(&[b, c, h, h], prop::gen_values(rng, b * c * h * h));
        let (cols, dims) = im2col(&x, k, stride, pad);
        let want = quantize_matrix(&cols, bits, region);
        let (got, dims2) = im2col_quantized(&x, k, stride, pad, bits, region, 1);
        let ctx = format!("b={b} c={c} h={h} k={k} s={stride} p={pad} bits={bits} region={region}");
        assert_eq!(dims, dims2, "{ctx}");
        assert_eq!(got.rows, want.rows, "{ctx}");
        assert_eq!(got.k, want.k, "{ctx}");
        assert_eq!(got.codes, want.codes, "{ctx}");
        assert_eq!(got.scales, want.scales, "{ctx}");
        assert_eq!(got.mins, want.mins, "{ctx}");
        assert_eq!(got.code_sums, want.code_sums, "{ctx}");
        for threads in [3usize, 7] {
            let (par, dims3) = im2col_quantized(&x, k, stride, pad, bits, region, threads);
            assert_eq!(dims2, dims3, "{ctx} threads={threads}");
            assert_eq!(par.codes, got.codes, "{ctx} threads={threads}");
            assert_eq!(par.scales, got.scales, "{ctx} threads={threads}");
            assert_eq!(par.mins, got.mins, "{ctx} threads={threads}");
            assert_eq!(par.code_sums, got.code_sums, "{ctx} threads={threads}");
        }
    });
}

fn tiny_engine(seed: u64) -> Engine {
    let arch = Arch {
        name: "tiny",
        input: (2, 8, 8),
        num_classes: 4,
        layers: vec![
            Layer::Conv {
                name: "c1", cin: 2, cout: 4, k: 3, stride: 1, pad: 1, groups: 1, pool: true,
            },
            Layer::Fc { name: "f1", cin: 4 * 4 * 4, cout: 4, relu: false },
        ],
    };
    arch.validate().unwrap();
    let mut rng = Rng::new(seed);
    let mut params = HashMap::new();
    for l in &arch.layers {
        let (wshape, blen): (Vec<usize>, usize) = match *l {
            Layer::Conv { cin, cout, k, .. } => (vec![cout, cin, k, k], cout),
            Layer::Fc { cin, cout, .. } => (vec![cin, cout], cout),
        };
        let n: usize = wshape.iter().product();
        params.insert(format!("{}.w", l.name()), Tensor::new(&wshape, rng.normal_vec(n)));
        params.insert(format!("{}.b", l.name()), Tensor::new(&[blen], rng.normal_vec(blen)));
    }
    Engine::from_params(arch, params).unwrap()
}

#[test]
fn engine_reuses_cached_panel_across_forward_passes() {
    let eng = tiny_engine(21);
    let mut rng = Rng::new(22);
    let x = Tensor::new(&[2, 2, 8, 8], rng.uniform_vec(2 * 2 * 8 * 8, 0.0, 1.0));
    let precision = Precision::lq(8);

    assert!(
        eng.cached_panel("c1", 8, RegionSpec::PerRow).is_none(),
        "no panel before the first forward pass"
    );
    let y1 = eng.forward(&x, precision);
    let p1 = eng
        .cached_panel("c1", 8, RegionSpec::PerRow)
        .expect("first forward pass must populate the panel cache");
    let y2 = eng.forward(&x, precision);
    let p2 = eng
        .cached_panel("c1", 8, RegionSpec::PerRow)
        .expect("panel cache must survive the second pass");
    // The regression: the second pass reuses the prepared panel (pointer
    // identity), instead of re-widening the weights per call.
    assert!(std::sync::Arc::ptr_eq(&p1, &p2), "panel was rebuilt between passes");
    assert_eq!(y1.data(), y2.data(), "cached panel must not change numerics");

    // Different quantization config -> different panel.
    let lq4 = Precision::Quant {
        scheme: Scheme::Lq,
        bits_a: 4,
        bits_w: 4,
        region: RegionSpec::PerRow,
        lut: false,
    };
    eng.forward(&x, lq4);
    let p4 = eng.cached_panel("c1", 4, RegionSpec::PerRow).expect("4-bit panel cached");
    assert!(!std::sync::Arc::ptr_eq(&p1, &p4));
}

#[test]
fn engine_lut_and_integer_paths_agree_on_panels() {
    let eng = tiny_engine(31);
    let mut rng = Rng::new(32);
    let x = Tensor::new(&[2, 2, 8, 8], rng.uniform_vec(2 * 2 * 8 * 8, 0.0, 1.0));
    let base = Precision::Quant {
        scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::Size(9), lut: false,
    };
    let with_lut = Precision::Quant {
        scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::Size(9), lut: true,
    };
    let a = eng.forward(&x, base);
    let b = eng.forward(&x, with_lut);
    assert!(a.max_abs_diff(&b) <= 1e-4 * a.max_abs().max(1.0));
}
