//! Property tests pinning the shared weight-panel GEMM core bit-close to the
//! seed's naive general-region formulation, across every axis the panel
//! layout complicates: multiple regions per row, odd K tails (K not a
//! multiple of the region or the NR tile), bit widths 1/2/4/8, thread counts
//! 1/3, and N crossing tile boundaries. Plus the engine-level regression
//! that prepared panels are cached (pointer identity across forward passes).

use std::collections::HashMap;

use lqr::fixedpoint::gemm_packed::PackedMatrix;
use lqr::fixedpoint::{
    gemm_lut_panel, gemm_panel, gemm_panel_packed, gemm_quantized_naive, WeightPanel,
};
use lqr::nn::forward::Scheme;
use lqr::nn::{Arch, Engine, Layer, Precision};
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::Tensor;
use lqr::util::prop;
use lqr::util::rng::Rng;

/// Random shapes that deliberately stress panel edges: M crossing MR blocks,
/// N crossing NR tiles, K with short tail regions.
fn gen_case(rng: &mut Rng) -> (usize, usize, usize, RegionSpec) {
    let m = rng.index(1, 22);
    let n = rng.index(1, 52);
    let k = rng.index(1, 90);
    let region = match rng.below(4) {
        0 => RegionSpec::PerRow,
        1 => RegionSpec::PerTensor,
        // Sizes that rarely divide K: forces rpr > 1 with a ragged tail.
        _ => RegionSpec::Size(rng.index(1, k + 1)),
    };
    (m, n, k, region)
}

fn rel_close(got: &Tensor, want: &Tensor, ctx: &str) {
    let tol = 1e-5 * want.max_abs().max(1.0);
    assert!(
        got.max_abs_diff(want) <= tol,
        "{ctx}: diff {} > tol {tol}",
        got.max_abs_diff(want)
    );
}

#[test]
fn panel_gemm_matches_naive_oracle() {
    prop::check_named("panel-vs-naive", 0xBEE5, 80, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [1u8, 2, 4, 8][rng.below(4) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, bits, region);
        let want = gemm_quantized_naive(&aq, &wq, 1);
        let wp = WeightPanel::from_quantized(&wq);
        for threads in [1usize, 3] {
            let got = gemm_panel(&aq, &wp, threads);
            let ctx = format!("m={m} n={n} k={k} bits={bits} region={region} threads={threads}");
            rel_close(&got, &want, &ctx);
        }
    });
}

#[test]
fn packed_panel_matches_naive_oracle() {
    prop::check_named("packed-panel-vs-naive", 0xBEE6, 60, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [2u8, 4, 8][rng.below(3) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, bits, region);
        let want = gemm_quantized_naive(&aq, &wq, 1);
        let ap = PackedMatrix::from_quantized(&aq);
        let wp = WeightPanel::from_packed(&PackedMatrix::from_quantized(&wq));
        for threads in [1usize, 3] {
            let got = gemm_panel_packed(&ap, &wp, threads);
            let ctx =
                format!("packed m={m} n={n} k={k} bits={bits} region={region} threads={threads}");
            rel_close(&got, &want, &ctx);
        }
    });
}

#[test]
fn lut_panel_matches_naive_oracle() {
    prop::check_named("lut-panel-vs-naive", 0xBEE7, 60, |rng, _| {
        let (m, n, k, region) = gen_case(rng);
        let bits = [1u8, 2, 4][rng.below(3) as usize];
        let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
        let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
        let aq = quantize_matrix(&a, bits, region);
        let wq = quantize_matrix(&w, 8, region); // paper: weights stay 8-bit
        let want = gemm_quantized_naive(&aq, &wq, 1);
        let wp = WeightPanel::from_quantized(&wq);
        for threads in [1usize, 3] {
            let got = gemm_lut_panel(&aq, &wp, threads);
            let ctx =
                format!("lut m={m} n={n} k={k} bits={bits} region={region} threads={threads}");
            rel_close(&got, &want, &ctx);
        }
    });
}

fn tiny_engine(seed: u64) -> Engine {
    let arch = Arch {
        name: "tiny",
        input: (2, 8, 8),
        num_classes: 4,
        layers: vec![
            Layer::Conv {
                name: "c1", cin: 2, cout: 4, k: 3, stride: 1, pad: 1, groups: 1, pool: true,
            },
            Layer::Fc { name: "f1", cin: 4 * 4 * 4, cout: 4, relu: false },
        ],
    };
    arch.validate().unwrap();
    let mut rng = Rng::new(seed);
    let mut params = HashMap::new();
    for l in &arch.layers {
        let (wshape, blen): (Vec<usize>, usize) = match *l {
            Layer::Conv { cin, cout, k, .. } => (vec![cout, cin, k, k], cout),
            Layer::Fc { cin, cout, .. } => (vec![cin, cout], cout),
        };
        let n: usize = wshape.iter().product();
        params.insert(format!("{}.w", l.name()), Tensor::new(&wshape, rng.normal_vec(n)));
        params.insert(format!("{}.b", l.name()), Tensor::new(&[blen], rng.normal_vec(blen)));
    }
    Engine::from_params(arch, params).unwrap()
}

#[test]
fn engine_reuses_cached_panel_across_forward_passes() {
    let eng = tiny_engine(21);
    let mut rng = Rng::new(22);
    let x = Tensor::new(&[2, 2, 8, 8], rng.uniform_vec(2 * 2 * 8 * 8, 0.0, 1.0));
    let precision = Precision::lq(8);

    assert!(
        eng.cached_panel("c1", 8, RegionSpec::PerRow).is_none(),
        "no panel before the first forward pass"
    );
    let y1 = eng.forward(&x, precision);
    let p1 = eng
        .cached_panel("c1", 8, RegionSpec::PerRow)
        .expect("first forward pass must populate the panel cache");
    let y2 = eng.forward(&x, precision);
    let p2 = eng
        .cached_panel("c1", 8, RegionSpec::PerRow)
        .expect("panel cache must survive the second pass");
    // The regression: the second pass reuses the prepared panel (pointer
    // identity), instead of re-widening the weights per call.
    assert!(std::sync::Arc::ptr_eq(&p1, &p2), "panel was rebuilt between passes");
    assert_eq!(y1.data(), y2.data(), "cached panel must not change numerics");

    // Different quantization config -> different panel.
    let lq4 = Precision::Quant {
        scheme: Scheme::Lq,
        bits_a: 4,
        bits_w: 4,
        region: RegionSpec::PerRow,
        lut: false,
    };
    eng.forward(&x, lq4);
    let p4 = eng.cached_panel("c1", 4, RegionSpec::PerRow).expect("4-bit panel cached");
    assert!(!std::sync::Arc::ptr_eq(&p1, &p4));
}

#[test]
fn engine_lut_and_integer_paths_agree_on_panels() {
    let eng = tiny_engine(31);
    let mut rng = Rng::new(32);
    let x = Tensor::new(&[2, 2, 8, 8], rng.uniform_vec(2 * 2 * 8 * 8, 0.0, 1.0));
    let base = Precision::Quant {
        scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::Size(9), lut: false,
    };
    let with_lut = Precision::Quant {
        scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::Size(9), lut: true,
    };
    let a = eng.forward(&x, base);
    let b = eng.forward(&x, with_lut);
    assert!(a.max_abs_diff(&b) <= 1e-4 * a.max_abs().max(1.0));
}
