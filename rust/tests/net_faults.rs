//! Wire-level fault injection: the ingress half of the liveness contract.
//!
//! The server half of `tests/failure_injection.rs`: every connection — even
//! a hostile one — must resolve to a typed outcome in bounded time, and no
//! fault on one connection may degrade service on another. No test here can
//! hang: every socket read carries a timeout, and every shutdown is raced
//! against a deadline on a separate thread.
//!
//! Scenarios: oversized length prefixes (the `u32::MAX` DoS), random
//! garbage, truncated frames and mid-frame disconnects, zero-length and
//! non-UTF-8 routes, pipelining across an error reply, slowloris (stalled
//! reader), a stalled writer pinned by a multi-megabyte reply, a connection
//! flood past `max_conns`, shutdown under load, and the health built-in.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use lqr::coordinator::backend::{Backend, MockBackend};
use lqr::coordinator::net::{ImageSpec, NetClient, NetConfig, NetServer, WireStatus};
use lqr::coordinator::router::Router;
use lqr::coordinator::CoordinatorConfig;
use lqr::tensor::Tensor;
use lqr::util::rng::Rng;

const RECV_TIMEOUT: Duration = Duration::from_secs(10);
const SPEC: ImageSpec = ImageSpec { c: 1, h: 2, w: 2 };

fn router_with(classes: usize, delay: Duration) -> Arc<Router> {
    let mut r = Router::new();
    r.add_route(
        "mock",
        CoordinatorConfig::default(),
        Box::new(move || {
            Ok(Box::new(MockBackend { classes, delay, calls: Arc::new(AtomicU64::new(0)) })
                as Box<dyn Backend>)
        }),
    )
    .unwrap();
    Arc::new(r)
}

fn img(v: f32) -> Tensor {
    Tensor::filled(&[1, 1, 2, 2], v)
}

/// Encode one request frame (`route_len | route | n_floats | floats`).
fn frame(route: &[u8], floats: &[f32]) -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(&(route.len() as u32).to_le_bytes());
    b.extend_from_slice(route);
    b.extend_from_slice(&(floats.len() as u32).to_le_bytes());
    for v in floats {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b
}

/// Raw connection with every read bounded by `RECV_TIMEOUT` — a hung read
/// here is a server liveness bug, surfaced as a test failure not a hang.
fn raw_connect(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    s.set_write_timeout(Some(RECV_TIMEOUT)).unwrap();
    s
}

/// Read one reply status byte; `None` on EOF/timeout.
fn read_status(s: &mut TcpStream) -> Option<u8> {
    let mut b = [0u8; 1];
    s.read_exact(&mut b).ok().map(|_| b[0])
}

/// Read the `u32 len | utf8` body that follows a non-Ok status.
fn read_msg_body(s: &mut TcpStream) -> String {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).unwrap();
    let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
    s.read_exact(&mut body).unwrap();
    String::from_utf8_lossy(&body).into_owned()
}

/// Assert a healthy round still works — the "no collateral damage" check
/// run after every fault scenario.
fn assert_healthy(addr: std::net::SocketAddr) {
    let mut c = NetClient::connect(addr).unwrap();
    c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
    let (logits, predicted) = c.classify("mock", &img(0.5)).unwrap();
    assert_eq!(logits[0], 2.0);
    assert_eq!(predicted, 0);
}

/// Run `NetServer::shutdown` on a separate thread and require it to finish
/// within `bound` — a drain that hangs fails the test instead of the suite.
/// Returns (elapsed, ingress metrics).
fn shutdown_within(
    server: NetServer,
    bound: Duration,
) -> (Duration, Arc<lqr::coordinator::metrics::NetMetrics>) {
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    std::thread::spawn(move || {
        let _ = tx.send(server.shutdown());
    });
    match rx.recv_timeout(bound) {
        Ok(m) => (t0.elapsed(), m),
        Err(_) => panic!("liveness violation: shutdown did not finish within {bound:?}"),
    }
}

// ------------------------------------------------------------- bad frames --

#[test]
fn oversized_n_floats_is_rejected_before_allocation() {
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();

    // The classic DoS: a 12-byte frame whose length prefix promises
    // u32::MAX floats (~16 GiB). The server must answer with a typed
    // BadFrame — without allocating — and close.
    let mut s = raw_connect(server.addr);
    let mut b = frame(b"mock", &[]);
    let n = b.len();
    b[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
    s.write_all(&b).unwrap();
    assert_eq!(read_status(&mut s), Some(WireStatus::BadFrame as u8));
    let msg = read_msg_body(&mut s);
    assert!(msg.contains("max_frame_bytes"), "{msg}");
    // Fatal reject: the server closes after the reply.
    assert_eq!(read_status(&mut s), None, "connection must close after BadFrame");

    // Meanwhile a well-behaved client is unaffected.
    assert_healthy(server.addr);
    let m = server.shutdown();
    assert_eq!(m.malformed.load(Ordering::Relaxed), 1);
}

#[test]
fn oversized_route_len_is_rejected() {
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
    let mut s = raw_connect(server.addr);
    s.write_all(&u32::MAX.to_le_bytes()).unwrap();
    assert_eq!(read_status(&mut s), Some(WireStatus::BadFrame as u8));
    let msg = read_msg_body(&mut s);
    assert!(msg.contains("max_route_len"), "{msg}");
    assert_eq!(read_status(&mut s), None);
    assert_healthy(server.addr);
    server.shutdown();
}

#[test]
fn random_garbage_never_takes_the_server_down() {
    let router = router_with(4, Duration::ZERO);
    let cfg = NetConfig { io_timeout: Duration::from_millis(300), ..Default::default() };
    let server = NetServer::serve_with("127.0.0.1:0", router, SPEC, cfg).unwrap();

    let mut rng = Rng::new(0x5EED_0008);
    for _ in 0..16 {
        let len = rng.below(256) as usize;
        let mut bytes = Vec::with_capacity(len + 8);
        while bytes.len() < len {
            bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
        }
        bytes.truncate(len);
        let mut s = raw_connect(server.addr);
        // The server's 300ms io_timeout closes each stalled connection; 2s
        // here is a generous bound, not the expected wait.
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let _ = s.write_all(&bytes);
        // Drain whatever the server replies until it closes or times out;
        // the only requirement is a typed reaction, not a specific one.
        let mut sink = [0u8; 256];
        while matches!(s.read(&mut sink), Ok(n) if n > 0) {}
        // Service must be intact after every hostile connection.
        assert_healthy(server.addr);
    }
    server.shutdown();
}

#[test]
fn truncated_frame_then_reconnect_works() {
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();

    // Send half a valid frame, then disconnect mid-frame.
    let full = frame(b"mock", &[1.0, 2.0, 3.0, 4.0]);
    let mut s = raw_connect(server.addr);
    s.write_all(&full[..full.len() / 2]).unwrap();
    drop(s);

    // The handler sees the mid-frame EOF as an I/O error and cleans up;
    // a reconnect gets a fresh, fully working connection.
    assert_healthy(server.addr);
    let m = server.shutdown();
    assert_eq!(m.active_conns.load(Ordering::Relaxed), 0);
}

// --------------------------------------------------- in-sync error replies --

#[test]
fn zero_length_and_non_utf8_routes_stay_in_sync() {
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();

    // Pipeline three frames before reading anything: empty route, non-UTF-8
    // route, then a valid request. The two rejects must each consume their
    // whole frame so the third parses cleanly on the same connection.
    let mut s = raw_connect(server.addr);
    s.write_all(&frame(b"", &[1.0; 4])).unwrap();
    s.write_all(&frame(&[0xFF, 0xFE, 0x80], &[1.0; 4])).unwrap();
    s.write_all(&frame(b"mock", &[1.0; 4])).unwrap();

    assert_eq!(read_status(&mut s), Some(WireStatus::BadRequest as u8));
    assert!(read_msg_body(&mut s).contains("empty route"));
    assert_eq!(read_status(&mut s), Some(WireStatus::BadRequest as u8));
    assert!(read_msg_body(&mut s).contains("UTF-8"));
    assert_eq!(read_status(&mut s), Some(WireStatus::Ok as u8), "stream desynced");

    let m = server.shutdown();
    assert_eq!(m.malformed.load(Ordering::Relaxed), 2);
    assert_eq!(m.frames.load(Ordering::Relaxed), 1);
}

#[test]
fn wrong_float_count_then_pipelined_request_succeeds() {
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
    let mut s = raw_connect(server.addr);
    // Wrong count (9 floats for a 4-float spec) followed immediately by a
    // correct frame — written back-to-back before any reply is read.
    s.write_all(&frame(b"mock", &[1.0; 9])).unwrap();
    s.write_all(&frame(b"mock", &[0.25; 4])).unwrap();
    assert_eq!(read_status(&mut s), Some(WireStatus::BadRequest as u8));
    assert!(read_msg_body(&mut s).contains("expected 4 floats"));
    assert_eq!(read_status(&mut s), Some(WireStatus::Ok as u8));
    server.shutdown();
}

// ------------------------------------------------------------------ stalls --

#[test]
fn slowloris_reader_is_timed_out() {
    let router = router_with(4, Duration::ZERO);
    let cfg = NetConfig { io_timeout: Duration::from_millis(100), ..Default::default() };
    let server = NetServer::serve_with("127.0.0.1:0", router, SPEC, cfg).unwrap();

    // Connect and send nothing: the read timeout must reclaim the handler.
    let mut s = raw_connect(server.addr);
    let deadline = Instant::now() + RECV_TIMEOUT;
    loop {
        if server.metrics().timed_out.load(Ordering::Relaxed) >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "stalled reader was never timed out");
        std::thread::sleep(Duration::from_millis(10));
    }
    // The server closed our socket (EOF), and service is unaffected.
    assert_eq!(read_status(&mut s), None);
    assert_healthy(server.addr);
    server.shutdown();
}

#[test]
fn stalled_writer_cannot_pin_a_handler() {
    // 4M classes make the Ok reply ~16 MiB — far past any socket buffer —
    // so a client that never reads stalls the server's write path.
    let router = router_with(1 << 22, Duration::ZERO);
    let cfg = NetConfig { io_timeout: Duration::from_millis(200), ..Default::default() };
    let server = NetServer::serve_with("127.0.0.1:0", router, SPEC, cfg).unwrap();

    let mut s = raw_connect(server.addr);
    s.write_all(&frame(b"mock", &[1.0; 4])).unwrap();
    // Never read. The write timeout must fire and free the handler.
    let deadline = Instant::now() + RECV_TIMEOUT;
    loop {
        if server.metrics().timed_out.load(Ordering::Relaxed) >= 1
            && server.active_connections() == 0
        {
            break;
        }
        assert!(Instant::now() < deadline, "stalled writer was never timed out");
        std::thread::sleep(Duration::from_millis(10));
    }
    // Shutdown stays prompt — nothing is pinned.
    shutdown_within(server, Duration::from_secs(5));
}

// ------------------------------------------------------------------- flood --

#[test]
fn connection_flood_is_shed_with_busy_and_slots_recycle() {
    let router = router_with(4, Duration::ZERO);
    let cfg = NetConfig { max_conns: 2, ..Default::default() };
    let server = NetServer::serve_with("127.0.0.1:0", router, SPEC, cfg).unwrap();

    // Two holders occupy the whole pool (a completed round proves each is
    // admitted, not just queued in the accept backlog).
    let mut holders: Vec<NetClient> = (0..2)
        .map(|_| {
            let mut c = NetClient::connect(server.addr).unwrap();
            c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
            c.classify("mock", &img(1.0)).unwrap();
            c
        })
        .collect();

    // Flood: every further connection gets a typed Busy reply, then close.
    for _ in 0..8 {
        let mut s = raw_connect(server.addr);
        assert_eq!(read_status(&mut s), Some(WireStatus::Busy as u8));
        assert!(read_msg_body(&mut s).contains("max_conns"));
        assert_eq!(read_status(&mut s), None, "shed connection must be closed");
    }
    assert!(server.metrics().rejected_conns.load(Ordering::Relaxed) >= 8);

    // Holders still work while the flood is being shed.
    for c in holders.iter_mut() {
        c.classify("mock", &img(0.5)).unwrap();
    }

    // Dropping a holder frees its slot for new clients.
    drop(holders);
    let deadline = Instant::now() + RECV_TIMEOUT;
    loop {
        if server.active_connections() == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "freed slots were never reclaimed");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_healthy(server.addr);
    server.shutdown();
}

// ---------------------------------------------------------------- shutdown --

#[test]
fn shutdown_under_load_resolves_every_in_flight_request() {
    // Slow backend so requests are genuinely in flight when shutdown hits.
    let router = router_with(4, Duration::from_millis(300));
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
    let addr = server.addr;

    let clients: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = NetClient::connect(addr).unwrap();
                c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
                c.classify("mock", &img(i as f32 * 0.1))
            })
        })
        .collect();

    // Let every request reach its handler (the 300ms backend is the only
    // slow stage), then shut down while they are all mid-inference.
    std::thread::sleep(Duration::from_millis(150));
    let (elapsed, _) = shutdown_within(server, Duration::from_secs(8));
    // Drain, not abort: shutdown waited for the in-flight replies...
    assert!(elapsed < Duration::from_secs(6), "drain took {elapsed:?}");

    // ...and every client got its answer.
    for (i, h) in clients.into_iter().enumerate() {
        let (logits, _) = h.join().unwrap().unwrap();
        assert!((logits[0] - 4.0 * (i as f32 * 0.1)).abs() < 1e-5);
    }
}

#[test]
fn shutdown_with_idle_connections_is_prompt() {
    let router = router_with(4, Duration::ZERO);
    // Long io_timeout: promptness must come from the drain logic
    // (half-close waking idle readers), not from timeouts expiring.
    let cfg = NetConfig { io_timeout: Duration::from_secs(60), ..Default::default() };
    let server = NetServer::serve_with("127.0.0.1:0", router, SPEC, cfg).unwrap();

    let mut idle: Vec<NetClient> = (0..3)
        .map(|_| {
            let mut c = NetClient::connect(server.addr).unwrap();
            c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
            c.classify("mock", &img(1.0)).unwrap();
            c
        })
        .collect();
    let (elapsed, metrics) = shutdown_within(server, Duration::from_secs(5));
    assert!(elapsed < Duration::from_secs(3), "idle drain took {elapsed:?}");
    assert_eq!(metrics.active_conns.load(Ordering::Relaxed), 0);
    // Idle clients observe a clean close on their next round.
    for c in idle.iter_mut() {
        assert!(c.classify("mock", &img(1.0)).is_err());
    }
}

#[test]
fn shutdown_is_prompt_with_no_connection_ever_made() {
    // No client ever connects: the accept loop is parked in its idle wait
    // (the same sliced, stop-aware wait its error backoff uses). Shutdown
    // must interrupt that wait, not ride it out.
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    let (elapsed, metrics) = shutdown_within(server, Duration::from_secs(5));
    assert!(elapsed < Duration::from_secs(2), "idle accept loop took {elapsed:?} to stop");
    assert_eq!(metrics.active_conns.load(Ordering::Relaxed), 0);
}

// ------------------------------------------------------------------ health --

#[test]
fn health_reports_pool_and_queue_state() {
    let router = router_with(4, Duration::ZERO);
    let cfg = NetConfig { max_conns: 7, ..Default::default() };
    let server = NetServer::serve_with("127.0.0.1:0", router, SPEC, cfg).unwrap();
    let mut c = NetClient::connect(server.addr).unwrap();
    c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
    let report = c.health().unwrap();
    assert!(report.contains("ready=true"), "{report}");
    assert!(report.contains("mock depth=0/1024 up"), "{report}");
    assert!(report.contains("active_conns=1"), "{report}");
    // Self-healing counters ride on every route line (zero on a healthy
    // pool) — scrapers watch these to catch wedged-worker incidents.
    assert!(report.contains("watchdog_kills=0 inflight_expired=0"), "{report}");
    server.shutdown();
}

#[test]
fn health_appends_route_status_callback() {
    // Routes registered with a status callback (shared-engine routes report
    // pre-warm state) surface it in the wire health report.
    let mut r = Router::new();
    r.add_route_with_status(
        "mock",
        CoordinatorConfig::default(),
        Box::new(|| {
            Ok(Box::new(MockBackend {
                classes: 4,
                delay: Duration::ZERO,
                calls: Arc::new(AtomicU64::new(0)),
            }) as Box<dyn Backend>)
        }),
        Box::new(|| "warmed panels=6 panel_bytes=1234".into()),
    )
    .unwrap();
    let server = NetServer::serve("127.0.0.1:0", Arc::new(r), SPEC).unwrap();
    let mut c = NetClient::connect(server.addr).unwrap();
    c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
    let report = c.health().unwrap();
    assert!(report.contains("mock depth=0/1024 up [warmed panels=6 panel_bytes=1234]"), "{report}");
    server.shutdown();
}

// ------------------------------------------------------- golden wire bytes --
// The zero-copy rewrite (pooled buffers, gathered single-write replies)
// must not change a single byte on the wire. These pins hand-build frames
// and compare whole replies byte-for-byte, across pipelined rounds so the
// reused buffers are exercised.

/// The exact expected Ok reply for `classes` logits.
fn ok_reply_bytes(logits: &[f32], predicted: u32) -> Vec<u8> {
    let mut b = vec![WireStatus::Ok as u8];
    b.extend_from_slice(&(logits.len() as u32).to_le_bytes());
    for v in logits {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b.extend_from_slice(&predicted.to_le_bytes());
    b
}

/// The exact expected non-Ok reply (`status | u32 len | utf8`).
fn msg_reply_bytes(status: WireStatus, msg: &str) -> Vec<u8> {
    let mut b = vec![status as u8];
    b.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    b.extend_from_slice(msg.as_bytes());
    b
}

#[test]
fn server_reply_bytes_are_bit_identical_across_pooled_rounds() {
    let router = router_with(4, Duration::ZERO);
    let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
    let mut s = raw_connect(server.addr);

    // Round 1: hand-built request, whole reply compared byte-for-byte.
    s.write_all(&frame(b"mock", &[0.25; 4])).unwrap();
    let expect = ok_reply_bytes(&[1.0, 0.0, 0.0, 0.0], 0);
    let mut got = vec![0u8; expect.len()];
    s.read_exact(&mut got).unwrap();
    assert_eq!(got, expect, "Ok reply bytes changed");

    // Round 2 on the same connection: the handler's recycled buffers are in
    // play now — bytes must still be identical for different values.
    s.write_all(&frame(b"mock", &[0.5, 1.5, -2.0, 0.0])).unwrap();
    let expect = ok_reply_bytes(&[0.0, 0.0, 0.0, 0.0], 0);
    let mut got = vec![0u8; expect.len()];
    s.read_exact(&mut got).unwrap();
    assert_eq!(got[..5], expect[..5], "Ok header bytes changed");
    // Logits are the mock's row sum: 0.5+1.5-2.0+0.0 = 0.0 in slot 0.
    assert_eq!(got[5..9], 0.0f32.to_le_bytes(), "logit encoding changed");

    // Round 3: a typed error reply is also byte-exact (and in sync).
    s.write_all(&frame(b"nope", &[0.25; 4])).unwrap();
    let expect = msg_reply_bytes(WireStatus::NoRoute, "no route nope");
    let mut got = vec![0u8; expect.len()];
    s.read_exact(&mut got).unwrap();
    assert_eq!(got, expect, "error reply bytes changed");

    // Round 4: still in sync after the error — Ok again.
    s.write_all(&frame(b"mock", &[0.25; 4])).unwrap();
    assert_eq!(read_status(&mut s), Some(WireStatus::Ok as u8));
    server.shutdown();
}

#[test]
fn client_request_bytes_are_bit_identical() {
    // A raw listener stands in for the server: capture exactly what
    // NetClient writes and compare against the hand-built frame, then feed
    // a hand-built reply and require an exact decode.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let logits = [3.5f32, -1.0, 0.25, 9.0];
    let reply = ok_reply_bytes(&logits, 3);
    let expect_untagged = frame(b"mock", &[0.25; 4]);
    // Lane-tagged frame: LANE_FLAG on route_len, lane byte 1 (bulk).
    let mut expect_tagged = Vec::new();
    expect_tagged.extend_from_slice(&(4u32 | 0x8000_0000).to_le_bytes());
    expect_tagged.extend_from_slice(b"mock");
    expect_tagged.push(1);
    expect_tagged.extend_from_slice(&4u32.to_le_bytes());
    for _ in 0..4 {
        expect_tagged.extend_from_slice(&0.25f32.to_le_bytes());
    }

    let reply2 = reply.clone();
    let (e1, e2) = (expect_untagged.clone(), expect_tagged.clone());
    let srv = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        s.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
        let mut got = vec![0u8; e1.len()];
        s.read_exact(&mut got).unwrap();
        assert_eq!(got, e1, "untagged request bytes changed");
        s.write_all(&reply2).unwrap();
        let mut got = vec![0u8; e2.len()];
        s.read_exact(&mut got).unwrap();
        assert_eq!(got, e2, "lane-tagged request bytes changed");
        s.write_all(&reply2).unwrap();
    });

    let mut c = NetClient::connect(addr).unwrap();
    c.set_io_timeout(Some(RECV_TIMEOUT)).unwrap();
    let (got_logits, predicted) = c.classify("mock", &img(0.25)).unwrap();
    assert_eq!(got_logits, logits.to_vec());
    assert_eq!(predicted, 3);
    let (got_logits, predicted) = c
        .classify_with_priority("mock", &img(0.25), lqr::coordinator::Priority::Bulk)
        .unwrap();
    assert_eq!(got_logits, logits.to_vec());
    assert_eq!(predicted, 3);
    srv.join().unwrap();
}
