//! Accuracy-protocol smoke tests over the real weights (requires artifacts):
//! small-subset versions of Tables 1–2 / Figs. 9–10, checking the paper's
//! qualitative shape so regressions in the pipeline are caught in `cargo
//! test` without running the full benches.

use lqr::dataset::Dataset;
use lqr::eval::evaluate;
use lqr::nn::forward::Scheme;
use lqr::nn::{Arch, Engine, Precision};
use lqr::quant::RegionSpec;

fn setup(model: &str) -> Option<(Engine, Dataset)> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    let engine = Engine::from_npz(
        Arch::by_name(model).unwrap(),
        format!("{dir}/weights_{model}.npz"),
    )
    .unwrap();
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap().take(256);
    Some((engine, ds))
}

#[test]
fn table1_shape_8bit_lq_no_drop() {
    let Some((engine, ds)) = setup("minialexnet") else { return };
    let f32_acc = evaluate(&engine, &ds, Precision::F32, 32, None);
    let lq8_acc = evaluate(&engine, &ds, Precision::lq(8), 32, None);
    assert!(f32_acc.top1 > 0.95, "baseline top-1 {}", f32_acc.top1);
    assert!(
        (f32_acc.top1 - lq8_acc.top1).abs() <= 0.02,
        "8-bit LQ should not drop: f32={} lq8={}",
        f32_acc.top1,
        lq8_acc.top1
    );
}

#[test]
fn table2_shape_lq_beats_dq_at_2bit() {
    let Some((engine, ds)) = setup("minivgg") else { return };
    let lq2 = evaluate(&engine, &ds, Precision::lq(2), 32, None);
    let dq2 = evaluate(&engine, &ds, Precision::dq(2), 32, None);
    assert!(
        lq2.top1 > dq2.top1 + 0.05,
        "LQ must clearly beat DQ at 2-bit: lq={} dq={}",
        lq2.top1,
        dq2.top1
    );
}

#[test]
fn fig10_shape_smaller_region_helps_at_2bit() {
    let Some((engine, ds)) = setup("minivgg") else { return };
    let kernel_sized = evaluate(&engine, &ds, Precision::lq(2), 32, None);
    let small = Precision::Quant {
        scheme: Scheme::Lq,
        bits_a: 2,
        bits_w: 8,
        region: RegionSpec::Size(9),
        lut: false,
    };
    let small_acc = evaluate(&engine, &ds, small, 32, None);
    assert!(
        small_acc.top1 >= kernel_sized.top1,
        "smaller regions should not hurt at 2-bit: small={} kernel={}",
        small_acc.top1,
        kernel_sized.top1
    );
}

#[test]
fn lut_path_accuracy_identical() {
    let Some((engine, ds)) = setup("minialexnet") else { return };
    let ds = ds.take(64);
    let no_lut = Precision::Quant {
        scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::PerRow, lut: false,
    };
    let with_lut = Precision::Quant {
        scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::PerRow, lut: true,
    };
    let a = evaluate(&engine, &ds, no_lut, 32, None);
    let b = evaluate(&engine, &ds, with_lut, 32, None);
    assert_eq!(a.top1, b.top1, "LUT changes accuracy");
    assert_eq!(a.top5, b.top5);
}
