//! Failure injection: the liveness invariant under injected faults.
//!
//! Every submitted request must resolve to exactly one typed outcome —
//! success, `BackendFailed`, `Shed`, `DeadlineExceeded`, `ShapeMismatch`,
//! `ShuttingDown`, or `NoWorkers` — within a bounded time. No test here
//! relies on `RecvError` to detect failure, and none can hang: all receives
//! go through `recv_timeout`.
//!
//! Scenarios: flaky backend, poison request inside a healthy batch, worker
//! death at init and mid-stream (supervisor restarts), pool death into the
//! fail-fast state, deadline expiry under a stalled worker, drop-oldest
//! load shedding, and shutdown under load.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::Result;
use lqr::coordinator::backend::{Backend, MockBackend};
use lqr::coordinator::{
    Coordinator, CoordinatorConfig, InferError, InferReply, ShedPolicy, ShedReason, SubmitError,
};
use lqr::tensor::Tensor;

const RECV_TIMEOUT: Duration = Duration::from_secs(10);

fn img(v: f32) -> Tensor {
    Tensor::filled(&[1, 1, 2, 2], v)
}

fn mock(classes: usize, delay: Duration) -> MockBackend {
    MockBackend { classes, delay, calls: Arc::new(AtomicU64::new(0)) }
}

/// Resolve a receiver within the global timeout; a timeout is a liveness
/// bug, a disconnect is a reply-protocol bug — both fail loudly.
fn resolve(rx: mpsc::Receiver<InferReply>) -> InferReply {
    match rx.recv_timeout(RECV_TIMEOUT) {
        Ok(reply) => reply,
        Err(mpsc::RecvTimeoutError::Timeout) => panic!("liveness violation: request hung"),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            panic!("protocol violation: sender dropped without a typed reply")
        }
    }
}

/// Backend that fails every `fail_every`-th call.
struct FlakyBackend {
    inner: MockBackend,
    calls: u64,
    fail_every: u64,
}

impl Backend for FlakyBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.calls += 1;
        if self.calls % self.fail_every == 0 {
            // Failures cost the same wall-clock as successes would, so a
            // fail-everything backend can't starve a healthy peer worker.
            if !self.inner.delay.is_zero() {
                std::thread::sleep(self.inner.delay);
            }
            anyhow::bail!("injected failure on call {}", self.calls);
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "flaky-mock".into()
    }
}

/// Backend that errors on any batch containing a poison row (pixel sum
/// >= 1000) and otherwise behaves like the mock.
struct PoisonSensitive {
    inner: MockBackend,
}

impl Backend for PoisonSensitive {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        let per = batch.len() / n;
        for i in 0..n {
            let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
            if s >= 1000.0 {
                anyhow::bail!("poison row {i}");
            }
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "poison-sensitive".into()
    }
}

/// Backend that panics on any batch containing a magic row.
struct PanicOnMagic {
    inner: MockBackend,
}

impl Backend for PanicOnMagic {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        let per = batch.len() / n;
        for i in 0..n {
            let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
            if s >= 1000.0 {
                panic!("magic row {i} detonated");
            }
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "panic-on-magic".into()
    }
}

#[test]
fn failed_batches_get_typed_errors_not_disconnects() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1, // one request per batch -> deterministic failure mapping
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        retry_budget: 1, // single-request batches: no bisection to retry
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| {
            Ok(Box::new(FlakyBackend {
                inner: mock(4, Duration::ZERO),
                calls: 0,
                fail_every: 3,
            }) as Box<dyn Backend>)
        }),
    )
    .unwrap();

    let n = 30;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut ok = 0;
    let mut failed = 0;
    for rx in rxs {
        match resolve(rx) {
            Ok(_) => ok += 1,
            Err(InferError::BackendFailed { message }) => {
                assert!(message.contains("injected failure"), "{message}");
                failed += 1;
            }
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert_eq!(ok + failed, n);
    assert_eq!(failed, n / 3, "every 3rd single-request batch fails");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
    assert_eq!(m.failed.load(Ordering::Relaxed), failed as u64, "failed work must be visible");
}

#[test]
fn poison_request_is_isolated_neighbors_complete() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(500), // wait for the full batch
        queue_capacity: 256,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(PoisonSensitive { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)),
    )
    .unwrap();

    // 8 requests co-batched; index 5 is poison (4 pixels of 500 = sum 2000).
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            let v = if i == 5 { 500.0 } else { i as f32 };
            coord.submit(img(v)).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        match resolve(rx) {
            Ok(resp) => {
                assert_ne!(i, 5, "poison request must not succeed");
                assert_eq!(resp.logits[0], 4.0 * i as f32);
            }
            Err(InferError::BackendFailed { .. }) => {
                assert_eq!(i, 5, "only the poison request may fail");
            }
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), 7);
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
    assert!(
        m.batches.load(Ordering::Relaxed) > 1,
        "bisection must have retried sub-batches"
    );
}

#[test]
fn all_workers_dead_at_init_fails_start_not_first_infer() {
    let cfg = CoordinatorConfig {
        workers: 2,
        restart_limit: 1, // fail construction quickly
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = Coordinator::start(
        cfg,
        Box::new(|| -> Result<Box<dyn Backend>> { anyhow::bail!("backend init exploded") }),
    );
    let err = result.err().expect("start must fail when no backend initializes");
    assert!(format!("{err:#}").contains("no worker backend initialized"), "{err:#}");
    assert!(t0.elapsed() < RECV_TIMEOUT, "start must fail fast, not hang");
}

#[test]
fn transient_init_failure_is_restarted_through() {
    // First two factory calls fail, the third succeeds: the supervisor's
    // restart loop must bring the pool up and serve traffic.
    let attempts = Arc::new(AtomicU64::new(0));
    let a2 = Arc::clone(&attempts);
    let cfg = CoordinatorConfig {
        workers: 1,
        restart_limit: 5,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if a2.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient init failure");
            }
            Ok(Box::new(mock(4, Duration::ZERO)) as Box<dyn Backend>)
        }),
    )
    .unwrap();
    let resp = coord.infer(img(1.0)).unwrap();
    assert_eq!(resp.logits[0], 4.0);
    let m = coord.shutdown();
    assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
    assert!(attempts.load(Ordering::SeqCst) >= 3);
}

#[test]
fn worker_panic_mid_stream_recovers_with_typed_replies() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        restart_limit: 5,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(PanicOnMagic { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)),
    )
    .unwrap();

    // Healthy request works.
    assert!(resolve(coord.submit(img(1.0)).unwrap()).is_ok());
    // Magic request detonates the backend: typed reply, not a hang.
    match resolve(coord.submit(img(500.0)).unwrap()) {
        Err(InferError::BackendFailed { message }) => {
            assert!(message.contains("panicked"), "{message}");
        }
        other => panic!("expected BackendFailed after panic, got {other:?}"),
    }
    // Supervisor replaced the worker: traffic flows again.
    let resp = resolve(coord.submit(img(2.0)).unwrap()).expect("pool must recover after restart");
    assert_eq!(resp.logits[0], 8.0);
    let m = coord.shutdown();
    assert!(m.worker_restarts.load(Ordering::Relaxed) >= 1, "restart must be counted");
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
}

#[test]
fn dead_pool_flips_to_fail_fast_no_hangs() {
    // Factory succeeds once with a backend that panics on everything, then
    // fails forever: after the restart budget burns down, the pool is dead
    // — queued requests get NoWorkers and submits refuse fast.
    let attempts = Arc::new(AtomicU64::new(0));
    let a2 = Arc::clone(&attempts);
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        restart_limit: 2,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if a2.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Box::new(PanicOnMagic { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)
            } else {
                anyhow::bail!("backend gone")
            }
        }),
    )
    .unwrap();

    // Detonate the only worker; replacement inits fail until the budget is
    // exhausted and the supervisor fails the queue.
    let rx_boom = coord.submit(img(500.0)).unwrap();
    let rx_queued = coord.submit(img(1.0)).unwrap();
    assert!(matches!(resolve(rx_boom), Err(InferError::BackendFailed { .. })));
    match resolve(rx_queued) {
        Err(InferError::NoWorkers) => {}
        other => panic!("queued request on a dead pool must get NoWorkers, got {other:?}"),
    }
    // Fail-fast state: submit refuses immediately once the pool is dead.
    let t0 = std::time::Instant::now();
    while !coord.is_failed() {
        assert!(t0.elapsed() < RECV_TIMEOUT, "pool never entered fail-fast state");
        std::thread::sleep(Duration::from_millis(1));
    }
    match coord.submit(img(2.0)) {
        Err(SubmitError::NoWorkers) => {}
        other => panic!("expected NoWorkers from submit, got {other:?}"),
    }
    // infer on a dead pool errors fast instead of blocking forever.
    let t0 = std::time::Instant::now();
    assert!(coord.infer(img(3.0)).is_err());
    assert!(t0.elapsed() < Duration::from_secs(1), "infer must not block on a dead pool");
    let m = coord.shutdown();
    assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
}

#[test]
fn deadlines_expire_under_stalled_worker() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        ..Default::default()
    };
    // 300ms backend stalls the single worker.
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(300))) as Box<dyn Backend>)),
    )
    .unwrap();
    let rx_head = coord.submit(img(1.0)).unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            coord
                .submit_with_deadline(img(10.0 + i as f32), Some(Duration::from_millis(20)))
                .unwrap()
        })
        .collect();
    assert!(resolve(rx_head).is_ok(), "head-of-line request executes normally");
    for rx in rxs {
        match resolve(rx) {
            Err(InferError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.expired.load(Ordering::Relaxed), 3);
    assert_eq!(m.completed.load(Ordering::Relaxed), 1);
}

#[test]
fn drop_oldest_sheds_stale_keeps_fresh() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 2,
        shed: ShedPolicy::DropOldest,
        ..Default::default()
    };
    // Slow backend so the queue saturates while the worker is busy.
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(50))) as Box<dyn Backend>)),
    )
    .unwrap();
    let rxs: Vec<_> = (0..8).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut shed = 0;
    let mut ok = 0;
    let mut last_ok = None;
    for (i, rx) in rxs.into_iter().enumerate() {
        match resolve(rx) {
            Ok(_) => {
                ok += 1;
                last_ok = Some(i);
            }
            Err(InferError::Shed { reason: ShedReason::DropOldest }) => shed += 1,
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert_eq!(ok + shed, 8, "every request resolves exactly once");
    assert!(shed > 0, "overload must shed under drop-oldest");
    assert_eq!(last_ok, Some(7), "drop-oldest favors the freshest request");
    let m = coord.shutdown();
    assert_eq!(m.shed.load(Ordering::Relaxed), shed as u64);
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
}

#[test]
fn shutdown_under_load_resolves_every_receiver() {
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        queue_capacity: 1024,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(3))) as Box<dyn Backend>)),
    )
    .unwrap();
    let rxs: Vec<_> = (0..200).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let m = coord.shutdown();
    let mut ok = 0;
    let mut shutdown_replies = 0;
    for rx in rxs {
        match resolve(rx) {
            Ok(_) => ok += 1,
            Err(InferError::ShuttingDown) => shutdown_replies += 1,
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert_eq!(ok + shutdown_replies, 200, "every outstanding receiver resolves");
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
}

#[test]
fn mixed_shape_request_gets_typed_error_neighbors_survive() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 4,
        max_wait: Duration::from_millis(500),
        queue_capacity: 256,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::ZERO)) as Box<dyn Backend>)),
    )
    .unwrap();
    let rx0 = coord.submit(img(0.0)).unwrap();
    let rx1 = coord.submit(img(1.0)).unwrap();
    let rx_odd = coord.submit(Tensor::filled(&[1, 1, 3, 3], 1.0)).unwrap();
    let rx3 = coord.submit(img(3.0)).unwrap();
    for (rx, v) in [(rx0, 0.0), (rx1, 4.0), (rx3, 12.0)] {
        let resp = resolve(rx).expect("same-shape request must survive the odd one");
        assert_eq!(resp.logits[0], v);
    }
    match resolve(rx_odd) {
        Err(InferError::ShapeMismatch { expected, got }) => {
            assert_eq!(expected, vec![1, 1, 2, 2]);
            assert_eq!(got, vec![1, 1, 3, 3]);
        }
        other => panic!("expected ShapeMismatch, got {other:?}"),
    }
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), 3);
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
}

#[test]
fn healthy_worker_carries_flaky_peer() {
    // Two workers: one whose backend always fails, one healthy. Every
    // request resolves typed — and a majority succeed because the healthy
    // worker keeps draining (failed singles are not retried: batch of 1).
    let flaky_first = Arc::new(AtomicU64::new(0));
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        retry_budget: 1,
        restart_limit: 0, // errors (not crashes) never kill workers anyway
        ..Default::default()
    };
    let ff = Arc::clone(&flaky_first);
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if ff.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Box::new(FlakyBackend {
                    // 1ms per (failed) call: slower than the healthy peer,
                    // so the flaky worker cannot drain the whole stream.
                    inner: mock(4, Duration::from_millis(1)),
                    calls: 0,
                    fail_every: 1, // always fails
                }) as Box<dyn Backend>)
            } else {
                Ok(Box::new(mock(4, Duration::from_micros(100))) as Box<dyn Backend>)
            }
        }),
    )
    .unwrap();
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut ok = 0;
    for rx in rxs {
        match resolve(rx) {
            Ok(_) => ok += 1,
            Err(InferError::BackendFailed { .. }) => {}
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert!(ok > 0, "healthy worker should complete some requests");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
    assert_eq!(m.failed.load(Ordering::Relaxed), (n - ok) as u64);
}

#[test]
fn backpressure_then_recovery_keeps_serving() {
    // Reject-newest under a saturated queue: accepted requests all resolve,
    // rejected ones are visible in metrics, and the stream keeps flowing.
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 2,
        max_wait: Duration::from_millis(5),
        queue_capacity: 2,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(2, Duration::from_millis(20))) as Box<dyn Backend>)),
    )
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..20 {
        match coord.submit(img(i as f32)) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::QueueFull(_)) => {
                rejected += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("unexpected submit error {e}"),
        }
    }
    assert!(rejected > 0, "expected backpressure");
    for rx in accepted {
        assert!(resolve(rx).is_ok());
    }
    let m = coord.shutdown();
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected as u64);
    assert_eq!(m.shed.load(Ordering::Relaxed), rejected as u64);
}
