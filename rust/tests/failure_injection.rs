//! Failure injection: the liveness invariant under injected faults.
//!
//! Every submitted request must resolve to exactly one typed outcome —
//! success, `BackendFailed`, `Shed`, `DeadlineExceeded`, `ShapeMismatch`,
//! `ShuttingDown`, or `NoWorkers` — within a bounded time. No test here
//! relies on `RecvError` to detect failure, and none can hang: all receives
//! go through `recv_timeout`.
//!
//! Scenarios: flaky backend, poison request inside a healthy batch, worker
//! death at init and mid-stream (supervisor restarts), pool death into the
//! fail-fast state, deadline expiry under a stalled worker, drop-oldest
//! load shedding, and shutdown under load.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;
use lqr::coordinator::backend::{Backend, MockBackend};
use lqr::coordinator::batcher::{BatchPolicy, BatchQueue};
use lqr::coordinator::metrics::Metrics;
use lqr::coordinator::request::InferRequest;
use lqr::coordinator::{
    Coordinator, CoordinatorConfig, InferError, InferReply, Priority, ShedPolicy, ShedReason,
    SubmitError,
};
use lqr::tensor::Tensor;

const RECV_TIMEOUT: Duration = Duration::from_secs(10);

fn img(v: f32) -> Tensor {
    Tensor::filled(&[1, 1, 2, 2], v)
}

fn mock(classes: usize, delay: Duration) -> MockBackend {
    MockBackend { classes, delay, calls: Arc::new(AtomicU64::new(0)) }
}

/// Resolve a receiver within the global timeout; a timeout is a liveness
/// bug, a disconnect is a reply-protocol bug — both fail loudly.
fn resolve(rx: mpsc::Receiver<InferReply>) -> InferReply {
    match rx.recv_timeout(RECV_TIMEOUT) {
        Ok(reply) => reply,
        Err(mpsc::RecvTimeoutError::Timeout) => panic!("liveness violation: request hung"),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            panic!("protocol violation: sender dropped without a typed reply")
        }
    }
}

/// Backend that fails every `fail_every`-th call.
struct FlakyBackend {
    inner: MockBackend,
    calls: u64,
    fail_every: u64,
}

impl Backend for FlakyBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.calls += 1;
        if self.calls % self.fail_every == 0 {
            // Failures cost the same wall-clock as successes would, so a
            // fail-everything backend can't starve a healthy peer worker.
            if !self.inner.delay.is_zero() {
                std::thread::sleep(self.inner.delay);
            }
            anyhow::bail!("injected failure on call {}", self.calls);
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "flaky-mock".into()
    }
}

/// Backend that errors on any batch containing a poison row (pixel sum
/// >= 1000) and otherwise behaves like the mock.
struct PoisonSensitive {
    inner: MockBackend,
}

impl Backend for PoisonSensitive {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        let per = batch.len() / n;
        for i in 0..n {
            let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
            if s >= 1000.0 {
                anyhow::bail!("poison row {i}");
            }
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "poison-sensitive".into()
    }
}

/// Backend that panics on any batch containing a magic row.
struct PanicOnMagic {
    inner: MockBackend,
}

impl Backend for PanicOnMagic {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        let per = batch.len() / n;
        for i in 0..n {
            let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
            if s >= 1000.0 {
                panic!("magic row {i} detonated");
            }
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "panic-on-magic".into()
    }
}

#[test]
fn failed_batches_get_typed_errors_not_disconnects() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1, // one request per batch -> deterministic failure mapping
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        retry_budget: 1, // single-request batches: no bisection to retry
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| {
            Ok(Box::new(FlakyBackend {
                inner: mock(4, Duration::ZERO),
                calls: 0,
                fail_every: 3,
            }) as Box<dyn Backend>)
        }),
    )
    .unwrap();

    let n = 30;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut ok = 0;
    let mut failed = 0;
    for rx in rxs {
        match resolve(rx) {
            Ok(_) => ok += 1,
            Err(InferError::BackendFailed { message }) => {
                assert!(message.contains("injected failure"), "{message}");
                failed += 1;
            }
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert_eq!(ok + failed, n);
    assert_eq!(failed, n / 3, "every 3rd single-request batch fails");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
    assert_eq!(m.failed.load(Ordering::Relaxed), failed as u64, "failed work must be visible");
}

#[test]
fn poison_request_is_isolated_neighbors_complete() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(500), // wait for the full batch
        queue_capacity: 256,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(PoisonSensitive { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)),
    )
    .unwrap();

    // 8 requests co-batched; index 5 is poison (4 pixels of 500 = sum 2000).
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            let v = if i == 5 { 500.0 } else { i as f32 };
            coord.submit(img(v)).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        match resolve(rx) {
            Ok(resp) => {
                assert_ne!(i, 5, "poison request must not succeed");
                assert_eq!(resp.logits[0], 4.0 * i as f32);
            }
            Err(InferError::BackendFailed { .. }) => {
                assert_eq!(i, 5, "only the poison request may fail");
            }
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), 7);
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
    assert!(
        m.batches.load(Ordering::Relaxed) > 1,
        "bisection must have retried sub-batches"
    );
}

#[test]
fn all_workers_dead_at_init_fails_start_not_first_infer() {
    let cfg = CoordinatorConfig {
        workers: 2,
        restart_limit: 1, // fail construction quickly
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = Coordinator::start(
        cfg,
        Box::new(|| -> Result<Box<dyn Backend>> { anyhow::bail!("backend init exploded") }),
    );
    let err = result.err().expect("start must fail when no backend initializes");
    assert!(format!("{err:#}").contains("no worker backend initialized"), "{err:#}");
    assert!(t0.elapsed() < RECV_TIMEOUT, "start must fail fast, not hang");
}

#[test]
fn transient_init_failure_is_restarted_through() {
    // First two factory calls fail, the third succeeds: the supervisor's
    // restart loop must bring the pool up and serve traffic.
    let attempts = Arc::new(AtomicU64::new(0));
    let a2 = Arc::clone(&attempts);
    let cfg = CoordinatorConfig {
        workers: 1,
        restart_limit: 5,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if a2.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient init failure");
            }
            Ok(Box::new(mock(4, Duration::ZERO)) as Box<dyn Backend>)
        }),
    )
    .unwrap();
    let resp = coord.infer(img(1.0)).unwrap();
    assert_eq!(resp.logits[0], 4.0);
    let m = coord.shutdown();
    assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
    assert!(attempts.load(Ordering::SeqCst) >= 3);
}

#[test]
fn worker_panic_mid_stream_recovers_with_typed_replies() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        restart_limit: 5,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(PanicOnMagic { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)),
    )
    .unwrap();

    // Healthy request works.
    assert!(resolve(coord.submit(img(1.0)).unwrap()).is_ok());
    // Magic request detonates the backend: typed reply, not a hang.
    match resolve(coord.submit(img(500.0)).unwrap()) {
        Err(InferError::BackendFailed { message }) => {
            assert!(message.contains("panicked"), "{message}");
        }
        other => panic!("expected BackendFailed after panic, got {other:?}"),
    }
    // Supervisor replaced the worker: traffic flows again.
    let resp = resolve(coord.submit(img(2.0)).unwrap()).expect("pool must recover after restart");
    assert_eq!(resp.logits[0], 8.0);
    let m = coord.shutdown();
    assert!(m.worker_restarts.load(Ordering::Relaxed) >= 1, "restart must be counted");
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
}

#[test]
fn dead_pool_flips_to_fail_fast_no_hangs() {
    // Factory succeeds once with a backend that panics on everything, then
    // fails forever: after the restart budget burns down, the pool is dead
    // — queued requests get NoWorkers and submits refuse fast.
    let attempts = Arc::new(AtomicU64::new(0));
    let a2 = Arc::clone(&attempts);
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        restart_limit: 2,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if a2.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Box::new(PanicOnMagic { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)
            } else {
                anyhow::bail!("backend gone")
            }
        }),
    )
    .unwrap();

    // Detonate the only worker; replacement inits fail until the budget is
    // exhausted and the supervisor fails the queue.
    let rx_boom = coord.submit(img(500.0)).unwrap();
    let rx_queued = coord.submit(img(1.0)).unwrap();
    assert!(matches!(resolve(rx_boom), Err(InferError::BackendFailed { .. })));
    match resolve(rx_queued) {
        Err(InferError::NoWorkers) => {}
        other => panic!("queued request on a dead pool must get NoWorkers, got {other:?}"),
    }
    // Fail-fast state: submit refuses immediately once the pool is dead.
    let t0 = std::time::Instant::now();
    while !coord.is_failed() {
        assert!(t0.elapsed() < RECV_TIMEOUT, "pool never entered fail-fast state");
        std::thread::sleep(Duration::from_millis(1));
    }
    match coord.submit(img(2.0)) {
        Err(SubmitError::NoWorkers) => {}
        other => panic!("expected NoWorkers from submit, got {other:?}"),
    }
    // infer on a dead pool errors fast instead of blocking forever.
    let t0 = std::time::Instant::now();
    assert!(coord.infer(img(3.0)).is_err());
    assert!(t0.elapsed() < Duration::from_secs(1), "infer must not block on a dead pool");
    let m = coord.shutdown();
    assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
}

#[test]
fn shutdown_interrupts_restart_backoff() {
    // Detonate the only worker under a restart backoff far longer than any
    // test budget, then shut down while the supervisor is mid-backoff. The
    // interruptible wait must abandon the sleep immediately — a shutdown
    // that blocks for `restart_backoff` is a liveness bug.
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 64,
        restart_limit: 5,
        restart_backoff: Duration::from_secs(30),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(PanicOnMagic { inner: mock(4, Duration::ZERO) }) as Box<dyn Backend>)),
    )
    .unwrap();
    assert!(resolve(coord.submit(img(1.0)).unwrap()).is_ok());
    // The detonation reply resolves before the replacement spawns, so right
    // after it the supervisor is inside its 30s backoff window.
    assert!(matches!(
        resolve(coord.submit(img(500.0)).unwrap()),
        Err(InferError::BackendFailed { .. })
    ));
    std::thread::sleep(Duration::from_millis(20));
    let t0 = Instant::now();
    let m = coord.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown must interrupt the restart backoff, took {:?}",
        t0.elapsed()
    );
    assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
}

#[test]
fn deadlines_expire_under_stalled_worker() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        ..Default::default()
    };
    // 300ms backend stalls the single worker.
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(300))) as Box<dyn Backend>)),
    )
    .unwrap();
    let rx_head = coord.submit(img(1.0)).unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            coord
                .submit_with_deadline(img(10.0 + i as f32), Some(Duration::from_millis(20)))
                .unwrap()
        })
        .collect();
    assert!(resolve(rx_head).is_ok(), "head-of-line request executes normally");
    for rx in rxs {
        match resolve(rx) {
            Err(InferError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.expired.load(Ordering::Relaxed), 3);
    assert_eq!(m.completed.load(Ordering::Relaxed), 1);
}

#[test]
fn drop_oldest_sheds_stale_keeps_fresh() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 2,
        shed: ShedPolicy::DropOldest,
        ..Default::default()
    };
    // Slow backend so the queue saturates while the worker is busy.
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(50))) as Box<dyn Backend>)),
    )
    .unwrap();
    let rxs: Vec<_> = (0..8).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut shed = 0;
    let mut ok = 0;
    let mut last_ok = None;
    for (i, rx) in rxs.into_iter().enumerate() {
        match resolve(rx) {
            Ok(_) => {
                ok += 1;
                last_ok = Some(i);
            }
            Err(InferError::Shed { reason: ShedReason::DropOldest }) => shed += 1,
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert_eq!(ok + shed, 8, "every request resolves exactly once");
    assert!(shed > 0, "overload must shed under drop-oldest");
    assert_eq!(last_ok, Some(7), "drop-oldest favors the freshest request");
    let m = coord.shutdown();
    assert_eq!(m.shed.load(Ordering::Relaxed), shed as u64);
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
}

#[test]
fn shutdown_under_load_resolves_every_receiver() {
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        queue_capacity: 1024,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(3))) as Box<dyn Backend>)),
    )
    .unwrap();
    let rxs: Vec<_> = (0..200).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let m = coord.shutdown();
    let mut ok = 0;
    let mut shutdown_replies = 0;
    for rx in rxs {
        match resolve(rx) {
            Ok(_) => ok += 1,
            Err(InferError::ShuttingDown) => shutdown_replies += 1,
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert_eq!(ok + shutdown_replies, 200, "every outstanding receiver resolves");
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
}

#[test]
fn mixed_shape_requests_form_separate_batches_all_complete() {
    // Shape-bucketed formation: an odd-shaped request lands in its own
    // bucket and its own batch instead of poisoning its neighbors' batch
    // with a ShapeMismatch. Everyone completes; no batch ever mixes shapes.
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::ZERO)) as Box<dyn Backend>)),
    )
    .unwrap();
    let rx0 = coord.submit(img(0.0)).unwrap();
    let rx1 = coord.submit(img(1.0)).unwrap();
    let rx_odd = coord.submit(Tensor::filled(&[1, 1, 3, 3], 1.0)).unwrap();
    let rx3 = coord.submit(img(3.0)).unwrap();
    for (rx, v) in [(rx0, 0.0), (rx1, 4.0), (rx3, 12.0)] {
        let resp = resolve(rx).expect("same-shape request must complete");
        assert_eq!(resp.logits[0], v);
    }
    // The odd shape completes too — in a single-request batch of its own
    // bucket ([1,1,3,3] filled with 1.0 sums to 9 per row).
    let resp = resolve(rx_odd).expect("odd-shaped request completes in its own bucket");
    assert_eq!(resp.logits[0], 9.0);
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), 4);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    // At least two backend invocations: the two shapes can never share one.
    assert!(m.batches.load(Ordering::Relaxed) >= 2, "shapes must not share a batch");
}

#[test]
fn healthy_worker_carries_flaky_peer() {
    // Two workers: one whose backend always fails, one healthy. Every
    // request resolves typed — and a majority succeed because the healthy
    // worker keeps draining (failed singles are not retried: batch of 1).
    let flaky_first = Arc::new(AtomicU64::new(0));
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        retry_budget: 1,
        restart_limit: 0, // errors (not crashes) never kill workers anyway
        ..Default::default()
    };
    let ff = Arc::clone(&flaky_first);
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if ff.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Box::new(FlakyBackend {
                    // 1ms per (failed) call: slower than the healthy peer,
                    // so the flaky worker cannot drain the whole stream.
                    inner: mock(4, Duration::from_millis(1)),
                    calls: 0,
                    fail_every: 1, // always fails
                }) as Box<dyn Backend>)
            } else {
                Ok(Box::new(mock(4, Duration::from_micros(100))) as Box<dyn Backend>)
            }
        }),
    )
    .unwrap();
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut ok = 0;
    for rx in rxs {
        match resolve(rx) {
            Ok(_) => ok += 1,
            Err(InferError::BackendFailed { .. }) => {}
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert!(ok > 0, "healthy worker should complete some requests");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
    assert_eq!(m.failed.load(Ordering::Relaxed), (n - ok) as u64);
}

#[test]
fn backpressure_then_recovery_keeps_serving() {
    // Reject-newest under a saturated queue: accepted requests all resolve,
    // rejected ones are visible in metrics, and the stream keeps flowing.
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 2,
        max_wait: Duration::from_millis(5),
        queue_capacity: 2,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(2, Duration::from_millis(20))) as Box<dyn Backend>)),
    )
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..20 {
        match coord.submit(img(i as f32)) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::QueueFull(_)) => {
                rejected += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("unexpected submit error {e}"),
        }
    }
    assert!(rejected > 0, "expected backpressure");
    for rx in accepted {
        assert!(resolve(rx).is_ok());
    }
    let m = coord.shutdown();
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected as u64);
    assert_eq!(m.shed.load(Ordering::Relaxed), rejected as u64);
}

#[test]
fn lane_flood_sheds_bulk_before_interactive() {
    // Flood both lanes past capacity under drop-oldest with priority lanes
    // on. Lane-aware shedding must victimize bulk first: interactive
    // arrivals evict queued bulk, and once only interactive remains a bulk
    // arrival is refused outright (bulk may never evict interactive).
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 4,
        shed: ShedPolicy::DropOldest,
        shards: 1,
        priority_lanes: true,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| Ok(Box::new(mock(4, Duration::from_millis(50))) as Box<dyn Backend>)),
    )
    .unwrap();

    // Head request occupies the single worker, freezing the queue for 50 ms
    // — the whole flood below lands inside that window.
    let head = coord.submit_with_options(img(0.0), None, Priority::Interactive).unwrap();
    std::thread::sleep(Duration::from_millis(5));

    // Fill capacity with bulk, then push interactive past capacity: each
    // interactive arrival must evict the stalest queued *bulk* request.
    let bulk_rxs: Vec<_> = (0..4)
        .map(|i| coord.submit_with_options(img(i as f32), None, Priority::Bulk).unwrap())
        .collect();
    let inter_rxs: Vec<_> = (0..4)
        .map(|i| coord.submit_with_options(img(10.0 + i as f32), None, Priority::Interactive))
        .collect::<Result<_, _>>()
        .unwrap();

    // Queue now holds only interactive; further bulk arrivals cannot evict
    // across lanes and are refused in-line as QueueFull.
    let mut bulk_refused = 0;
    for i in 0..2 {
        match coord.submit_with_options(img(20.0 + i as f32), None, Priority::Bulk) {
            Err(SubmitError::QueueFull(_)) => bulk_refused += 1,
            other => panic!("bulk must not evict interactive, got {other:?}"),
        }
    }
    assert_eq!(bulk_refused, 2);

    // Every evicted bulk request resolves typed as drop-oldest shed.
    for rx in bulk_rxs {
        match resolve(rx) {
            Err(InferError::Shed { reason: ShedReason::DropOldest }) => {}
            other => panic!("evicted bulk must resolve Shed(DropOldest), got {other:?}"),
        }
    }
    // The head and every interactive survivor complete.
    assert!(resolve(head).is_ok());
    for rx in inter_rxs {
        assert!(resolve(rx).is_ok(), "interactive must survive the flood");
    }

    let m = coord.shutdown();
    // Shed accounting is lane-exact: all four drop-oldest evictions hit the
    // bulk lane, none hit interactive; the two inline refusals land in
    // rejected (and the shed total) but not in the lane-eviction counters.
    assert_eq!(m.lane_shed[1].load(Ordering::Relaxed), 4, "bulk evictions");
    assert_eq!(m.lane_shed[0].load(Ordering::Relaxed), 0, "interactive never victimized");
    assert_eq!(m.lane_submitted[0].load(Ordering::Relaxed), 5);
    assert_eq!(m.lane_submitted[1].load(Ordering::Relaxed), 4);
    assert_eq!(m.rejected.load(Ordering::Relaxed), 2);
    assert_eq!(m.shed.load(Ordering::Relaxed), 4 + 2);
    assert_eq!(m.completed.load(Ordering::Relaxed), 5);
}

#[test]
fn pool_failure_flushes_every_shard_typed() {
    // Deterministic per-shard flush: queue work onto every shard of a
    // multi-shard queue directly, then fail the pool. Each shard must flush
    // its queued requests with typed NoWorkers — no shard may strand work.
    let q = BatchQueue::new(
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 1024,
            shed: ShedPolicy::RejectNewest,
            shards: 4,
            steal: true,
            priority_lanes: true,
        },
        Arc::new(Metrics::default()),
    );
    let mut rxs = Vec::new();
    for shard in 0..4 {
        for i in 0..8 {
            let (tx, rx) = mpsc::channel();
            let priority = if i % 2 == 0 { Priority::Interactive } else { Priority::Bulk };
            q.submit_to(
                shard,
                InferRequest {
                    id: (shard * 8 + i) as u64,
                    image: img(i as f32),
                    submitted_at: Instant::now(),
                    deadline: None,
                    priority,
                    reply: tx,
                    recycle: None,
                },
            )
            .unwrap();
            rxs.push(rx);
        }
    }
    assert_eq!(q.depth(), 32);
    assert!(q.shard_depths().iter().all(|&d| d == 8), "every shard holds queued work");

    q.fail();
    for rx in rxs {
        match resolve(rx) {
            Err(InferError::NoWorkers) => {}
            other => panic!("failed pool must flush NoWorkers, got {other:?}"),
        }
    }
    assert_eq!(q.depth(), 0);
    assert!(q.shard_depths().iter().all(|&d| d == 0), "no shard strands work after fail");
    assert_eq!(q.lane_depths(), [0, 0]);

    // And the fail-fast state refuses new work on every shard, in-line.
    for shard in 0..4 {
        let (tx, _rx) = mpsc::channel();
        let req = InferRequest {
            id: 1000 + shard as u64,
            image: img(0.0),
            submitted_at: Instant::now(),
            deadline: None,
            priority: Priority::Interactive,
            reply: tx,
            recycle: None,
        };
        assert!(matches!(q.submit_to(shard, req), Err(SubmitError::NoWorkers)));
    }
}

#[test]
fn pool_death_mid_flood_resolves_all_shards_typed() {
    // Kill the whole pool while a multi-submitter flood is in flight on a
    // sharded queue. Every outstanding receiver — across all shards and
    // both lanes — must resolve typed (success, BackendFailed for the
    // detonating batches, NoWorkers for flushed/late work). No hangs, and
    // no shard may hold residual depth once everything has resolved.
    let attempts = Arc::new(AtomicU64::new(0));
    let a2 = Arc::clone(&attempts);
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 2048,
        restart_limit: 2,
        restart_backoff: Duration::from_millis(1),
        shards: 4,
        steal: true,
        priority_lanes: true,
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start(
            cfg,
            Box::new(move || {
                // The two initial workers come up panic-prone; every respawn
                // fails, so two detonations kill the pool for good.
                if a2.fetch_add(1, Ordering::SeqCst) < 2 {
                    Ok(Box::new(PanicOnMagic { inner: mock(4, Duration::from_millis(1)) })
                        as Box<dyn Backend>)
                } else {
                    anyhow::bail!("backend gone")
                }
            }),
        )
        .unwrap(),
    );

    // Phase 1: four submitter threads flood the bulk lane. Distinct threads
    // land on distinct submitter slots, spreading work across shards.
    let handles: Vec<_> = (0..4)
        .map(|s| {
            let c = Arc::clone(&coord);
            std::thread::spawn(move || {
                (0..60)
                    .map(|i| c.submit_with_options(img((s * 60 + i) as f32), None, Priority::Bulk))
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let mut outcomes: Vec<Result<mpsc::Receiver<InferReply>, SubmitError>> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert!(coord.queue_depth() > 0, "flood must outpace the 1ms-per-batch workers");

    // Phase 2: two interactive poison requests jump the bulk backlog.
    // Spaced out so each detonating batch kills a distinct worker; with the
    // factory refusing respawns, the second detonation kills the pool while
    // most of the bulk flood is still queued.
    outcomes.push(coord.submit_with_options(img(500.0), None, Priority::Interactive));
    std::thread::sleep(Duration::from_millis(10));
    outcomes.push(coord.submit_with_options(img(500.0), None, Priority::Interactive));

    // Phase 3: late arrivals race the fail-fast flip — each is either
    // accepted (then flushed) or refused in-line; both outcomes are typed.
    for i in 0..50 {
        outcomes.push(coord.submit_with_options(img(i as f32), None, Priority::Interactive));
        std::thread::sleep(Duration::from_micros(200));
    }

    let (mut ok, mut backend_failed, mut no_workers, mut refused) = (0u64, 0u64, 0u64, 0u64);
    for outcome in outcomes {
        match outcome {
            Ok(rx) => match resolve(rx) {
                Ok(_) => ok += 1,
                Err(InferError::BackendFailed { .. }) => backend_failed += 1,
                Err(InferError::NoWorkers) => no_workers += 1,
                Err(other) => panic!("unexpected error kind: {other:?}"),
            },
            Err(SubmitError::NoWorkers) => refused += 1,
            Err(other) => panic!("unexpected submit error: {other}"),
        }
    }
    assert_eq!(ok + backend_failed + no_workers + refused, 240 + 2 + 50);
    assert!(backend_failed >= 2, "both poison requests resolve typed");
    assert!(no_workers > 0, "the dead pool must flush queued work typed");

    // The pool is fail-fast, and no shard stranded a request: every shard
    // and both lanes drained to zero through replies, not drops.
    let t0 = Instant::now();
    while !coord.is_failed() {
        assert!(t0.elapsed() < RECV_TIMEOUT, "pool never entered fail-fast state");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(coord.queue_depth(), 0);
    assert!(coord.shard_depths().iter().all(|&d| d == 0), "no shard strands work");
    assert_eq!(coord.lane_depths(), [0, 0]);
    let m = Arc::try_unwrap(coord).ok().expect("sole owner").shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok);
}
