//! Failure injection: the coordinator must degrade cleanly when a backend
//! misbehaves — failed batches drop their reply senders (receivers see a
//! disconnect, not a hang), healthy workers keep serving, and metrics stay
//! consistent.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use lqr::coordinator::backend::{Backend, MockBackend};
use lqr::coordinator::{Coordinator, CoordinatorConfig};
use lqr::tensor::Tensor;

/// Backend that fails every `fail_every`-th batch.
struct FlakyBackend {
    inner: MockBackend,
    calls: u64,
    fail_every: u64,
}

impl Backend for FlakyBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.calls += 1;
        if self.calls % self.fail_every == 0 {
            anyhow::bail!("injected failure on call {}", self.calls);
        }
        self.inner.run_batch(batch)
    }

    fn describe(&self) -> String {
        "flaky-mock".into()
    }
}

fn img(v: f32) -> Tensor {
    Tensor::filled(&[1, 1, 2, 2], v)
}

#[test]
fn failed_batches_disconnect_not_hang() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1, // one request per batch -> deterministic failure mapping
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| {
            Ok(Box::new(FlakyBackend {
                inner: MockBackend {
                    classes: 4,
                    delay: Duration::ZERO,
                    calls: Arc::new(AtomicU64::new(0)),
                },
                calls: 0,
                fail_every: 3,
            }) as Box<dyn Backend>)
        }),
    )
    .unwrap();

    let n = 30;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let mut ok = 0;
    let mut failed = 0;
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(_) => ok += 1,
            Err(_) => failed += 1, // disconnect == injected failure
        }
    }
    assert_eq!(ok + failed, n);
    assert_eq!(failed, n / 3, "every 3rd single-request batch fails");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
}

#[test]
fn broken_backend_factory_degrades_to_error_not_panic() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        queue_capacity: 8,
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| anyhow::bail!("backend init exploded")),
    )
    .unwrap();
    // The worker exits at init; requests get disconnects, not hangs.
    let rx = coord.submit(img(1.0)).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(5)).is_err());
}

#[test]
fn healthy_worker_carries_flaky_peer() {
    // Two workers: one whose backend always fails, one healthy. Every
    // request must eventually succeed or disconnect — and a majority
    // succeed because the healthy worker keeps draining.
    let flaky_first = Arc::new(AtomicU64::new(0));
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
    };
    let ff = Arc::clone(&flaky_first);
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            if ff.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Box::new(FlakyBackend {
                    inner: MockBackend {
                        classes: 4,
                        delay: Duration::ZERO,
                        calls: Arc::new(AtomicU64::new(0)),
                    },
                    calls: 0,
                    fail_every: 1, // always fails
                }) as Box<dyn Backend>)
            } else {
                Ok(Box::new(MockBackend {
                    classes: 4,
                    delay: Duration::from_micros(100),
                    calls: Arc::new(AtomicU64::new(0)),
                }) as Box<dyn Backend>)
            }
        }),
    )
    .unwrap();
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(img(i as f32)).unwrap()).collect();
    let ok = rxs
        .into_iter()
        .filter(|rx| rx.recv_timeout(Duration::from_secs(10)).is_ok())
        .count();
    assert!(ok > 0, "healthy worker should complete some requests");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(Ordering::Relaxed), ok as u64);
}

#[test]
fn oversized_then_normal_requests_keep_serving() {
    // A mixed-shape batch would be a caller bug; the worker asserts shapes
    // only in debug builds, so the coordinator contract is "one route = one
    // shape". This test pins the *documented* behaviour that single-shape
    // streams keep flowing after queue-full rejections.
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 2,
        max_wait: Duration::from_millis(5),
        queue_capacity: 2,
    };
    let coord = Coordinator::start(
        cfg,
        Box::new(|| {
            Ok(Box::new(MockBackend {
                classes: 2,
                delay: Duration::from_millis(20),
                calls: Arc::new(AtomicU64::new(0)),
            }) as Box<dyn Backend>)
        }),
    )
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..20 {
        match coord.submit(img(i as f32)) {
            Ok(rx) => accepted.push(rx),
            Err(_) => {
                rejected += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    assert!(rejected > 0, "expected backpressure");
    for rx in accepted {
        assert!(rx.recv_timeout(Duration::from_secs(10)).is_ok());
    }
}
