//! End-to-end self-healing under deterministic chaos: the closing scenario
//! of the fault contract in `docs/serving-robustness.md`.
//!
//! Three layers under test at once, wired through a seeded fault-injecting
//! [`ChaosProxy`]:
//!
//! - the **in-flight watchdog** (supervisor side): a backend wedged
//!   mid-`run_batch` is detected, its stranded requests get typed
//!   `DeadlineExceeded` replies, and the slot respawns — observed here
//!   through the full TCP stack, not a unit harness;
//! - the **resilient client**: `ResilientClient` reconnects through
//!   resets/truncations/black-holes, retries retryable statuses, and trips
//!   its circuit breaker against a dead path;
//! - the **ledger**: the coordinator's conservation invariant
//!   (`completed + failed + shed + expired == submitted`) holds *exactly*
//!   no matter what the wire does, and the new self-healing counters
//!   (`watchdog_kills`, `inflight_expired`, `client_retries`,
//!   `circuit_opens`) reconcile with the observed outcomes.
//!
//! Determinism: every proxy fault schedule, corruption byte, and client
//! backoff jitter derives from fixed seeds — a failure replays.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lqr::coordinator::backend::{Backend, BackendFactory, MockBackend};
use lqr::coordinator::chaos::{ChaosProxy, ConnFault, FaultKind};
use lqr::coordinator::metrics::ClientMetrics;
use lqr::coordinator::net::{ImageSpec, NetConfig, NetServer, ResilientClient, RetryPolicy};
use lqr::coordinator::router::Router;
use lqr::coordinator::{ClientError, CoordinatorConfig};
use lqr::tensor::Tensor;

const SPEC: ImageSpec = ImageSpec { c: 1, h: 2, w: 2 };

fn img(v: f32) -> Tensor {
    Tensor::filled(&[1, 1, 2, 2], v)
}

/// Sum of the coordinator's resolved-outcome counters (the ledger's
/// right-hand side).
fn resolved(m: &lqr::coordinator::metrics::Metrics) -> u64 {
    m.completed.load(Ordering::Relaxed)
        + m.failed.load(Ordering::Relaxed)
        + m.shed.load(Ordering::Relaxed)
        + m.expired.load(Ordering::Relaxed)
}

// ----------------------------------------------------- watchdog, end-to-end --

#[test]
fn wedged_backend_recovers_while_client_retries_to_success() {
    // First run_batch across the worker pool hangs until `release`; every
    // later call (the respawned slot) serves normally.
    struct WedgeOnce {
        wedge: Arc<AtomicBool>,
        release: Arc<AtomicBool>,
        inner: MockBackend,
    }
    impl Backend for WedgeOnce {
        fn run_batch(&mut self, b: &Tensor) -> anyhow::Result<Tensor> {
            if self.wedge.swap(false, Ordering::SeqCst) {
                while !self.release.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(2));
                }
                anyhow::bail!("unwedged late");
            }
            self.inner.run_batch(b)
        }
        fn describe(&self) -> String {
            "wedge-once".into()
        }
    }
    let wedge = Arc::new(AtomicBool::new(true));
    let release = Arc::new(AtomicBool::new(false));
    let calls = Arc::new(AtomicU64::new(0));
    let (w2, r2) = (Arc::clone(&wedge), Arc::clone(&release));
    let factory: BackendFactory = Box::new(move || {
        Ok(Box::new(WedgeOnce {
            wedge: Arc::clone(&w2),
            release: Arc::clone(&r2),
            inner: MockBackend {
                classes: 4,
                delay: Duration::ZERO,
                calls: Arc::clone(&calls),
            },
        }) as Box<dyn Backend>)
    });
    let coord_cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_capacity: 64,
        default_deadline: Some(Duration::from_millis(150)),
        watchdog_grace: Some(Duration::from_millis(50)),
        restart_backoff: Duration::from_millis(5),
        ..Default::default()
    };
    let mut router = Router::new();
    router.add_route("mock", coord_cfg, factory).unwrap();
    let router = Arc::new(router);
    let server = NetServer::serve("127.0.0.1:0", Arc::clone(&router), SPEC).unwrap();
    let mut proxy = ChaosProxy::start(server.addr, 0xC4A0_0001).unwrap();

    let policy = RetryPolicy {
        max_attempts: 10,
        base_backoff: Duration::from_millis(20),
        max_backoff: Duration::from_millis(100),
        failure_threshold: 100, // keep the breaker out of this scenario
        ..RetryPolicy::default()
    };
    let mut client = ResilientClient::connect_lazy(proxy.addr.to_string(), policy);
    client.set_io_timeout(Some(Duration::from_secs(10)));

    // One call, end to end: the first attempt strands in the wedged
    // backend, the watchdog expires it with a typed retryable reply, the
    // client retries, and the respawned slot answers.
    let t0 = Instant::now();
    let (logits, predicted) = client.classify("mock", &img(0.5)).unwrap();
    let elapsed = t0.elapsed();
    assert_eq!(logits[0], 2.0);
    assert_eq!(predicted, 0);
    // Bounded recovery: deadline + grace + restart backoff + sweep tick +
    // client backoff — far under this generous ceiling either way.
    assert!(elapsed < Duration::from_secs(8), "recovery took {elapsed:?}");

    let cm = client.metrics();
    assert!(
        cm.client_retries.load(Ordering::Relaxed) >= 1,
        "success required at least one retry"
    );
    assert_eq!(cm.circuit_opens.load(Ordering::Relaxed), 0);

    // Server-side reconciliation, down to exact counts: one watchdog kill
    // expired exactly one in-flight request, the slot restarted, and the
    // ledger stayed exact.
    let m = router.coordinator("mock").unwrap().metrics();
    assert_eq!(m.watchdog_kills.load(Ordering::Relaxed), 1);
    assert_eq!(m.inflight_expired.load(Ordering::Relaxed), 1);
    assert_eq!(m.expired.load(Ordering::Relaxed), 1);
    assert!(m.worker_restarts.load(Ordering::Relaxed) >= 1);
    assert_eq!(m.submitted.load(Ordering::Relaxed), resolved(m), "ledger must balance");

    // The health built-in carries the new counters through the wire.
    let report = client.health().unwrap();
    assert!(report.contains("watchdog_kills=1 inflight_expired=1"), "{report}");

    release.store(true, Ordering::SeqCst);
    proxy.shutdown();
    server.shutdown();
}

// ------------------------------------------------------------ circuit breaker --

#[test]
fn circuit_opens_against_dead_path_and_probe_closes_it_on_recovery() {
    let mut router = Router::new();
    router
        .add_route(
            "mock",
            CoordinatorConfig::default(),
            Box::new(|| {
                Ok(Box::new(MockBackend {
                    classes: 4,
                    delay: Duration::ZERO,
                    calls: Arc::new(AtomicU64::new(0)),
                }) as Box<dyn Backend>)
            }),
        )
        .unwrap();
    let server = NetServer::serve("127.0.0.1:0", Arc::new(router), SPEC).unwrap();
    let mut proxy = ChaosProxy::start(server.addr, 0xC4A0_0002).unwrap();
    // Dead path: every connection is reset before a byte crosses.
    proxy.set_default(ConnFault { up: FaultKind::Reset, down: FaultKind::Reset });

    let policy = RetryPolicy {
        max_attempts: 1, // isolate the breaker from the retry loop
        failure_threshold: 2,
        circuit_cooldown: Duration::from_millis(150),
        ..RetryPolicy::default()
    };
    let metrics = Arc::new(ClientMetrics::default());
    let mut client =
        ResilientClient::with_metrics(proxy.addr.to_string(), policy, Arc::clone(&metrics));
    client.set_io_timeout(Some(Duration::from_secs(2)));

    // Two consecutive transport failures trip the breaker...
    for _ in 0..2 {
        let err = client.classify("mock", &img(0.5)).unwrap_err();
        assert!(matches!(err, ClientError::Io(_)), "{err}");
    }
    assert!(client.circuit_open());
    assert_eq!(metrics.circuit_opens.load(Ordering::Relaxed), 1);

    // ...and within the cooldown the client fails fast, typed, no dial.
    let t0 = Instant::now();
    let err = client.classify("mock", &img(0.5)).unwrap_err();
    assert!(matches!(err, ClientError::CircuitOpen), "{err}");
    assert!(t0.elapsed() < Duration::from_millis(100), "CircuitOpen must not touch the wire");
    assert_eq!(metrics.circuit_open_rejections.load(Ordering::Relaxed), 1);

    // Path heals; after the cooldown the single half-open probe closes the
    // breaker and traffic flows again.
    proxy.set_default(ConnFault::clean());
    std::thread::sleep(Duration::from_millis(200));
    let (logits, _) = client.classify("mock", &img(0.5)).unwrap();
    assert_eq!(logits[0], 2.0);
    assert!(!client.circuit_open());
    // Exactly one open across the whole scenario, and the recovery dial
    // after the first (reset) connection counted as a reconnect.
    assert_eq!(metrics.circuit_opens.load(Ordering::Relaxed), 1);
    assert!(metrics.reconnects.load(Ordering::Relaxed) >= 1);

    proxy.shutdown();
    server.shutdown();
}

// -------------------------------------------------- conservation under chaos --

#[test]
fn conservation_ledger_is_exact_under_mixed_wire_faults() {
    let mut router = Router::new();
    let coord_cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 256,
        default_deadline: Some(Duration::from_secs(2)),
        watchdog_grace: Some(Duration::from_millis(500)),
        ..Default::default()
    };
    router
        .add_route(
            "mock",
            coord_cfg,
            Box::new(|| {
                Ok(Box::new(MockBackend {
                    classes: 4,
                    delay: Duration::from_millis(1),
                    calls: Arc::new(AtomicU64::new(0)),
                }) as Box<dyn Backend>)
            }),
        )
        .unwrap();
    let router = Arc::new(router);
    let net_cfg = NetConfig { io_timeout: Duration::from_millis(300), ..Default::default() };
    let server =
        NetServer::serve_with("127.0.0.1:0", Arc::clone(&router), SPEC, net_cfg).unwrap();
    let mut proxy = ChaosProxy::start(server.addr, 0xC4A0_0003).unwrap();
    let proxy_addr = proxy.addr;

    // A deterministic burst of per-connection faults; once the schedule
    // drains, connections are clean, so every retrying client can land.
    // Corrupt-up faults may surface as *typed terminal* rejections
    // (BadRequest/BadFrame from the server's frame validation) — those
    // resolve the call, they don't hang it.
    let pass = FaultKind::Pass;
    let faults = [
        ConnFault { up: FaultKind::TruncateAfter(6), down: pass },
        ConnFault { up: pass, down: FaultKind::Reset },
        ConnFault { up: FaultKind::CorruptAfter(10), down: pass },
        ConnFault { up: pass, down: FaultKind::BlackHole(Duration::from_millis(150)) },
        ConnFault { up: FaultKind::Delay(Duration::from_millis(30)), down: pass },
        ConnFault { up: FaultKind::Trickle, down: pass },
        ConnFault { up: FaultKind::TruncateAfter(9), down: pass },
        ConnFault { up: pass, down: FaultKind::TruncateAfter(2) },
    ];
    const CORRUPT_FAULTS: usize = 1; // the only kind that can end a call in a typed reject
    for f in faults {
        proxy.push_fault(f);
    }

    const THREADS: usize = 4;
    const CALLS: usize = 6;
    let shared = Arc::new(ClientMetrics::default());
    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let shared = Arc::clone(&shared);
            let addr = proxy_addr.to_string();
            std::thread::spawn(move || {
                let policy = RetryPolicy {
                    max_attempts: 12,
                    base_backoff: Duration::from_millis(5),
                    max_backoff: Duration::from_millis(50),
                    call_deadline: Some(Duration::from_secs(8)),
                    failure_threshold: 1000, // conservation scenario, not a breaker one
                    seed: 0xC4A0_1000 + t as u64,
                    ..RetryPolicy::default()
                };
                let mut client = ResilientClient::with_metrics(addr, policy, shared);
                client.set_io_timeout(Some(Duration::from_secs(2)));
                let mut ok = 0usize;
                let mut typed_err = 0usize;
                for i in 0..CALLS {
                    let v = (t * CALLS + i) as f32 * 0.05;
                    match client.classify("mock", &img(v)) {
                        Ok((logits, _)) => {
                            assert!(
                                (logits[0] - 4.0 * v).abs() < 1e-4,
                                "wrong answer for v={v}: {logits:?}"
                            );
                            ok += 1;
                        }
                        // Typed terminal rejection (e.g. a corrupted frame
                        // the server answered BadRequest to): resolved.
                        Err(ClientError::Wire(_)) => typed_err += 1,
                        Err(e) => panic!("call neither succeeded nor typed-failed: {e}"),
                    }
                }
                (ok, typed_err)
            })
        })
        .collect();

    let mut ok_total = 0usize;
    let mut err_total = 0usize;
    for w in workers {
        let (ok, err) = w.join().expect("client thread must not panic");
        ok_total += ok;
        err_total += err;
    }
    // Every call resolved; terminal rejections are bounded by the number of
    // corrupting faults in the schedule.
    assert_eq!(ok_total + err_total, THREADS * CALLS);
    assert!(
        err_total <= CORRUPT_FAULTS,
        "only corrupt-up faults may typed-fail, got {err_total}"
    );
    // The faults actually bit: transport-level retries and reconnects ran.
    assert!(shared.client_retries.load(Ordering::Relaxed) >= 1);
    assert!(shared.reconnects.load(Ordering::Relaxed) >= 1);
    assert_eq!(shared.circuit_opens.load(Ordering::Relaxed), 0);

    // Drain the server, then reconcile the ledger *exactly*: every request
    // the coordinator admitted resolved to exactly one typed outcome —
    // retries, severed connections, and black holes included.
    server.shutdown();
    let m = router.coordinator("mock").unwrap().metrics();
    let submitted = m.submitted.load(Ordering::Relaxed);
    assert!(submitted >= ok_total as u64, "at least every Ok was admitted");
    assert_eq!(submitted, resolved(m), "conservation must be exact under chaos");
    // No wedge in this scenario: the watchdog stayed quiet, and its
    // counters reconcile to zero.
    assert_eq!(m.watchdog_kills.load(Ordering::Relaxed), 0);
    assert_eq!(m.inflight_expired.load(Ordering::Relaxed), 0);
    proxy.shutdown();
}
