//! Cross-language parity: the rust `quant` module vs the python reference
//! (`python/compile/quant.py`) over fixtures dumped by `make artifacts`.
//!
//! Codes must match **bit-exactly** (same rounding, same region geometry);
//! scales/mins/GEMM outputs to f32 tolerance.

use lqr::fixedpoint::gemm_quantized;
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::{read_npz, NpzEntry, Tensor};

fn fixtures() -> Option<Vec<NpzEntry>> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let path = std::path::Path::new(&dir).join("fixtures.npz");
    if !path.exists() {
        eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
        return None;
    }
    Some(read_npz(path).unwrap())
}

fn by_name<'a>(entries: &'a [NpzEntry], name: &str) -> &'a NpzEntry {
    entries.iter().find(|e| e.name == name).unwrap_or_else(|| panic!("missing {name}"))
}

#[test]
fn codes_match_python_bit_exactly() {
    let Some(entries) = fixtures() else { return };
    let meta = by_name(&entries, "meta");
    let cases = meta.shape[0];
    let m = meta.as_i32().unwrap();
    for i in 0..cases {
        let (bits, g) = (m[i * 4 + 2] as u8, m[i * 4 + 3] as usize);
        let x = by_name(&entries, &format!("case{i}_x")).to_tensor();
        let want_codes = by_name(&entries, &format!("case{i}_codes"));
        let want_scales = by_name(&entries, &format!("case{i}_scales")).to_tensor();
        let want_mins = by_name(&entries, &format!("case{i}_mins")).to_tensor();

        let q = quantize_matrix(&x, bits, RegionSpec::Size(g));
        let got_codes: Vec<i32> = q.codes.iter().map(|&c| c as i32).collect();
        assert_eq!(
            got_codes,
            want_codes.as_i32().unwrap(),
            "case {i} (bits={bits} g={g}): codes differ from python"
        );
        let scales = Tensor::new(&want_scales.shape().to_vec(), q.scales.clone());
        let mins = Tensor::new(&want_mins.shape().to_vec(), q.mins.clone());
        assert!(scales.max_abs_diff(&want_scales) <= 1e-6 * want_scales.max_abs().max(1e-20));
        assert!(mins.max_abs_diff(&want_mins) <= 1e-6 * want_mins.max_abs().max(1e-20));
    }
}

#[test]
fn gemm_matches_python_reference() {
    let Some(entries) = fixtures() else { return };
    let meta = by_name(&entries, "meta");
    let m = meta.as_i32().unwrap();
    for i in 0..meta.shape[0] {
        let (bits, g) = (m[i * 4 + 2] as u8, m[i * 4 + 3] as usize);
        let x = by_name(&entries, &format!("case{i}_x")).to_tensor();
        let w = by_name(&entries, &format!("case{i}_w")).to_tensor();
        let want = by_name(&entries, &format!("case{i}_gemm")).to_tensor();

        let aq = quantize_matrix(&x, bits, RegionSpec::Size(g));
        let wq = quantize_matrix(&w.transpose2(), 8, RegionSpec::Size(g));
        let got = gemm_quantized(&aq, &wq, 1);
        let tol = 1e-3 * want.max_abs().max(1.0);
        assert!(
            got.max_abs_diff(&want) <= tol,
            "case {i} (bits={bits} g={g}): gemm diff {} > {tol}",
            got.max_abs_diff(&want)
        );
    }
}
