//! Coordinator end-to-end over the real PJRT backend (requires artifacts):
//! the full serving path — submit → batch → PJRT execute → response.

use std::sync::Arc;
use std::time::Duration;

use lqr::coordinator::backend::{Backend, NativeBackend, PjrtBackend};
use lqr::coordinator::{Coordinator, CoordinatorConfig};
use lqr::dataset::Dataset;
use lqr::nn::{Arch, Engine, Precision};

fn artifacts() -> Option<String> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing");
        None
    }
}

#[test]
fn serve_pjrt_f32_batch_correctness() {
    let Some(dir) = artifacts() else { return };
    let ds = Arc::new(Dataset::load(format!("{dir}/data"), "val").unwrap());
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(4),
        queue_capacity: 256,
        ..Default::default()
    };
    let d2 = dir.clone();
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            Ok(Box::new(PjrtBackend::open(&d2, "minialexnet", "f32")?) as Box<dyn Backend>)
        }),
    )
    .unwrap();

    // Submit 40 images, check predictions mostly match labels (99% model).
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(ds.image(i)).unwrap()).collect();
    let mut hits = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("reply within deadline")
            .expect("typed success");
        assert_eq!(resp.logits.len(), 16);
        if resp.predicted as i32 == ds.labels[i] {
            hits += 1;
        }
    }
    assert!(hits >= n * 9 / 10, "served accuracy {hits}/{n}");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(std::sync::atomic::Ordering::Relaxed), n as u64);
    assert!(m.mean_batch_size() > 1.0, "no batching happened");
}

#[test]
fn serve_native_lq2_still_classifies() {
    let Some(dir) = artifacts() else { return };
    let ds = Arc::new(Dataset::load(format!("{dir}/data"), "val").unwrap());
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        queue_capacity: 64,
        ..Default::default()
    };
    let d2 = dir.clone();
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            let engine =
                Engine::from_npz(Arch::minivgg(), format!("{d2}/weights_minivgg.npz"))?;
            Ok(Box::new(NativeBackend::new(engine, Precision::lq(2))) as Box<dyn Backend>)
        }),
    )
    .unwrap();
    let n = 16;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(ds.image(i)).unwrap()).collect();
    let mut hits = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("reply within deadline")
            .expect("typed success");
        if resp.predicted as i32 == ds.labels[i] {
            hits += 1;
        }
    }
    // 2-bit LQ drops accuracy but must stay far above chance (1/16).
    assert!(hits >= n / 2, "2-bit LQ served accuracy {hits}/{n}");
}
