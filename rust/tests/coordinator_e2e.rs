//! Coordinator end-to-end over the real PJRT backend (requires artifacts):
//! the full serving path — submit → batch → PJRT execute → response.
//! Plus artifact-free pins on the shared-engine panel cache (one
//! `WeightPanel` per (layer, bits, region) across every worker, surviving
//! supervisor restarts).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use lqr::coordinator::backend::{shared_native_factory, Backend, PjrtBackend};
use lqr::coordinator::{Coordinator, CoordinatorConfig};
use lqr::dataset::Dataset;
use lqr::nn::{Arch, Engine, Layer, Precision};
use lqr::quant::RegionSpec;
use lqr::tensor::Tensor;
use lqr::util::rng::Rng;

fn artifacts() -> Option<String> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing");
        None
    }
}

#[test]
fn serve_pjrt_f32_batch_correctness() {
    let Some(dir) = artifacts() else { return };
    let ds = Arc::new(Dataset::load(format!("{dir}/data"), "val").unwrap());
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(4),
        queue_capacity: 256,
        ..Default::default()
    };
    let d2 = dir.clone();
    let coord = Coordinator::start(
        cfg,
        Box::new(move || {
            Ok(Box::new(PjrtBackend::open(&d2, "minialexnet", "f32")?) as Box<dyn Backend>)
        }),
    )
    .unwrap();

    // Submit 40 images, check predictions mostly match labels (99% model).
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(ds.image(i)).unwrap()).collect();
    let mut hits = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("reply within deadline")
            .expect("typed success");
        assert_eq!(resp.logits.len(), 16);
        if resp.predicted as i32 == ds.labels[i] {
            hits += 1;
        }
    }
    assert!(hits >= n * 9 / 10, "served accuracy {hits}/{n}");
    let m = coord.shutdown();
    assert_eq!(m.completed.load(std::sync::atomic::Ordering::Relaxed), n as u64);
    assert!(m.mean_batch_size() > 1.0, "no batching happened");
}

#[test]
fn serve_native_lq2_still_classifies() {
    let Some(dir) = artifacts() else { return };
    let ds = Arc::new(Dataset::load(format!("{dir}/data"), "val").unwrap());
    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        queue_capacity: 64,
        ..Default::default()
    };
    // One engine loaded once and shared: both workers (and any restarted
    // replacement) attach to the same weights and panel cache.
    let engine = Arc::new(
        Engine::from_npz(Arch::minivgg(), format!("{dir}/weights_minivgg.npz")).unwrap(),
    );
    let (factory, warmed) = shared_native_factory(Arc::clone(&engine), Precision::lq(2));
    assert_eq!(warmed, engine.arch.layers.len(), "pre-warm must cover every layer");
    let coord = Coordinator::start(cfg, factory).unwrap();
    let n = 16;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(ds.image(i)).unwrap()).collect();
    let mut hits = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("reply within deadline")
            .expect("typed success");
        if resp.predicted as i32 == ds.labels[i] {
            hits += 1;
        }
    }
    // 2-bit LQ drops accuracy but must stay far above chance (1/16).
    assert!(hits >= n / 2, "2-bit LQ served accuracy {hits}/{n}");
}

// ---------------------------------------------------------------------------
// Artifact-free shared-panel-cache pins (synthetic engine, real coordinator).

/// A tiny 2-conv + 2-fc engine small enough to serve in-process.
fn tiny_engine(seed: u64) -> Engine {
    let arch = Arch {
        name: "tiny",
        input: (2, 8, 8),
        num_classes: 4,
        layers: vec![
            Layer::Conv { name: "c1", cin: 2, cout: 4, k: 3, stride: 1, pad: 1, groups: 1, pool: true },
            Layer::Conv { name: "c2", cin: 4, cout: 8, k: 3, stride: 1, pad: 1, groups: 1, pool: true },
            Layer::Fc { name: "f1", cin: 8 * 2 * 2, cout: 16, relu: true },
            Layer::Fc { name: "f2", cin: 16, cout: 4, relu: false },
        ],
    };
    arch.validate().unwrap();
    let mut rng = Rng::new(seed);
    let mut params = HashMap::new();
    for l in &arch.layers {
        let (wshape, blen): (Vec<usize>, usize) = match *l {
            Layer::Conv { cin, cout, k, .. } => (vec![cout, cin, k, k], cout),
            Layer::Fc { cin, cout, .. } => (vec![cin, cout], cout),
        };
        let n: usize = wshape.iter().product();
        params.insert(
            format!("{}.w", l.name()),
            Tensor::new(&wshape, rng.normal_vec(n).iter().map(|v| v * 0.3).collect()),
        );
        params.insert(format!("{}.b", l.name()), Tensor::new(&[blen], rng.normal_vec(blen)));
    }
    Engine::from_params(arch, params).unwrap()
}

/// Shared-engine backend that panics on a poison marker in the batch — the
/// worker-retiring fault, so the supervisor must restart the slot with a
/// factory-fresh backend (which must re-attach to the SAME engine).
struct CrashyShared {
    engine: Arc<Engine>,
    precision: Precision,
}

impl Backend for CrashyShared {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        if batch.data()[0] >= 999.0 {
            panic!("poison marker: backend state corrupted");
        }
        Ok(self.engine.forward(batch, self.precision))
    }

    fn describe(&self) -> String {
        "crashy-shared".into()
    }
}

#[test]
fn workers_share_one_panel_cache_across_restart() {
    let precision = Precision::lq(2);
    let engine = Arc::new(tiny_engine(42));
    // Pre-warm exactly as `shared_native_factory` does, then capture the
    // panel identity the whole pool must keep serving from.
    assert_eq!(engine.prewarm(precision), 4, "one panel per layer");
    let stats0 = engine.panel_stats();
    assert_eq!(stats0.panels, 4);
    let p0 = engine.cached_panel("c1", 8, RegionSpec::PerRow).expect("warmed panel");

    let eng2 = Arc::clone(&engine);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 64,
            ..Default::default()
        },
        Box::new(move || {
            Ok(Box::new(CrashyShared { engine: Arc::clone(&eng2), precision }) as Box<dyn Backend>)
        }),
    )
    .unwrap();

    let ok_img = || Tensor::filled(&[1, 2, 8, 8], 0.1);
    let reply = |rx: std::sync::mpsc::Receiver<lqr::coordinator::InferReply>| {
        rx.recv_timeout(Duration::from_secs(30)).expect("reply within deadline")
    };

    // Healthy traffic lands on both workers' backends — all one engine.
    for _ in 0..4 {
        let resp = reply(coord.submit(ok_img()).unwrap()).expect("typed success");
        assert_eq!(resp.logits.len(), 4);
    }

    // Poison: the backend panics, the worker retires, the supervisor
    // restarts the slot via the factory.
    let mut poison = vec![0.1f32; 2 * 8 * 8];
    poison[0] = 1000.0;
    let err = reply(coord.submit(Tensor::new(&[1, 2, 8, 8], poison)).unwrap())
        .expect_err("poison request must fail typed");
    assert!(
        matches!(err, lqr::coordinator::InferError::BackendFailed { .. }),
        "got {err:?}"
    );
    let deadline = Instant::now() + Duration::from_secs(30);
    while coord.metrics().worker_restarts.load(std::sync::atomic::Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "supervisor never restarted the crashed worker");
        std::thread::sleep(Duration::from_millis(5));
    }

    // The restarted worker serves — from the same shared panel cache.
    let resp = reply(coord.submit(ok_img()).unwrap()).expect("post-restart success");
    assert_eq!(resp.logits.len(), 4);

    let p1 = engine.cached_panel("c1", 8, RegionSpec::PerRow).expect("panel still cached");
    assert!(Arc::ptr_eq(&p0, &p1), "restart must re-attach to the SAME WeightPanel");
    assert_eq!(engine.panel_stats(), stats0, "no duplicate panels were built");
    for layer in ["c1", "c2", "f1", "f2"] {
        let p = engine.cached_panel(layer, 8, RegionSpec::PerRow);
        assert!(p.is_some(), "layer {layer} lost its warmed panel");
    }
    coord.shutdown();
}

#[test]
fn shared_factory_products_share_one_engine() {
    let engine = Arc::new(tiny_engine(7));
    let (factory, warmed) = shared_native_factory(Arc::clone(&engine), Precision::lq(2));
    assert_eq!(warmed, 4, "factory pre-warms every layer");
    // Every product — worker slots and any restart replacement — reports
    // the shared panel cache, never a private copy.
    let mut b1 = factory().unwrap();
    let mut b2 = factory().unwrap();
    let before = engine.panel_stats();
    let x = Tensor::filled(&[1, 2, 8, 8], 0.2);
    let y1 = b1.run_batch(&x).unwrap();
    let y2 = b2.run_batch(&x).unwrap();
    assert_eq!(y1, y2, "same engine, same panels, same logits");
    assert_eq!(engine.panel_stats(), before, "forward built no new panels after pre-warm");
    assert!(b1.describe().contains("panels=4"), "{}", b1.describe());
}
