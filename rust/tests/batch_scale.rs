//! Concurrency stress suite for the sharded batching core — the pin for the
//! scale plane (sharded submission queues, shape-bucketed formation, work
//! stealing, priority lanes).
//!
//! The headline invariant is **conservation**: with N submitter threads
//! racing M workers over sharded queues, every submission attempt resolves
//! to exactly one observable outcome —
//!
//! ```text
//! completed + shed + expired + failed + rejected == submitted attempts
//! ```
//!
//! — with no duplicated executions and no hangs (every wait in this file is
//! `recv_timeout`-bounded; a lost request fails the test instead of wedging
//! CI). Alongside it: property tests pinning bucket keying (a formed batch
//! is never shape-mixed) and priority ordering (interactive never starves
//! behind bulk when a lane slot is free).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use lqr::coordinator::backend::{Backend, BackendFactory, MockBackend};
use lqr::coordinator::batcher::{BatchPolicy, BatchQueue, ShedPolicy};
use lqr::coordinator::metrics::Metrics;
use lqr::coordinator::request::{InferError, InferReply, InferRequest, Priority};
use lqr::coordinator::server::{Coordinator, CoordinatorConfig};
use lqr::coordinator::SubmitError;
use lqr::tensor::Tensor;
use lqr::util::prop;

/// Upper bound on any single wait. Generous so slow CI never flakes; the
/// point is that a *lost* request trips this instead of hanging forever.
const RECV_TIMEOUT: Duration = Duration::from_secs(10);

fn mock_factory(delay: Duration) -> BackendFactory {
    Box::new(move || {
        Ok(Box::new(MockBackend {
            classes: 4,
            delay,
            calls: Arc::new(AtomicU64::new(0)),
        }) as Box<dyn Backend>)
    })
}

/// Build a raw queue request for direct `BatchQueue` tests.
fn raw_req(
    id: u64,
    shape: &[usize],
    priority: Priority,
    ttl: Option<Duration>,
) -> (InferRequest, mpsc::Receiver<InferReply>) {
    let (tx, rx) = mpsc::channel();
    let now = Instant::now();
    (
        InferRequest {
            id,
            image: Tensor::zeros(shape),
            submitted_at: now,
            deadline: ttl.map(|d| now + d),
            priority,
            reply: tx,
            recycle: None,
        },
        rx,
    )
}

// ---------------------------------------------------------- conservation --

/// Per-thread ground-truth tallies, merged after the run.
#[derive(Default)]
struct Tally {
    admitted: u64,
    rejected: u64,
    completed: u64,
    shed: u64,
    expired: u64,
    failed: u64,
}

/// The headline stress: 6 submitters × 4 workers × 4 shards, mixed lanes,
/// mixed shapes, a slice of tight TTLs, drop-oldest shedding under a small
/// capacity — and exact conservation at the end.
#[test]
fn conservation_under_concurrent_load() {
    const SUBMITTERS: usize = 6;
    const PER_THREAD: usize = 400;
    let cfg = CoordinatorConfig {
        workers: 4,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        queue_capacity: 64,
        shed: ShedPolicy::DropOldest,
        shards: 4,
        steal: true,
        priority_lanes: true,
        ..Default::default()
    };
    let coord =
        Arc::new(Coordinator::start(cfg, mock_factory(Duration::from_millis(1))).unwrap());

    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|t| {
            let coord = Arc::clone(&coord);
            std::thread::spawn(move || {
                let mut tally = Tally::default();
                let mut pending = Vec::with_capacity(PER_THREAD);
                for i in 0..PER_THREAD {
                    let idx = (t * PER_THREAD + i) as u64;
                    // Mixed shapes exercise the buckets; mixed lanes the
                    // priority scheduler; sparse tight TTLs the expiry path.
                    let shape: &[usize] =
                        if idx % 3 == 0 { &[1, 1, 3, 3] } else { &[1, 1, 2, 2] };
                    let pri = if idx % 4 == 0 { Priority::Bulk } else { Priority::Interactive };
                    let ttl = (idx % 7 == 0).then(|| Duration::from_millis(2));
                    let npix: usize = shape.iter().product();
                    let expect = idx as f32 * npix as f32;
                    match coord.submit_with_options(Tensor::filled(shape, idx as f32), ttl, pri)
                    {
                        Ok(rx) => {
                            tally.admitted += 1;
                            pending.push((expect, rx));
                        }
                        Err(SubmitError::QueueFull(_)) => tally.rejected += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                for (expect, rx) in pending {
                    match rx.recv_timeout(RECV_TIMEOUT) {
                        Ok(Ok(r)) => {
                            assert_eq!(
                                r.logits[0], expect,
                                "response wired to the wrong request"
                            );
                            tally.completed += 1;
                        }
                        Ok(Err(InferError::Shed { .. })) => tally.shed += 1,
                        Ok(Err(InferError::DeadlineExceeded)) => tally.expired += 1,
                        Ok(Err(_)) => tally.failed += 1,
                        Err(e) => panic!("reply lost (conservation broken): {e}"),
                    }
                }
                tally
            })
        })
        .collect();

    let mut total = Tally::default();
    for h in handles {
        let t = h.join().expect("submitter panicked");
        total.admitted += t.admitted;
        total.rejected += t.rejected;
        total.completed += t.completed;
        total.shed += t.shed;
        total.expired += t.expired;
        total.failed += t.failed;
    }

    let attempts = (SUBMITTERS * PER_THREAD) as u64;
    assert_eq!(total.admitted + total.rejected, attempts);
    assert_eq!(
        total.completed + total.shed + total.expired + total.failed,
        total.admitted,
        "every admitted request must resolve exactly once"
    );
    assert_eq!(total.failed, 0, "mock backend never fails");

    let m = coord.metrics();
    // No duplicated executions: every request a worker ran completed, and
    // nothing completed twice (batched rows == completions == our tally).
    assert_eq!(m.batched_requests.load(Ordering::Relaxed), total.completed);
    assert_eq!(m.completed.load(Ordering::Relaxed), total.completed);
    assert_eq!(
        m.lane_submitted[0].load(Ordering::Relaxed)
            + m.lane_submitted[1].load(Ordering::Relaxed),
        total.admitted
    );
    assert_eq!(coord.queue_depth(), 0, "nothing may remain queued");
}

// ------------------------------------------------------------ properties --

/// Bucket keying: whatever the (shape, lane, shard) interleaving, a formed
/// batch always holds exactly one shape, and shutdown-drain pops every
/// admitted request exactly once.
#[test]
fn property_formed_batches_are_shape_homogeneous() {
    prop::check("batch-scale-bucket-keying", 0xB0C4_E7E5, |rng, _| {
        let shards = 1 + rng.below(3) as usize;
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 1 + rng.below(6) as usize,
                max_wait: Duration::from_secs(60),
                capacity: 1024,
                shed: ShedPolicy::RejectNewest,
                shards,
                steal: true,
                priority_lanes: rng.below(2) == 0,
            },
            Arc::new(Metrics::default()),
        );
        let shapes: [&[usize]; 3] = [&[1, 1, 2, 2], &[1, 1, 3, 3], &[1, 2, 2, 2]];
        let n = 8 + rng.below(56) as usize;
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let shape = shapes[rng.below(3) as usize];
            let pri =
                if rng.below(2) == 0 { Priority::Interactive } else { Priority::Bulk };
            let (req, rx) = raw_req(i as u64, shape, pri, None);
            q.submit_to(rng.below(shards as u64) as usize, req).unwrap();
            rxs.push(rx);
        }
        q.shutdown();
        let mut popped = 0usize;
        while let Some((batch, _reason)) = q.pop_batch_from(0) {
            let s0 = batch[0].image.shape().to_vec();
            for r in &batch {
                assert_eq!(r.image.shape(), &s0[..], "one batch mixed two shapes");
            }
            popped += batch.len();
        }
        assert_eq!(popped, n, "shutdown drain must pop every admitted request once");
    });
}

/// Priority ordering: when both lanes hold releasable work, the formed
/// batch comes from the interactive lane — bulk age notwithstanding.
#[test]
fn property_interactive_never_starves_behind_bulk() {
    prop::check("batch-scale-priority-order", 0x1A4E_0001, |rng, _| {
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(60),
                capacity: 1024,
                shed: ShedPolicy::RejectNewest,
                shards: 1,
                steal: true,
                priority_lanes: true,
            },
            Arc::new(Metrics::default()),
        );
        // Bulk arrives first (it is strictly older) and is already
        // releasable (>= max_batch queued)...
        let n_bulk = 4 + rng.below(8) as usize;
        let mut rxs = Vec::new();
        for i in 0..n_bulk {
            let (req, rx) = raw_req(i as u64, &[1, 1, 2, 2], Priority::Bulk, None);
            q.submit(req).unwrap();
            rxs.push(rx);
        }
        // ...then a full interactive batch lands.
        for i in 0..4 {
            let (req, rx) =
                raw_req(1000 + i as u64, &[1, 1, 2, 2], Priority::Interactive, None);
            q.submit(req).unwrap();
            rxs.push(rx);
        }
        let (batch, _) = q.pop_batch_from(0).expect("releasable work queued");
        assert!(
            batch.iter().all(|r| r.priority == Priority::Interactive),
            "interactive lane must form first while a lane slot is free"
        );
        assert!(batch.iter().all(|r| r.id >= 1000));
        // Queued bulk gets typed replies on fail(); the popped interactive
        // requests are resolved by dropping their senders here.
        q.fail();
        drop(batch);
        for rx in rxs.iter().take(n_bulk) {
            match rx.recv_timeout(RECV_TIMEOUT) {
                Ok(Err(InferError::NoWorkers)) => {}
                other => panic!("bulk straggler must get a typed NoWorkers reply: {other:?}"),
            }
        }
    });
}

// ------------------------------------------------------ metrics exactness --

/// Randomized 10k-request run, then an exact cross-check of every Metrics
/// counter against ground-truth tallies observed at the reply channels.
#[test]
fn metrics_match_ground_truth_after_randomized_run() {
    const SUBMITTERS: usize = 4;
    const PER_THREAD: usize = 2500;
    let cfg = CoordinatorConfig {
        workers: 4,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        queue_capacity: 48,
        shed: ShedPolicy::DropOldest,
        shards: 2,
        steal: true,
        priority_lanes: true,
        ..Default::default()
    };
    let coord =
        Arc::new(Coordinator::start(cfg, mock_factory(Duration::from_micros(200))).unwrap());

    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|t| {
            let coord = Arc::clone(&coord);
            std::thread::spawn(move || {
                let mut rng = lqr::util::rng::Rng::new(0x5EED_0000 + t as u64);
                let mut tally = Tally::default();
                let mut pending = Vec::with_capacity(PER_THREAD);
                for _ in 0..PER_THREAD {
                    let pri =
                        if rng.below(3) == 0 { Priority::Bulk } else { Priority::Interactive };
                    let ttl = (rng.below(10) == 0).then(|| Duration::from_millis(1));
                    match coord.submit_with_options(Tensor::zeros(&[1, 1, 2, 2]), ttl, pri) {
                        Ok(rx) => {
                            tally.admitted += 1;
                            pending.push(rx);
                        }
                        Err(SubmitError::QueueFull(_)) => tally.rejected += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                for rx in pending {
                    match rx.recv_timeout(RECV_TIMEOUT) {
                        Ok(Ok(_)) => tally.completed += 1,
                        Ok(Err(InferError::Shed { .. })) => tally.shed += 1,
                        Ok(Err(InferError::DeadlineExceeded)) => tally.expired += 1,
                        Ok(Err(_)) => tally.failed += 1,
                        Err(e) => panic!("reply lost: {e}"),
                    }
                }
                tally
            })
        })
        .collect();

    let mut gt = Tally::default();
    for h in handles {
        let t = h.join().expect("submitter panicked");
        gt.admitted += t.admitted;
        gt.rejected += t.rejected;
        gt.completed += t.completed;
        gt.shed += t.shed;
        gt.expired += t.expired;
        gt.failed += t.failed;
    }
    assert_eq!(gt.admitted + gt.rejected, (SUBMITTERS * PER_THREAD) as u64);

    let m = coord.metrics();
    assert_eq!(m.submitted.load(Ordering::Relaxed), gt.admitted, "submitted");
    assert_eq!(m.rejected.load(Ordering::Relaxed), gt.rejected, "rejected");
    assert_eq!(m.completed.load(Ordering::Relaxed), gt.completed, "completed");
    assert_eq!(m.expired.load(Ordering::Relaxed), gt.expired, "expired");
    assert_eq!(m.failed.load(Ordering::Relaxed), gt.failed, "failed");
    // `shed` counts drop-oldest victims (reply sheds) plus synchronous
    // queue-full rejections (the coordinator records both).
    assert_eq!(m.shed.load(Ordering::Relaxed), gt.shed + gt.rejected, "shed");
    // Lane admissions partition the admitted set.
    assert_eq!(
        m.lane_submitted[0].load(Ordering::Relaxed)
            + m.lane_submitted[1].load(Ordering::Relaxed),
        gt.admitted,
        "lane_submitted"
    );
    // Execution-side consistency: rows ran == rows completed (the mock
    // never fails), and steals can't exceed formed batches.
    assert_eq!(m.batched_requests.load(Ordering::Relaxed), gt.completed);
    assert!(m.steals.load(Ordering::Relaxed) <= m.batches.load(Ordering::Relaxed));
}

// ----------------------------------------------------------------- lanes --

/// End-to-end lane-slot check through the Coordinator: saturate the bulk
/// lane behind a slow backend, then verify interactive requests overtake
/// the queued bulk backlog (strict lane priority at formation).
#[test]
fn interactive_overtakes_queued_bulk_end_to_end() {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 1024,
        shards: 1,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, mock_factory(Duration::from_millis(5))).unwrap();
    // Head batch occupies the worker; the rest of bulk queues behind it.
    // 80 requests = 20 batches x 5ms, a backlog far longer than the
    // interactive round trip, so the depth check below can't be raced away
    // by scheduler jitter.
    let bulk: Vec<_> = (0..80)
        .map(|i| {
            coord
                .submit_with_options(Tensor::filled(&[1, 1, 2, 2], i as f32), None, Priority::Bulk)
                .unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(2)); // let the first batch form
    let inter = coord
        .submit_with_options(Tensor::filled(&[1, 1, 2, 2], 99.0), None, Priority::Interactive)
        .unwrap();
    let inter_resp = inter.recv_timeout(RECV_TIMEOUT).unwrap().unwrap();
    // The interactive request must not have waited for the whole bulk
    // backlog (20 batches x 5ms); queued bulk work was still pending when
    // it completed.
    assert!(
        coord.queue_depth() > 0,
        "interactive reply arrived only after the bulk backlog drained"
    );
    assert_eq!(inter_resp.logits[0], 4.0 * 99.0);
    for rx in bulk {
        assert!(rx.recv_timeout(RECV_TIMEOUT).unwrap().is_ok());
    }
    coord.shutdown();
}
