//! Deployment-format round trip: quantize the trained model offline to
//! `.lqz`, reload with no f32 weights, and verify the quantized engine
//! serves the same accuracy (requires `make artifacts`).

use lqr::dataset::Dataset;
use lqr::eval::evaluate;
use lqr::nn::{Arch, Engine, Precision};
use lqr::quant::serialize::{read_lqz, write_lqz};
use lqr::quant::RegionSpec;

fn setup() -> Option<(Engine, Dataset, String)> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    let engine = Engine::from_npz(
        Arch::minialexnet(),
        format!("{dir}/weights_minialexnet.npz"),
    )
    .unwrap();
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap().take(128);
    Some((engine, ds, dir))
}

#[test]
fn lqz_deploy_preserves_quantized_accuracy() {
    let Some((engine, ds, _)) = setup() else { return };
    let tmp = std::env::temp_dir().join(format!("lqr_deploy_{}.lqz", std::process::id()));
    write_lqz(&tmp, &engine.to_lqz_entries(8, RegionSpec::PerRow)).unwrap();

    let deployed = Engine::from_lqz(Arch::minialexnet(), &tmp).unwrap();
    let a = evaluate(&engine, &ds, Precision::lq(8), 32, None);
    let b = evaluate(&deployed, &ds, Precision::lq(8), 32, None);
    // The deployed engine re-quantizes activations at runtime but uses the
    // *shipped* weight codes; accuracy must match the build-host run.
    assert_eq!(a.top1, b.top1, "deployed {} vs build-host {}", b.top1, a.top1);
    std::fs::remove_file(&tmp).unwrap();
}

#[test]
fn lqz_file_much_smaller_than_npz() {
    let Some((engine, _, dir)) = setup() else { return };
    let npz = std::fs::metadata(format!("{dir}/weights_minialexnet.npz")).unwrap().len();
    let size_of = |bits: u8, region: RegionSpec| -> u64 {
        let tmp = std::env::temp_dir()
            .join(format!("lqr_size_{}_{bits}_{region}.lqz", std::process::id()));
        write_lqz(&tmp, &engine.to_lqz_entries(bits, region)).unwrap();
        let s = std::fs::metadata(&tmp).unwrap().len();
        std::fs::remove_file(&tmp).unwrap();
        s
    };
    // Kernel-sized regions: side-car (scale+min per region) is negligible,
    // so the file shrinks ~bits/32.
    let perrow2 = size_of(2, RegionSpec::PerRow);
    assert!(
        perrow2 * 8 < npz,
        "2-bit kernel-region lqz ({perrow2}) should be >8x smaller than npz ({npz})"
    );
    // Small regions trade footprint for accuracy (Fig. 10): 8 bytes of
    // side-car per 9 codes at g=9 dominates 2-bit codes. The deploy format
    // makes that trade visible rather than hiding it.
    let g9 = size_of(2, RegionSpec::Size(9));
    assert!(g9 > perrow2 * 2, "g=9 side-car overhead should show: {g9} vs {perrow2}");
    assert!(g9 < npz, "even g=9 beats shipping f32");
}

#[test]
fn lqz_entries_enumerate_all_layers() {
    let Some((engine, _, _)) = setup() else { return };
    let tmp = std::env::temp_dir().join(format!("lqr_enum_{}.lqz", std::process::id()));
    write_lqz(&tmp, &engine.to_lqz_entries(4, RegionSpec::PerRow)).unwrap();
    let names: Vec<String> = read_lqz(&tmp).unwrap().into_iter().map(|e| e.name).collect();
    for l in ["conv1", "conv2", "conv3", "fc1", "fc2"] {
        assert!(names.contains(&format!("{l}.w")), "{l}.w missing");
        assert!(names.contains(&format!("{l}.b")), "{l}.b missing");
    }
    std::fs::remove_file(&tmp).unwrap();
}
