//! npz interop: the hand-rolled reader vs real numpy-written archives
//! (requires `make artifacts`), plus artifact-free parity pins on the
//! copy-free loading path (`into_tensor` vs the cloning `to_tensor`).

use lqr::dataset::Dataset;
use lqr::tensor::{npz_bytes, read_npz, read_npz_bytes, NpzData, NpzEntry};

fn dir() -> Option<String> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing");
        None
    }
}

#[test]
fn weights_npz_loads_with_expected_shapes() {
    let Some(dir) = dir() else { return };
    let entries = read_npz(format!("{dir}/weights_minialexnet.npz")).unwrap();
    let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
    for want in ["conv1.w", "conv1.b", "conv2.w", "conv3.w", "fc1.w", "fc2.w"] {
        assert!(names.contains(&want), "missing {want} in {names:?}");
    }
    let c1 = entries.iter().find(|e| e.name == "conv1.w").unwrap();
    assert_eq!(c1.shape, vec![32, 3, 5, 5]);
    let t = c1.to_tensor();
    assert!(t.data().iter().all(|v| v.is_finite()));
    assert!(t.max_abs() > 0.0, "weights are all zero?");
}

#[test]
fn val_dataset_loads_and_is_balanced() {
    let Some(dir) = dir() else { return };
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap();
    assert_eq!(ds.len(), 2000);
    assert_eq!(ds.image_shape(), (3, 32, 32));
    // Pixel range sanity.
    assert!(ds.images.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    // Balanced classes (exactly n/16 each by construction).
    let mut counts = [0usize; 16];
    for &l in &ds.labels {
        counts[l as usize] += 1;
    }
    for (c, &n) in counts.iter().enumerate() {
        assert_eq!(n, 125, "class {c} has {n} examples");
    }
}

#[test]
fn int_labels_decode_correctly() {
    let Some(dir) = dir() else { return };
    let entries = read_npz(format!("{dir}/data/val.npz")).unwrap();
    let y = entries.iter().find(|e| e.name == "y").unwrap();
    let labels = y.as_i32().expect("y should be an integer array");
    assert!(labels.iter().all(|&l| (0..16).contains(&l)));
}

/// Artifact-free parity pin: the copy-free load path (`into_tensor`, which
/// moves/converts storage in place) must produce bit-identical tensors to
/// the old cloning path (`to_tensor`) for both f32 and i32 members, through
/// a full in-memory archive round trip.
#[test]
fn copy_free_load_matches_cloning_path() {
    let entries = vec![
        NpzEntry {
            name: "w".into(),
            shape: vec![2, 3],
            data: NpzData::F32(vec![0.5, -1.25, 3.75, f32::MIN_POSITIVE, 0.0, -0.0]),
        },
        NpzEntry {
            name: "y".into(),
            shape: vec![4],
            data: NpzData::I32(vec![-7, 0, 15, i32::MAX]),
        },
    ];
    let archive = npz_bytes(&entries);
    let old_path = read_npz_bytes(&archive).unwrap();
    let new_path = read_npz_bytes(&archive).unwrap();
    assert_eq!(old_path.len(), 2);
    for (old, new) in old_path.iter().zip(new_path) {
        // Old path clones through a borrow; new path consumes the entry.
        let cloned = old.to_tensor();
        let moved = new.into_tensor();
        assert_eq!(cloned.shape(), moved.shape());
        let (a, b) = (cloned.data(), moved.data());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "bit-exact parity in {}", old.name);
        }
    }
    // And the decoded i32 view survives the writer round trip exactly.
    let y = old_path.iter().find(|e| e.name == "y").unwrap();
    assert_eq!(y.as_i32().unwrap(), &[-7, 0, 15, i32::MAX]);
}

// Corrupt-archive rejection: the typed `NpzError` validation must fire
// through the full archive path (`read_npz_bytes`, member context and all),
// not just the npy parser it lives in. Archives are built with the crate's
// own writer, then surgically damaged — the reader is CRC-agnostic by
// design (STORED members are sliced, not checksummed), so validation is
// the only line of defense these tests pin.

/// Byte offset of `needle`'s first occurrence in `hay` (panics if absent —
/// these tests know exactly what they wrote).
fn find(hay: &[u8], needle: &[u8]) -> usize {
    hay.windows(needle.len())
        .position(|w| w == needle)
        .expect("pattern must exist in the archive these tests built")
}

fn f32_entry(name: &str, shape: Vec<usize>, vals: Vec<f32>) -> NpzEntry {
    NpzEntry { name: name.into(), shape, data: NpzData::F32(vals) }
}

#[test]
fn archive_with_nan_weight_fails_the_load_typed() {
    // Locate the payload by the 8-byte [2.5, 3.5] pair (a single float's 4
    // bytes could in principle collide with a zip header field), then stamp
    // NaN over the 2.5.
    let mut archive =
        npz_bytes(&[f32_entry("w", vec![2, 2], vec![0.5, 1.5, 2.5, 3.5])]);
    let mut needle = Vec::new();
    needle.extend_from_slice(&2.5f32.to_le_bytes());
    needle.extend_from_slice(&3.5f32.to_le_bytes());
    let at = find(&archive, &needle);
    archive[at..at + 4].copy_from_slice(&f32::NAN.to_le_bytes());
    let err = read_npz_bytes(&archive).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("member w.npy"), "{msg}");
    assert!(msg.contains("non-finite value (NaN/Inf) at element 2"), "{msg}");
}

#[test]
fn archive_with_zero_dim_member_fails_the_load_typed() {
    // The writer will happily serialize an empty (0, 3) array — numpy does
    // too — so the *reader* must be the one to refuse it.
    let archive = npz_bytes(&[
        f32_entry("ok", vec![2], vec![1.0, 2.0]),
        f32_entry("empty", vec![0, 3], vec![]),
    ]);
    let err = read_npz_bytes(&archive).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("member empty.npy"), "{msg}");
    assert!(msg.contains("zero-sized dimension in shape [0, 3]"), "{msg}");
}

#[test]
fn archive_with_shape_body_disagreement_fails_the_load_typed() {
    // Rewrite the ASCII shape tuple in the npy header — "(2, 3)" and
    // "(2, 4)" are the same length, so every zip offset stays valid and
    // only the promised element count lies.
    let mut archive = npz_bytes(&[f32_entry(
        "w",
        vec![2, 3],
        vec![0.5, -1.0, 1.5, -2.0, 2.5, -3.0],
    )]);
    let at = find(&archive, b"'shape': (2, 3)");
    archive[at..at + 15].copy_from_slice(b"'shape': (2, 4)");
    let err = read_npz_bytes(&archive).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("member w.npy"), "{msg}");
    assert!(msg.contains("body length mismatch: expected 32 bytes, got 24"), "{msg}");
}
