//! End-to-end runtime tests over the real artifacts (require `make artifacts`).
//!
//! These pin the full AOT contract: python-lowered HLO text loads, compiles
//! and executes through the rust PJRT session, and its numerics agree with
//! the rust-native engine over the same npz weights.

use lqr::dataset::Dataset;
use lqr::eval::topk_hit;
use lqr::nn::{Arch, Engine, Precision};
use lqr::runtime::Session;
use lqr::tensor::Tensor;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: {dir}/manifest.json missing (run `make artifacts`)");
        None
    }
}

#[test]
fn f32_artifact_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::open(&dir).unwrap();
    let runner = session.load("minialexnet_f32_b8").unwrap();
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap();
    let x = ds.batch(0, 8);
    let pjrt_logits = session.run(&runner, &x).unwrap();

    let engine = Engine::from_npz(
        Arch::minialexnet(),
        format!("{dir}/weights_minialexnet.npz"),
    )
    .unwrap();
    let native_logits = engine.forward(&x, Precision::F32);

    assert_eq!(pjrt_logits.shape(), native_logits.shape());
    let scale = native_logits.max_abs().max(1.0);
    let diff = pjrt_logits.max_abs_diff(&native_logits);
    assert!(
        diff <= 2e-3 * scale,
        "PJRT vs native f32 forward diverge: {diff} (scale {scale})"
    );
}

#[test]
fn lq8_artifact_classifies_val_set() {
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::open(&dir).unwrap();
    let runner = session.load("minivgg_lq8_b8").unwrap();
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap();
    let n = 64;
    let mut hits = 0;
    for start in (0..n).step_by(8) {
        let x = ds.batch(start, 8);
        let logits = session.run(&runner, &x).unwrap();
        for r in 0..8 {
            if topk_hit(logits.row(r), ds.labels[start + r], 1) {
                hits += 1;
            }
        }
    }
    let acc = hits as f64 / n as f64;
    // The Pallas 8-bit LQ artifact should track the ~99% f32 model closely.
    assert!(acc > 0.9, "lq8 artifact top-1 over {n} val images = {acc}");
}

#[test]
fn batch1_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::open(&dir).unwrap();
    let runner = session.load("minialexnet_f32_b1").unwrap();
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap();
    let logits = session.run(&runner, &ds.image(0)).unwrap();
    assert_eq!(logits.shape(), &[1, 16]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn weight_override_changes_output() {
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::open(&dir).unwrap();
    let runner = session.load("minialexnet_f32_b1").unwrap();
    let ds = Dataset::load(format!("{dir}/data"), "val").unwrap();
    let x = ds.image(0);
    let before = session.run(&runner, &x).unwrap();
    // Zeroing fc2 weights must zero the logits (bias only remains).
    let zero = Tensor::zeros(&[256, 16]);
    session.override_weight("minialexnet", "fc2.w", &zero).unwrap();
    let after = session.run(&runner, &x).unwrap();
    assert!(before.max_abs_diff(&after) > 1e-3, "override had no effect");
}

#[test]
fn wrong_input_size_is_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::open(&dir).unwrap();
    let runner = session.load("minialexnet_f32_b1").unwrap();
    let bad = Tensor::zeros(&[1, 3, 16, 16]);
    assert!(session.run(&runner, &bad).is_err());
}
