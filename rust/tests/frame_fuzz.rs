//! Seeded fuzz harness for the wire frame parser (the ROADMAP's "fuzz
//! target for the frame parser" leftover).
//!
//! Deterministic, not coverage-guided: a SplitMix64 stream mutates valid
//! frames (bit flips, truncations, length-field extremes, splices of two
//! frames) and drives `read_frame_into` — the exact production parse
//! function, public for this harness — across 100k cases per run. Every
//! case asserts the parser's full safety contract:
//!
//! - **No panic** on any input (the `#[test]` would fail).
//! - **No over-allocation**: scratch buffers stay bounded by
//!   `max_route_len` / the route's `ImageSpec` regardless of what the
//!   length fields claim.
//! - **Scratch-independence**: parsing with a dirty recycled
//!   [`FrameScratch`] yields the same outcome and consumes the same bytes
//!   as parsing with a fresh one — buffer reuse can never leak one
//!   request's bytes into the next.
//! - **Classification consistency**: fatal rejects are `BadFrame`
//!   (stream desynced, connection must close), in-sync rejects are
//!   `BadRequest`, and after an in-sync reject that consumed the whole
//!   mutated input, an appended valid frame still parses — the "never
//!   desync" guarantee the connection handler relies on.
//!
//! A failure prints the case's seed index and mutated bytes; rerun with
//! `LQR_FUZZ_CASES` to widen or narrow the sweep.

use std::io::Cursor;

use lqr::coordinator::net::{
    read_frame_into, Frame, FrameError, FrameScratch, ImageSpec, NetConfig, LANE_FLAG,
};
use lqr::coordinator::net::WireStatus;
use lqr::util::rng::Rng;

const SPEC: ImageSpec = ImageSpec { c: 1, h: 2, w: 2 };
const N_FLOATS: usize = 4; // SPEC.c * SPEC.h * SPEC.w

fn small_cfg() -> NetConfig {
    // Small limits so length-field extremes actually straddle them.
    NetConfig { max_route_len: 64, max_frame_bytes: 4096, ..NetConfig::default() }
}

/// A well-formed frame: route, optional lane byte, spec-sized payload.
fn valid_frame(rng: &mut Rng) -> Vec<u8> {
    let routes: [&[u8]; 3] = [b"mock", b"health", b"a-much-longer-route-name"];
    let route = routes[rng.below(routes.len() as u64) as usize];
    let lane = match rng.below(3) {
        0 => None,
        1 => Some(0u8),
        _ => Some(1u8),
    };
    let mut len = route.len() as u32;
    if lane.is_some() {
        len |= LANE_FLAG;
    }
    let mut b = Vec::new();
    b.extend_from_slice(&len.to_le_bytes());
    b.extend_from_slice(route);
    if let Some(l) = lane {
        b.push(l);
    }
    b.extend_from_slice(&(N_FLOATS as u32).to_le_bytes());
    for _ in 0..N_FLOATS {
        b.extend_from_slice(&rng.range(-4.0, 4.0).to_le_bytes());
    }
    b
}

/// The recycled-buffer worst case: every scratch buffer holds residue from
/// a previous request.
fn dirty_scratch() -> FrameScratch {
    FrameScratch {
        route: b"stale-route-from-last-request".to_vec(),
        payload: vec![0xAB; 64],
        image: vec![999.0; 16],
        reply: vec![0xCD; 32],
    }
}

/// Collapse an outcome to a comparable tag (errors compare by kind, not by
/// message text or io::Error identity).
fn outcome_tag(r: &Result<Frame, FrameError>) -> String {
    match r {
        Ok(Frame::Infer { priority, lane_tagged }) => format!("infer:{priority:?}:{lane_tagged}"),
        Ok(Frame::Health) => "health".into(),
        Ok(Frame::Eof) => "eof".into(),
        Err(FrameError::Reject { status, fatal, .. }) => format!("reject:{status:?}:{fatal}"),
        Err(FrameError::Io(e)) => format!("io:{:?}", e.kind()),
    }
}

/// Mutate `bytes` in place (or build a fresh stream) per the seeded plan.
fn mutate(rng: &mut Rng, mut bytes: Vec<u8>) -> Vec<u8> {
    match rng.below(4) {
        // Bit flips: 1–4 flipped bits anywhere in the frame.
        0 => {
            for _ in 0..=rng.below(3) {
                let at = rng.below(bytes.len() as u64) as usize;
                bytes[at] ^= 1 << rng.below(8);
            }
            bytes
        }
        // Truncation: cut anywhere, including inside the length prefix.
        1 => {
            let cut = rng.below(bytes.len() as u64) as usize;
            bytes.truncate(cut);
            bytes
        }
        // Length-field extremes on route_len or n_floats.
        2 => {
            let extremes = [
                u32::MAX,
                u32::MAX & !LANE_FLAG,
                LANE_FLAG,          // lane-tagged empty route
                LANE_FLAG | 65,     // lane-tagged, just past max_route_len
                65,                 // just past max_route_len
                64,                 // exactly max_route_len
                0,
                1 << 20,            // large but under the LANE_FLAG bit
            ];
            let v = extremes[rng.below(extremes.len() as u64) as usize];
            if rng.below(2) == 0 {
                bytes[..4].copy_from_slice(&v.to_le_bytes());
            } else {
                // Overwrite the last 4 bytes before the payload start — for
                // an untagged "mock" frame that's not exactly the n_floats
                // field, which is fine: the fuzzer's contract is outcome
                // consistency, not mutation precision.
                let at = bytes.len().saturating_sub(N_FLOATS * 4 + 4);
                bytes[at..at + 4].copy_from_slice(&v.to_le_bytes());
            }
            bytes
        }
        // Splice: the head of one frame grafted onto the tail of another.
        _ => {
            let other = valid_frame(rng);
            let cut_a = rng.below(bytes.len() as u64 + 1) as usize;
            let cut_b = rng.below(other.len() as u64 + 1) as usize;
            let mut spliced = bytes[..cut_a].to_vec();
            spliced.extend_from_slice(&other[cut_b..]);
            spliced
        }
    }
}

/// Parse one stream with the given scratch; returns (outcome tag, bytes
/// consumed, what the parser left in the scratch).
fn parse_with(bytes: &[u8], cfg: &NetConfig, mut scratch: FrameScratch) -> (String, u64, FrameScratch) {
    let mut cur = Cursor::new(bytes);
    let out = read_frame_into(&mut cur, SPEC, cfg, &mut scratch);
    (outcome_tag(&out), cur.position(), scratch)
}

#[test]
fn fuzz_mutated_frames_hold_the_parser_contract() {
    let cases: u64 = std::env::var("LQR_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let cfg = small_cfg();
    let mut rng = Rng::new(0xF0_22_5EED);
    for case in 0..cases {
        let base = valid_frame(&mut rng);
        let mutated = mutate(&mut rng, base);

        let (tag_fresh, pos_fresh, s_fresh) = parse_with(&mutated, &cfg, FrameScratch::new());
        let (tag_dirty, pos_dirty, s_dirty) = parse_with(&mutated, &cfg, dirty_scratch());

        // Scratch-independence: identical outcome and cursor position.
        assert_eq!(
            tag_fresh, tag_dirty,
            "case {case}: outcome depends on scratch residue; bytes={mutated:?}"
        );
        assert_eq!(
            pos_fresh, pos_dirty,
            "case {case}: consumed bytes depend on scratch residue; bytes={mutated:?}"
        );

        // Bounded allocation no matter what the length fields claimed.
        for s in [&s_fresh, &s_dirty] {
            assert!(
                s.route.len() <= cfg.max_route_len,
                "case {case}: route buffer {} exceeds max_route_len",
                s.route.len()
            );
            assert!(
                s.payload.len() <= N_FLOATS * 4 + 64,
                "case {case}: payload buffer {} exceeds spec bound",
                s.payload.len()
            );
        }

        // Classification consistency + no stale residue on success.
        if tag_fresh.starts_with("infer") {
            assert_eq!(
                s_fresh.image, s_dirty.image,
                "case {case}: decoded image differs across scratches"
            );
            assert_eq!(s_fresh.image.len(), N_FLOATS, "case {case}: image not spec-sized");
            assert_eq!(
                s_fresh.route, s_dirty.route,
                "case {case}: decoded route differs across scratches"
            );
        } else if let Some(rest) = tag_fresh.strip_prefix("reject:") {
            let fatal = rest.ends_with("true");
            if fatal {
                assert!(
                    rest.starts_with(&format!("{:?}", WireStatus::BadFrame)),
                    "case {case}: fatal reject must be BadFrame, got {tag_fresh}"
                );
            } else {
                assert!(
                    rest.starts_with(&format!("{:?}", WireStatus::BadRequest)),
                    "case {case}: in-sync reject must be BadRequest, got {tag_fresh}"
                );
                // Never-desync: when the in-sync reject consumed exactly the
                // mutated stream, a valid frame appended after it parses.
                if pos_fresh == mutated.len() as u64 {
                    let follow = valid_frame(&mut rng);
                    let mut stream = mutated.clone();
                    stream.extend_from_slice(&follow);
                    let mut cur = Cursor::new(&stream[..]);
                    let mut scratch = dirty_scratch();
                    let first = read_frame_into(&mut cur, SPEC, &cfg, &mut scratch);
                    assert_eq!(
                        outcome_tag(&first),
                        tag_fresh,
                        "case {case}: reject changed with appended data"
                    );
                    let second = read_frame_into(&mut cur, SPEC, &cfg, &mut scratch);
                    assert!(
                        matches!(second, Ok(Frame::Infer { .. }) | Ok(Frame::Health)),
                        "case {case}: stream desynced after in-sync reject: {}",
                        outcome_tag(&second)
                    );
                }
            }
        }
    }
}

#[test]
fn unmutated_frames_always_parse() {
    // Control: the generator really does produce valid frames (otherwise
    // the fuzz above would be vacuous).
    let cfg = small_cfg();
    let mut rng = Rng::new(0xBA5E);
    for case in 0..1_000 {
        let frame = valid_frame(&mut rng);
        let (tag, pos, _) = parse_with(&frame, &cfg, dirty_scratch());
        assert!(
            tag.starts_with("infer") || tag == "health",
            "case {case}: valid frame rejected: {tag}"
        );
        assert_eq!(pos, frame.len() as u64, "case {case}: valid frame not fully consumed");
    }
}
