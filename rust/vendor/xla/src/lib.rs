//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate links the xla_extension C++ runtime, which is not
//! available in hermetic builds. This stub keeps the `lqr::runtime` module
//! compiling with identical signatures; every entry point returns a clear
//! runtime error instead. Code paths that need real PJRT execution (the
//! `runtime_e2e` tests, `lqr classify`, the pjrt serving backend) already
//! skip or surface errors when artifacts are unavailable, so nothing in the
//! tier-1 test suite depends on a live backend.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` far enough for `anyhow::Error::from`.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "xla/PJRT backend unavailable: this build uses the offline stub \
         (link the real xla_extension runtime to execute AOT artifacts)"
            .to_string(),
    )
}

/// Stub PJRT client: construction fails, so sessions error out up front.
pub struct PjRtClient;

/// Stub device buffer (never constructed).
pub struct PjRtBuffer;

/// Stub compiled executable (never constructed).
pub struct PjRtLoadedExecutable;

/// Stub HLO module proto (never constructed).
pub struct HloModuleProto;

/// Stub computation handle.
pub struct XlaComputation;

/// Stub literal (host tensor) handle (never constructed).
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_stub() {
        let e = PjRtClient::cpu().err().expect("stub must error");
        assert!(e.to_string().contains("offline stub"));
    }
}
