//! Offline subset of the `log` facade crate, vendored so the workspace builds
//! with no registry access. Provides the pieces `lqr` uses: the [`Log`]
//! trait, [`Level`] / [`LevelFilter`], `set_logger` / `set_max_level`, and
//! the `error!` .. `trace!` macros.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

/// Log verbosity of a single record, most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (just the level here).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record as handed to the installed [`Log`] implementation.
pub struct Record<'a> {
    metadata: Metadata,
    target: &'a str,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn target(&self) -> &str {
        self.target
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

/// A log sink. Implementations are installed once with [`set_logger`].
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

// The installed logger, stored as a raw fat pointer behind two atomics
// (pointer + vtable can't live in one AtomicPtr; box the trait-object ref).
static LOGGER: AtomicPtr<&'static dyn Log> = AtomicPtr::new(std::ptr::null_mut());
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let boxed: *mut &'static dyn Log = Box::into_raw(Box::new(logger));
    match LOGGER.compare_exchange(
        std::ptr::null_mut(),
        boxed,
        Ordering::SeqCst,
        Ordering::SeqCst,
    ) {
        Ok(_) => Ok(()),
        Err(_) => {
            // Lost the race: free our box and report the duplicate install.
            // SAFETY: `boxed` came from Box::into_raw above and was never
            // published.
            drop(unsafe { Box::from_raw(boxed) });
            Err(SetLoggerError(()))
        }
    }
}

fn logger() -> Option<&'static dyn Log> {
    let p = LOGGER.load(Ordering::SeqCst);
    if p.is_null() {
        None
    } else {
        // SAFETY: once published, the box is never freed or mutated.
        Some(unsafe { *p })
    }
}

/// Set the maximum level that reaches the logger.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

/// Current maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if let Some(l) = logger() {
        let record = Record { metadata: Metadata { level }, target, args };
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__log(lvl, ::std::module_path!(), ::std::format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct Counter(AtomicUsize);

    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= max_level()
        }
        fn log(&self, _r: &Record) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter(AtomicUsize::new(0));

    #[test]
    fn filter_and_dispatch() {
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = COUNTER.0.load(Ordering::SeqCst);
        info!("hello {}", 1);
        debug!("filtered out");
        assert_eq!(COUNTER.0.load(Ordering::SeqCst), before + 1);
        assert!(set_logger(&COUNTER).is_err(), "second install must fail");
    }

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
    }
}
