//! Offline subset of the `anyhow` crate, vendored so the workspace builds
//! with no registry access. Implements exactly the surface the `lqr` crate
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters here:
//! - `{}` displays the outermost message only;
//! - `{:#}` displays the whole chain joined by `": "`;
//! - `{:?}` displays the message plus a `Caused by:` list (what `.unwrap()`
//!   prints in tests).

use std::fmt;

/// Error type: an outermost message plus the chain of causes beneath it.
pub struct Error {
    msg: String,
    /// Causes from outermost to innermost (does not include `msg`).
    causes: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), causes: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        let mut causes = Vec::with_capacity(self.causes.len() + 1);
        causes.push(self.msg);
        causes.extend(self.causes);
        Error { msg: ctx.to_string(), causes }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.causes.iter().map(|s| s.as_str()))
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.causes.last().map(|s| s.as_str()).unwrap_or(self.msg.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msg)?;
            for c in &self.causes {
                write!(f, ": {c}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut causes = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), causes }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring upstream anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(::std::format!($fmt $(, $($arg)*)?))
    };
    ($e:expr $(,)?) => {
        $crate::Error::msg($e)
    };
}

/// Early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}", ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_display() {
        let e: Error = Error::from(io_err()).context("loading weights");
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: file missing");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }
}
