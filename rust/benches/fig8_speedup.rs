//! Bench: Fig. 8 — per-image runtime, f32 baseline vs 8-bit LQ fixed point.
//!
//! Measured on the host engine (mini models) + the Edison cost model (full
//! models). `LQR_BENCH_LIMIT` scales the measured image count (default 20).

fn main() {
    let images = std::env::var("LQR_BENCH_LIMIT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let artifacts = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match lqr::eval::sweep::fig8(&artifacts, images) {
        Ok(t) => t.print(),
        Err(e) => {
            eprintln!("fig8_speedup skipped: {e:#} (run `make artifacts`)");
        }
    }
}
