//! Bench: Tables 4 + 5 — FPGA Matrix Multiplier resource/timing/perf/power
//! model, plus cycle counts from the functional 4x4 CU array simulation.

use lqr::platform::fpga::resource::CuConfig;
use lqr::platform::fpga::sim::simulate;
use lqr::util::rng::Rng;

fn main() {
    lqr::eval::sweep::table45().print();

    // Simulated cycle counts for an AlexNet-conv1-shaped GEMM panel per CU
    // configuration (same workload, narrower inputs).
    println!("cycle-level simulation, 16x363x16 quantized GEMM panel:");
    let (m, k, n) = (16usize, 363usize, 16usize);
    let mut rng = Rng::new(9);
    let b_codes: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
    for cfg in [
        CuConfig::Fixed { wp: 8, wi: 8 },
        CuConfig::Fixed { wp: 8, wi: 4 },
        CuConfig::Fixed { wp: 8, wi: 2 },
    ] {
        let wi = match cfg {
            CuConfig::Fixed { wi, .. } => wi,
            _ => unreachable!(),
        };
        let a_codes: Vec<i32> = (0..m * k).map(|_| rng.below(1 << wi) as i32).collect();
        let sim = simulate(cfg, &a_codes, &b_codes, m, k, n);
        let r = lqr::platform::fpga::resource::estimate(cfg);
        let us = sim.cycles as f64 / (r.fmax_mhz * 1e6) * 1e6;
        println!(
            "  {:<10} cycles={:<6} util={:>5.1}%  @Fmax: {:.2} us/panel",
            cfg.label(),
            sim.cycles,
            sim.utilization() * 100.0,
            us
        );
    }
}
