//! Micro-benchmarks of the GEMM ladder — the §Perf profiling tool.
//!
//! Times f32 / naive-i8 / panel-i8 / packed / LUT GEMMs on layer-shaped
//! problems and reports effective GMAC/s, plus the runtime activation-
//! quantization pass and the one-off weight-panel prep the engine caches.
//! `LQR_BENCH_ITERS` overrides the per-case iteration count (default 5).
//!
//! Besides the table on stdout, writes `BENCH_gemm.json` at the repo root
//! so the perf trajectory is machine-readable across PRs: one record per
//! (case, kernel) with ms, GMAC/s, speedup vs the blocked f32 baseline,
//! speedup vs the seed's naive general-region i8 path, and (for the panel
//! rows) speedup of the dispatched SIMD kernel over the forced-scalar one.
//! Every *other* SIMD arm the host supports (e.g. the NEON umlal tile on a
//! dotprod host, AVX2 on a VNNI host) gets its own `i8-panel[name]` row so
//! per-ISA comparisons are machine-readable too. The header records the
//! detected ISA and the dispatcher's selected kernel so results are
//! comparable across hosts. For 1/2/4-bit operands the bit-serial popcount
//! path gets a `bitserial[arm]-b{bits}` row per supported arm plus a
//! ratio-only `bitserial-vs-u8panel(b{bits})` headline row (dispatched
//! bit-serial vs dispatched u8 panel on the same low-bit operands; the
//! `u8panel-b{bits}` row carries that baseline's timing). An `im2col-fused` case times the fused conv
//! lowering single-threaded vs parallel, and a `conv-fwd` case times the
//! full engine conv path (fused im2col quantization) against the f32
//! engine.

use std::collections::HashMap;
use std::time::Instant;

use lqr::fixedpoint::gemm_lut::gemm_lut;
use lqr::fixedpoint::gemm_packed::PackedMatrix;
use lqr::fixedpoint::panel::{
    gemm_lut_panel, gemm_panel, gemm_panel_packed, gemm_panel_with, WeightPanel,
};
use lqr::fixedpoint::simd;
use lqr::fixedpoint::{gemm_bitserial_with, gemm_f32, gemm_quantized_naive, im2col_quantized};
use lqr::nn::{Arch, Engine, Layer, Precision};
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::Tensor;
use lqr::util::json::Json;
use lqr::util::rng::Rng;

fn gmacs(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    (m * k * n) as f64 / secs / 1e9
}

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

struct Record {
    case: &'static str,
    kernel: String,
    /// Which inner-loop implementation ran ("-" where not applicable).
    impl_name: String,
    /// Seconds per call (serialized as milliseconds).
    secs: f64,
    gmacs: f64,
    speedup_vs_f32: f64,
    /// vs the seed naive general-region i8 path at the same activation bits
    /// (0.0 when not applicable, e.g. the f32 / naive rows themselves).
    speedup_vs_naive: f64,
    /// Dispatched-SIMD vs forced-scalar panel kernel (0.0 when n/a).
    speedup_vs_scalar: f64,
}

fn print_row(r: &Record) {
    println!(
        "{:<34} {:>10.3} {:>10.2} {:>9.2}x {:>9}",
        format!("{} {}", r.case, r.kernel),
        r.secs * 1e3,
        r.gmacs,
        r.speedup_vs_f32,
        if r.speedup_vs_naive > 0.0 {
            format!("{:.2}x", r.speedup_vs_naive)
        } else {
            "-".to_string()
        }
    );
}

fn write_json(path: &str, threads: usize, iters: usize, records: &[Record]) {
    let cases: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("case", Json::str(r.case)),
                ("kernel", Json::str(r.kernel.clone())),
                ("impl", Json::str(r.impl_name.clone())),
                ("ms", Json::num(r.secs * 1e3)),
                ("gmacs", Json::num(r.gmacs)),
                ("speedup_vs_f32", Json::num(r.speedup_vs_f32)),
                ("speedup_vs_naive", Json::num(r.speedup_vs_naive)),
                ("speedup_vs_scalar", Json::num(r.speedup_vs_scalar)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("gemm_micro")),
        ("threads", Json::num(threads as f64)),
        ("iters", Json::num(iters as f64)),
        ("isa_detected", Json::str(simd::detected_isa())),
        ("simd_kernel", Json::str(simd::active().name)),
        ("cases", Json::Arr(cases)),
    ]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let iters: usize = std::env::var("LQR_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    println!("gemm micro-bench (iters={iters}, threads={threads})");
    println!(
        "{:<34} {:>10} {:>10} {:>10} {:>9}",
        "case", "ms", "GMAC/s", "vs f32", "vs naive"
    );

    let mut rng = Rng::new(1);
    let mut records: Vec<Record> = Vec::new();
    // Layer-shaped cases: (label, M, K, N) from the mini models' conv GEMMs.
    for &(label, m, k, n) in &[
        ("conv1 1024x75x32", 1024usize, 75usize, 32usize),
        ("conv2 256x800x64", 256, 800, 64),
        ("fc 8x2048x256", 8, 2048, 256),
    ] {
        let a = Tensor::new(&[m, k], rng.uniform_vec(m * k, 0.0, 1.0));
        let w_t = Tensor::new(&[n, k], rng.normal_vec(n * k));
        let w = w_t.transpose2();

        let t_f32 = time(iters, || {
            std::hint::black_box(gemm_f32(&a, &w, threads));
        });
        records.push(Record {
            case: label,
            kernel: "f32".into(),
            impl_name: "-".into(),
            secs: t_f32,
            gmacs: gmacs(m, k, n, t_f32),
            speedup_vs_f32: 1.0,
            speedup_vs_naive: 0.0,
            speedup_vs_scalar: 0.0,
        });
        print_row(records.last().unwrap());

        let wq = quantize_matrix(&w_t, 8, RegionSpec::PerRow);
        let wpanel = WeightPanel::from_quantized(&wq);
        for bits in [8u8, 2] {
            let aq = quantize_matrix(&a, bits, RegionSpec::PerRow);

            // Seed baseline: scalar dot per (i, j, region).
            let t_naive = time(iters, || {
                std::hint::black_box(gemm_quantized_naive(&aq, &wq, threads));
            });
            records.push(Record {
                case: label,
                kernel: format!("i8-naive(a{bits})"),
                impl_name: "-".into(),
                secs: t_naive,
                gmacs: gmacs(m, k, n, t_naive),
                speedup_vs_f32: t_f32 / t_naive,
                speedup_vs_naive: 0.0,
                speedup_vs_scalar: 0.0,
            });
            print_row(records.last().unwrap());

            // Forced-scalar panel: the portable dispatch arm, measured so
            // the SIMD speedup below is machine-readable.
            let t_scalar = time(iters, || {
                std::hint::black_box(gemm_panel_with(&aq, &wpanel, threads, simd::scalar_kernel()));
            });
            records.push(Record {
                case: label,
                kernel: format!("i8-panel-scalar(a{bits})"),
                impl_name: "scalar".into(),
                secs: t_scalar,
                gmacs: gmacs(m, k, n, t_scalar),
                speedup_vs_f32: t_f32 / t_scalar,
                speedup_vs_naive: t_naive / t_scalar,
                speedup_vs_scalar: 0.0,
            });
            print_row(records.last().unwrap());

            // Panel core over a cached panel — the engine's steady state,
            // on the dispatched SIMD kernel.
            let t_panel = time(iters, || {
                std::hint::black_box(gemm_panel(&aq, &wpanel, threads));
            });
            records.push(Record {
                case: label,
                kernel: format!("i8-panel(a{bits})"),
                impl_name: simd::active().name.into(),
                secs: t_panel,
                gmacs: gmacs(m, k, n, t_panel),
                speedup_vs_f32: t_f32 / t_panel,
                speedup_vs_naive: t_naive / t_panel,
                speedup_vs_scalar: t_scalar / t_panel,
            });
            print_row(records.last().unwrap());

            // The headline comparison row: dispatched SIMD vs forced scalar,
            // ratio-only so aggregators don't double-count the panel timing
            // (ms/gmacs live on the i8-panel rows above).
            records.push(Record {
                case: label,
                kernel: format!("simd-vs-scalar(a{bits})"),
                impl_name: simd::active().name.into(),
                secs: 0.0,
                gmacs: 0.0,
                speedup_vs_f32: 0.0,
                speedup_vs_naive: 0.0,
                speedup_vs_scalar: t_scalar / t_panel,
            });

            // Non-default arms the host also supports (e.g. neon-umlal on a
            // dotprod host, avx2-madd on a VNNI host): one row each, so the
            // per-ISA ladder is visible from a single run.
            for kernel in simd::supported_kernels() {
                if kernel.name == "scalar" || kernel.name == simd::active().name {
                    continue;
                }
                let t_arm = time(iters, || {
                    std::hint::black_box(gemm_panel_with(&aq, &wpanel, threads, kernel));
                });
                records.push(Record {
                    case: label,
                    kernel: format!("i8-panel[{}](a{bits})", kernel.name),
                    impl_name: kernel.name.into(),
                    secs: t_arm,
                    gmacs: gmacs(m, k, n, t_arm),
                    speedup_vs_f32: t_f32 / t_arm,
                    speedup_vs_naive: t_naive / t_arm,
                    speedup_vs_scalar: t_scalar / t_arm,
                });
                print_row(records.last().unwrap());
            }

            if bits == 2 {
                let t_lut = time(iters, || {
                    std::hint::black_box(gemm_lut_panel(&aq, &wpanel, threads));
                });
                records.push(Record {
                    case: label,
                    kernel: "lut-panel(a2)".into(),
                    impl_name: simd::active().name.into(),
                    secs: t_lut,
                    gmacs: gmacs(m, k, n, t_lut),
                    speedup_vs_f32: t_f32 / t_lut,
                    speedup_vs_naive: t_naive / t_lut,
                    speedup_vs_scalar: 0.0,
                });
                print_row(records.last().unwrap());
                // Legacy entry point (panel built per call) for reference.
                let t_lut_entry = time(iters, || {
                    std::hint::black_box(gemm_lut(&aq, &wq, threads));
                });
                records.push(Record {
                    case: label,
                    kernel: "lut(a2,prep incl)".into(),
                    impl_name: simd::active().name.into(),
                    secs: t_lut_entry,
                    gmacs: gmacs(m, k, n, t_lut_entry),
                    speedup_vs_f32: t_f32 / t_lut_entry,
                    speedup_vs_naive: t_naive / t_lut_entry,
                    speedup_vs_scalar: 0.0,
                });
                print_row(records.last().unwrap());

                let ap = PackedMatrix::from_quantized(&aq);
                let wp_packed = WeightPanel::from_packed(&PackedMatrix::from_quantized(&wq));
                let t_p = time(iters, || {
                    std::hint::black_box(gemm_panel_packed(&ap, &wp_packed, threads));
                });
                records.push(Record {
                    case: label,
                    kernel: "packed-panel(a2)".into(),
                    impl_name: simd::active().name.into(),
                    secs: t_p,
                    gmacs: gmacs(m, k, n, t_p),
                    speedup_vs_f32: t_f32 / t_p,
                    speedup_vs_naive: t_naive / t_p,
                    speedup_vs_scalar: 0.0,
                });
                print_row(records.last().unwrap());
            }
        }

        // Bit-serial popcount rows: both operands quantized at the low
        // width, one row per supported dispatch arm
        // (`bitserial[arm]-b{bits}`), plus the headline ratio row
        // (`bitserial-vs-u8panel(b{bits})`): the dispatched bit-serial arm
        // vs the dispatched u8 panel microkernel *on the same low-bit
        // operands* — the win the paper's Fig. 8 promises from sub-8-bit
        // compute, not just sub-8-bit memory.
        for bits in [1u8, 2, 4] {
            let aq = quantize_matrix(&a, bits, RegionSpec::PerRow);
            let wq_b = quantize_matrix(&w_t, bits, RegionSpec::PerRow);
            let wp_b = WeightPanel::from_quantized(&wq_b);
            let t_u8 = time(iters, || {
                std::hint::black_box(gemm_panel(&aq, &wp_b, threads));
            });
            records.push(Record {
                case: label,
                kernel: format!("u8panel-b{bits}"),
                impl_name: simd::active().name.into(),
                secs: t_u8,
                gmacs: gmacs(m, k, n, t_u8),
                speedup_vs_f32: t_f32 / t_u8,
                speedup_vs_naive: 0.0,
                speedup_vs_scalar: 0.0,
            });
            print_row(records.last().unwrap());
            for kernel in simd::supported_kernels() {
                let t_bs = time(iters, || {
                    std::hint::black_box(gemm_bitserial_with(&aq, &wp_b, threads, kernel));
                });
                records.push(Record {
                    case: label,
                    kernel: format!("bitserial[{}]-b{bits}", kernel.name),
                    impl_name: kernel.name.into(),
                    secs: t_bs,
                    gmacs: gmacs(m, k, n, t_bs),
                    speedup_vs_f32: t_f32 / t_bs,
                    speedup_vs_naive: 0.0,
                    // vs the dispatched u8 panel on identical operands.
                    speedup_vs_scalar: t_u8 / t_bs,
                });
                print_row(records.last().unwrap());
                if kernel.name == simd::active().name {
                    // Ratio-only headline row (no ms: the timing lives on
                    // the bitserial[arm] row above).
                    records.push(Record {
                        case: label,
                        kernel: format!("bitserial-vs-u8panel(b{bits})"),
                        impl_name: kernel.name.into(),
                        secs: 0.0,
                        gmacs: 0.0,
                        speedup_vs_f32: 0.0,
                        speedup_vs_naive: 0.0,
                        speedup_vs_scalar: t_u8 / t_bs,
                    });
                }
            }
        }

        // One-off costs the engine amortizes: panel prep (cached per layer)
        // and the runtime activation-quantization pass (per batch).
        let t_prep = time(iters, || {
            std::hint::black_box(WeightPanel::from_quantized(&wq));
        });
        records.push(Record {
            case: label,
            kernel: "panel-prep(w)".into(),
            impl_name: "-".into(),
            secs: t_prep,
            gmacs: 0.0,
            speedup_vs_f32: 0.0,
            speedup_vs_naive: 0.0,
            speedup_vs_scalar: 0.0,
        });
        print_row(records.last().unwrap());
        let t_quant = time(iters, || {
            std::hint::black_box(quantize_matrix(&a, 8, RegionSpec::PerRow));
        });
        println!(
            "{:<34} {:>10.3} {:>10} {:>10} {:>9}",
            format!("{label} quantize(a)"),
            t_quant * 1e3,
            "-",
            format!("{:.1}%", 100.0 * t_quant / t_f32),
            "-"
        );
        records.push(Record {
            case: label,
            kernel: "quantize(a8)".into(),
            impl_name: "-".into(),
            secs: t_quant,
            gmacs: 0.0,
            speedup_vs_f32: 0.0,
            speedup_vs_naive: 0.0,
            speedup_vs_scalar: 0.0,
        });
    }

    // Fused conv lowering: im2col + region min/max + code emission in one
    // pass, single-threaded vs chunked over the shared pool — the runtime
    // activation-quantization cost the paper's §VI overhead concern is
    // about, on an AlexNet-conv1-shaped input.
    {
        let (b, c, hh, kk, stride, pad) = (8usize, 3usize, 32usize, 5usize, 1usize, 2usize);
        let x = Tensor::new(&[b, c, hh, hh], rng.uniform_vec(b * c * hh * hh, 0.0, 1.0));
        let label = "im2col b8x3x32x32 k5";
        let t_one = time(iters, || {
            std::hint::black_box(im2col_quantized(&x, kk, stride, pad, 8, RegionSpec::PerRow, 1));
        });
        records.push(Record {
            case: label,
            kernel: "im2col-fused(t1)".into(),
            impl_name: "-".into(),
            secs: t_one,
            gmacs: 0.0,
            speedup_vs_f32: 0.0,
            speedup_vs_naive: 0.0,
            speedup_vs_scalar: 0.0,
        });
        print_row(records.last().unwrap());
        let t_par = time(iters, || {
            std::hint::black_box(im2col_quantized(
                &x, kk, stride, pad, 8, RegionSpec::PerRow, threads,
            ));
        });
        records.push(Record {
            case: label,
            kernel: format!("im2col-fused(t{threads})"),
            impl_name: "-".into(),
            secs: t_par,
            gmacs: 0.0,
            speedup_vs_f32: 0.0,
            speedup_vs_naive: 0.0,
            // Reuse the ratio column: parallel vs single-threaded lowering.
            speedup_vs_scalar: t_one / t_par,
        });
        print_row(records.last().unwrap());
    }

    // Conv forward path: the engine at LQ-8 (fused im2col quantization — no
    // f32 patch matrix on this path) vs the f32 engine baseline.
    {
        let arch = Arch::minialexnet();
        let mut params = HashMap::new();
        for l in &arch.layers {
            let (wshape, blen): (Vec<usize>, usize) = match *l {
                Layer::Conv { cin, cout, k, .. } => (vec![cout, cin, k, k], cout),
                Layer::Fc { cin, cout, .. } => (vec![cin, cout], cout),
            };
            let nn: usize = wshape.iter().product();
            params.insert(
                format!("{}.w", l.name()),
                Tensor::new(&wshape, rng.normal_vec(nn).iter().map(|v| v * 0.1).collect()),
            );
            params.insert(format!("{}.b", l.name()), Tensor::new(&[blen], rng.normal_vec(blen)));
        }
        let eng = Engine::from_params(arch, params).expect("bench engine");
        let batch = 8usize;
        let x = Tensor::new(&[batch, 3, 32, 32], rng.uniform_vec(batch * 3 * 32 * 32, 0.0, 1.0));
        let label = "conv-fwd minialexnet b8";
        let t_fwd_f32 = time(iters, || {
            std::hint::black_box(eng.forward(&x, Precision::F32));
        });
        records.push(Record {
            case: label,
            kernel: "engine-f32".into(),
            impl_name: "-".into(),
            secs: t_fwd_f32,
            gmacs: 0.0,
            speedup_vs_f32: 1.0,
            speedup_vs_naive: 0.0,
            speedup_vs_scalar: 0.0,
        });
        print_row(records.last().unwrap());
        let t_fwd_lq8 = time(iters, || {
            std::hint::black_box(eng.forward(&x, Precision::lq(8)));
        });
        records.push(Record {
            case: label,
            kernel: "engine-lq8(fused-im2col)".into(),
            impl_name: simd::active().name.into(),
            secs: t_fwd_lq8,
            gmacs: 0.0,
            speedup_vs_f32: t_fwd_f32 / t_fwd_lq8,
            speedup_vs_naive: 0.0,
            speedup_vs_scalar: 0.0,
        });
        print_row(records.last().unwrap());
    }

    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gemm.json");
    write_json(json_path, threads, iters, &records);
}
