//! Micro-benchmarks of the GEMM ladder — the §Perf profiling tool.
//!
//! Times f32 / naive-i8 / panel-i8 / packed / LUT GEMMs on layer-shaped
//! problems and reports effective GMAC/s, plus the runtime activation-
//! quantization pass and the one-off weight-panel prep the engine caches.
//! `LQR_BENCH_ITERS` overrides the per-case iteration count (default 5).
//!
//! Besides the table on stdout, writes `BENCH_gemm.json` at the repo root
//! so the perf trajectory is machine-readable across PRs: one record per
//! (case, kernel) with ms, GMAC/s, speedup vs the blocked f32 baseline and
//! speedup vs the seed's naive general-region i8 path.

use std::time::Instant;

use lqr::fixedpoint::gemm_lut::gemm_lut;
use lqr::fixedpoint::gemm_packed::PackedMatrix;
use lqr::fixedpoint::panel::{gemm_lut_panel, gemm_panel, gemm_panel_packed, WeightPanel};
use lqr::fixedpoint::{gemm_f32, gemm_quantized_naive};
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::Tensor;
use lqr::util::json::Json;
use lqr::util::rng::Rng;

fn gmacs(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    (m * k * n) as f64 / secs / 1e9
}

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

struct Record {
    case: &'static str,
    kernel: String,
    /// Seconds per call (serialized as milliseconds).
    secs: f64,
    gmacs: f64,
    speedup_vs_f32: f64,
    /// vs the seed naive general-region i8 path at the same activation bits
    /// (0.0 when not applicable, e.g. the f32 / naive rows themselves).
    speedup_vs_naive: f64,
}

fn print_row(r: &Record) {
    println!(
        "{:<34} {:>10.3} {:>10.2} {:>9.2}x {:>9}",
        format!("{} {}", r.case, r.kernel),
        r.secs * 1e3,
        r.gmacs,
        r.speedup_vs_f32,
        if r.speedup_vs_naive > 0.0 {
            format!("{:.2}x", r.speedup_vs_naive)
        } else {
            "-".to_string()
        }
    );
}

fn write_json(path: &str, threads: usize, iters: usize, records: &[Record]) {
    let cases: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("case", Json::str(r.case)),
                ("kernel", Json::str(r.kernel.clone())),
                ("ms", Json::num(r.secs * 1e3)),
                ("gmacs", Json::num(r.gmacs)),
                ("speedup_vs_f32", Json::num(r.speedup_vs_f32)),
                ("speedup_vs_naive", Json::num(r.speedup_vs_naive)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("gemm_micro")),
        ("threads", Json::num(threads as f64)),
        ("iters", Json::num(iters as f64)),
        ("cases", Json::Arr(cases)),
    ]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let iters: usize = std::env::var("LQR_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    println!("gemm micro-bench (iters={iters}, threads={threads})");
    println!(
        "{:<34} {:>10} {:>10} {:>10} {:>9}",
        "case", "ms", "GMAC/s", "vs f32", "vs naive"
    );

    let mut rng = Rng::new(1);
    let mut records: Vec<Record> = Vec::new();
    // Layer-shaped cases: (label, M, K, N) from the mini models' conv GEMMs.
    for &(label, m, k, n) in &[
        ("conv1 1024x75x32", 1024usize, 75usize, 32usize),
        ("conv2 256x800x64", 256, 800, 64),
        ("fc 8x2048x256", 8, 2048, 256),
    ] {
        let a = Tensor::new(&[m, k], rng.uniform_vec(m * k, 0.0, 1.0));
        let w_t = Tensor::new(&[n, k], rng.normal_vec(n * k));
        let w = w_t.transpose2();

        let t_f32 = time(iters, || {
            std::hint::black_box(gemm_f32(&a, &w, threads));
        });
        records.push(Record {
            case: label,
            kernel: "f32".into(),
            secs: t_f32,
            gmacs: gmacs(m, k, n, t_f32),
            speedup_vs_f32: 1.0,
            speedup_vs_naive: 0.0,
        });
        print_row(records.last().unwrap());

        let wq = quantize_matrix(&w_t, 8, RegionSpec::PerRow);
        let wpanel = WeightPanel::from_quantized(&wq);
        for bits in [8u8, 2] {
            let aq = quantize_matrix(&a, bits, RegionSpec::PerRow);

            // Seed baseline: scalar dot per (i, j, region).
            let t_naive = time(iters, || {
                std::hint::black_box(gemm_quantized_naive(&aq, &wq, threads));
            });
            records.push(Record {
                case: label,
                kernel: format!("i8-naive(a{bits})"),
                secs: t_naive,
                gmacs: gmacs(m, k, n, t_naive),
                speedup_vs_f32: t_f32 / t_naive,
                speedup_vs_naive: 0.0,
            });
            print_row(records.last().unwrap());

            // Panel core over a cached panel — the engine's steady state.
            let t_panel = time(iters, || {
                std::hint::black_box(gemm_panel(&aq, &wpanel, threads));
            });
            records.push(Record {
                case: label,
                kernel: format!("i8-panel(a{bits})"),
                secs: t_panel,
                gmacs: gmacs(m, k, n, t_panel),
                speedup_vs_f32: t_f32 / t_panel,
                speedup_vs_naive: t_naive / t_panel,
            });
            print_row(records.last().unwrap());

            if bits == 2 {
                let t_lut = time(iters, || {
                    std::hint::black_box(gemm_lut_panel(&aq, &wpanel, threads));
                });
                records.push(Record {
                    case: label,
                    kernel: "lut-panel(a2)".into(),
                    secs: t_lut,
                    gmacs: gmacs(m, k, n, t_lut),
                    speedup_vs_f32: t_f32 / t_lut,
                    speedup_vs_naive: t_naive / t_lut,
                });
                print_row(records.last().unwrap());
                // Legacy entry point (panel built per call) for reference.
                let t_lut_entry = time(iters, || {
                    std::hint::black_box(gemm_lut(&aq, &wq, threads));
                });
                records.push(Record {
                    case: label,
                    kernel: "lut(a2,prep incl)".into(),
                    secs: t_lut_entry,
                    gmacs: gmacs(m, k, n, t_lut_entry),
                    speedup_vs_f32: t_f32 / t_lut_entry,
                    speedup_vs_naive: t_naive / t_lut_entry,
                });
                print_row(records.last().unwrap());

                let ap = PackedMatrix::from_quantized(&aq);
                let wp_packed = WeightPanel::from_packed(&PackedMatrix::from_quantized(&wq));
                let t_p = time(iters, || {
                    std::hint::black_box(gemm_panel_packed(&ap, &wp_packed, threads));
                });
                records.push(Record {
                    case: label,
                    kernel: "packed-panel(a2)".into(),
                    secs: t_p,
                    gmacs: gmacs(m, k, n, t_p),
                    speedup_vs_f32: t_f32 / t_p,
                    speedup_vs_naive: t_naive / t_p,
                });
                print_row(records.last().unwrap());
            }
        }

        // One-off costs the engine amortizes: panel prep (cached per layer)
        // and the runtime activation-quantization pass (per batch).
        let t_prep = time(iters, || {
            std::hint::black_box(WeightPanel::from_quantized(&wq));
        });
        records.push(Record {
            case: label,
            kernel: "panel-prep(w)".into(),
            secs: t_prep,
            gmacs: 0.0,
            speedup_vs_f32: 0.0,
            speedup_vs_naive: 0.0,
        });
        print_row(records.last().unwrap());
        let t_quant = time(iters, || {
            std::hint::black_box(quantize_matrix(&a, 8, RegionSpec::PerRow));
        });
        println!(
            "{:<34} {:>10.3} {:>10} {:>10} {:>9}",
            format!("{label} quantize(a)"),
            t_quant * 1e3,
            "-",
            format!("{:.1}%", 100.0 * t_quant / t_f32),
            "-"
        );
        records.push(Record {
            case: label,
            kernel: "quantize(a8)".into(),
            secs: t_quant,
            gmacs: 0.0,
            speedup_vs_f32: 0.0,
            speedup_vs_naive: 0.0,
        });
    }

    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gemm.json");
    write_json(json_path, threads, iters, &records);
}
