//! Micro-benchmarks of the GEMM ladder — the §Perf profiling tool.
//!
//! Times f32 / eq.7-i8 / packed / LUT GEMMs on layer-shaped problems and
//! reports effective GMAC/s, plus the runtime activation-quantization pass.
//! `LQR_BENCH_ITERS` overrides the per-case iteration count (default 5).

use std::time::Instant;

use lqr::fixedpoint::gemm_lut::gemm_lut;
use lqr::fixedpoint::gemm_packed::{gemm_packed, PackedMatrix};
use lqr::fixedpoint::{gemm_f32, gemm_quantized};
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::Tensor;
use lqr::util::rng::Rng;

fn gmacs(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    (m * k * n) as f64 / secs / 1e9
}

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let iters: usize = std::env::var("LQR_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    println!("gemm micro-bench (iters={iters}, threads={threads})");
    println!("{:<28} {:>10} {:>10} {:>10}", "case", "ms", "GMAC/s", "vs f32");

    let mut rng = Rng::new(1);
    // Layer-shaped cases: (label, M, K, N) from the mini models' conv GEMMs.
    for &(label, m, k, n) in &[
        ("conv1 1024x75x32", 1024usize, 75usize, 32usize),
        ("conv2 256x800x64", 256, 800, 64),
        ("fc 8x2048x256", 8, 2048, 256),
    ] {
        let a = Tensor::new(&[m, k], rng.uniform_vec(m * k, 0.0, 1.0));
        let w_t = Tensor::new(&[n, k], rng.normal_vec(n * k));
        let w = w_t.transpose2();

        let t_f32 = time(iters, || {
            std::hint::black_box(gemm_f32(&a, &w, threads));
        });
        println!(
            "{:<28} {:>10.3} {:>10.2} {:>10}",
            format!("{label} f32"),
            t_f32 * 1e3,
            gmacs(m, k, n, t_f32),
            "1.00x"
        );

        for bits in [8u8, 2] {
            let aq = quantize_matrix(&a, bits, RegionSpec::PerRow);
            let wq = quantize_matrix(&w_t, 8, RegionSpec::PerRow);
            let t_q = time(iters, || {
                std::hint::black_box(gemm_quantized(&aq, &wq, threads));
            });
            println!(
                "{:<28} {:>10.3} {:>10.2} {:>9.2}x",
                format!("{label} i8(a{bits})"),
                t_q * 1e3,
                gmacs(m, k, n, t_q),
                t_f32 / t_q
            );
            if bits == 2 {
                let t_lut = time(iters, || {
                    std::hint::black_box(gemm_lut(&aq, &wq, threads));
                });
                println!(
                    "{:<28} {:>10.3} {:>10.2} {:>9.2}x",
                    format!("{label} lut(a2)"),
                    t_lut * 1e3,
                    gmacs(m, k, n, t_lut),
                    t_f32 / t_lut
                );
                let ap = PackedMatrix::from_quantized(&aq);
                let wp = PackedMatrix::from_quantized(&wq);
                let t_p = time(iters, || {
                    std::hint::black_box(gemm_packed(&ap, &wp, threads));
                });
                println!(
                    "{:<28} {:>10.3} {:>10.2} {:>9.2}x",
                    format!("{label} packed(a2)"),
                    t_p * 1e3,
                    gmacs(m, k, n, t_p),
                    t_f32 / t_p
                );
            }
        }

        // Runtime activation quantization cost (the paper's overhead term).
        let t_quant = time(iters, || {
            std::hint::black_box(quantize_matrix(&a, 8, RegionSpec::PerRow));
        });
        println!(
            "{:<28} {:>10.3} {:>10} {:>10}",
            format!("{label} quantize(a)"),
            t_quant * 1e3,
            "-",
            format!("{:.1}%", 100.0 * t_quant / t_f32)
        );
    }
}
