//! Bench: Fig. 10 — 2-bit accuracy vs LQ region size (MiniVGG).
//!
//! `LQR_BENCH_LIMIT` = validation images (default 512).

fn main() {
    let limit = std::env::var("LQR_BENCH_LIMIT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let artifacts = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match lqr::eval::sweep::fig10(&artifacts, &[27, 9, 3], limit) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("fig10_region_sweep skipped: {e:#} (run `make artifacts`)"),
    }
}
