//! Bench: Table 3 — conv multiply/add counts, original vs 2-bit LUT.
//! Purely analytic (full AlexNet / VGG-16); matches the paper's numbers.

fn main() {
    lqr::eval::sweep::table3().print();
}
