//! Bench: Fig. 2 — fixed-point quantization transfer + error curves.
//! Prints the staircase/sawtooth samples and verifies max|err| == step/2.

fn main() {
    print!("{}", lqr::quant::curves::render_curve_table(&[2, 4, 8], 17));
}
