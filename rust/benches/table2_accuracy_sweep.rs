//! Bench: Table 2 / Fig. 9 — DQ vs LQ accuracy across 8/6/4/2-bit inputs.
//!
//! `LQR_BENCH_LIMIT` = validation images (default 512).

fn main() {
    let limit = std::env::var("LQR_BENCH_LIMIT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let artifacts = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match lqr::eval::sweep::table2(&artifacts, &[8, 6, 4, 2], limit) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("table2_accuracy_sweep skipped: {e:#} (run `make artifacts`)"),
    }
}
