//! Bench: Table 1 — top-1/top-5 accuracy, f32 vs 8-bit LQ (both models).
//!
//! `LQR_BENCH_LIMIT` = validation images (default 512).

fn main() {
    let limit = std::env::var("LQR_BENCH_LIMIT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let artifacts = std::env::var("LQR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match lqr::eval::sweep::table1(&artifacts, limit) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("table1_accuracy skipped: {e:#} (run `make artifacts`)"),
    }
}
