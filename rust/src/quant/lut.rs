//! §V — look-up-table scheme: replace multiply-accumulate with table-indexed
//! adds when activation precision is extremely low (<= 4 bits; the paper
//! demonstrates 2 bits).
//!
//! Two equivalent formulations are provided, both exactly equal to the
//! integer dot product `sum_k qa_k * qw_k`:
//!
//! 1. **Code bucketing** ([`bucketed_dot`]): one pass over the region adds
//!    each weight code into the bucket of its paired activation code
//!    (adds/selects only), then `sum_c c * B_c` — `2^bits - 2` multiplies
//!    per region instead of K (c = 0 contributes nothing, c = 1 is free).
//!    This is what Figure 5's datapath computes.
//! 2. **Weight tables** ([`WeightLut`]): offline, per weight position, store
//!    `w * c` for every code c (the "indexed values ... stored in one
//!    look-up table"); runtime indexes by the activation code and adds.
//!    Multiplies happen once at table-build time and amortize across every
//!    reuse of the weights (conv kernels are reused per output position).
//!
//! Op-count accounting that regenerates Table 3 lives in `nn::opcount` and
//! references the constants of formulation 1.

/// Exact integer dot product via code bucketing.
///
/// `qa` are activation codes in [0, 2^bits); `qw` are weight codes (any i32
/// range — typically dequant-pending 8-bit codes).
pub fn bucketed_dot(qa: &[u8], qw: &[i32], bits: u8) -> i64 {
    assert_eq!(qa.len(), qw.len());
    assert!((1..=4).contains(&bits), "LUT scheme needs <= 4-bit activations");
    let levels = 1usize << bits;
    let mut buckets = [0i64; 16];
    for (&a, &w) in qa.iter().zip(qw) {
        buckets[a as usize] += w as i64; // add-only inner loop
    }
    let mut acc = 0i64;
    for (c, &b) in buckets.iter().enumerate().take(levels).skip(1) {
        acc += (c as i64) * b; // 2^bits - 1 multiplies (c=1 free in hardware)
    }
    acc
}

/// Upper bound on `2^bits` for the LUT scheme (bits <= 4): bucket arrays are
/// sized statically so they live in registers / L1.
pub const MAX_CODES: usize = 16;

/// Tile-wide code bucketing for the panel GEMM (`fixedpoint::panel`).
///
/// One add-only pass over a region segment of an `NR`-wide K-major weight
/// tile (`wseg[p][jj]`, `qa.len() * NR` bytes): each weight line is added
/// into the bucket of its paired activation code. Together with
/// [`collapse_buckets`] this equals [`bucketed_dot`] per tile column, but
/// buckets `NR` output channels in a single pass instead of one `(i, j)`
/// pair at a time. This is the portable arm of the bucketing dispatch
/// (`fixedpoint::simd` carries an AVX2 variant of the same pass).
pub fn bucket_panel_segment<const NR: usize>(
    qa: &[u8],
    wseg: &[u8],
    buckets: &mut [[i32; NR]; MAX_CODES],
) {
    debug_assert_eq!(qa.len() * NR, wseg.len());
    for (pi, &c) in qa.iter().enumerate() {
        let wline = &wseg[pi * NR..(pi + 1) * NR];
        let bucket = &mut buckets[c as usize];
        for (dst, &w) in bucket.iter_mut().zip(wline) {
            *dst += w as i32; // add-only inner loop (paper Fig. 5 datapath)
        }
    }
}

/// Collapse buckets to the integer dot product per lane:
/// `qq[jj] = sum_c c * buckets[c][jj]` — `2^bits - 2` multiplies per lane
/// (c = 0 contributes nothing, c = 1 is free in hardware).
pub fn collapse_buckets<const NR: usize>(
    buckets: &[[i32; NR]; MAX_CODES],
    levels: usize,
) -> [i32; NR] {
    let mut qq = [0i32; NR];
    for (c, bucket) in buckets.iter().enumerate().take(levels).skip(1) {
        let cf = c as i32;
        for (dst, &b) in qq.iter_mut().zip(bucket) {
            *dst += cf * b;
        }
    }
    qq
}

/// Offline weight table: `table[k][c] = qw[k] * c` for c in [0, 2^bits).
/// Row-major `(k, levels)`; built once per weight region, reused across all
/// activations that contract with it.
#[derive(Debug, Clone)]
pub struct WeightLut {
    /// Activation code width (1..=4).
    pub bits: u8,
    /// Number of weight positions covered by the table.
    pub k: usize,
    table: Vec<i32>,
}

impl WeightLut {
    /// Build the table offline: `2^bits` precomputed products per weight.
    pub fn build(qw: &[i32], bits: u8) -> WeightLut {
        assert!((1..=4).contains(&bits));
        let levels = 1usize << bits;
        let mut table = Vec::with_capacity(qw.len() * levels);
        for &w in qw {
            for c in 0..levels {
                table.push(w * c as i32); // the only multiplies in the scheme
            }
        }
        WeightLut { bits, k: qw.len(), table }
    }

    /// Runtime dot product: pure table lookups + adds, zero multiplies.
    pub fn dot(&self, qa: &[u8]) -> i64 {
        assert_eq!(qa.len(), self.k);
        let levels = 1usize << self.bits;
        let mut acc = 0i64;
        for (k, &a) in qa.iter().enumerate() {
            acc += self.table[k * levels + a as usize] as i64;
        }
        acc
    }

    /// Table footprint in bytes (paper: "the table size is relatively small
    /// if the quantization precision is low enough").
    pub fn bytes(&self) -> usize {
        self.table.len() * std::mem::size_of::<i32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn ref_dot(qa: &[u8], qw: &[i32]) -> i64 {
        qa.iter().zip(qw).map(|(&a, &w)| a as i64 * w as i64).sum()
    }

    #[test]
    fn bucketed_equals_reference() {
        prop::check("lut-bucketed-exact", 0x1007, |rng, _| {
            let bits = [1u8, 2, 3, 4][rng.below(4) as usize];
            let n = rng.index(0, 400);
            let qa: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let qw: Vec<i32> = (0..n).map(|_| rng.below(256) as i32 - 128).collect();
            assert_eq!(bucketed_dot(&qa, &qw, bits), ref_dot(&qa, &qw));
        });
    }

    #[test]
    fn weight_table_equals_reference() {
        prop::check("lut-table-exact", 0x1008, |rng, _| {
            let bits = [1u8, 2, 4][rng.below(3) as usize];
            let n = rng.index(1, 200);
            let qw: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();
            let lut = WeightLut::build(&qw, bits);
            let qa: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            assert_eq!(lut.dot(&qa), ref_dot(&qa, &qw));
        });
    }

    #[test]
    fn table_size_scales_with_bits() {
        let qw = vec![1i32; 100];
        assert_eq!(WeightLut::build(&qw, 2).bytes(), 100 * 4 * 4);
        assert_eq!(WeightLut::build(&qw, 4).bytes(), 100 * 16 * 4);
    }

    #[test]
    fn empty_dot() {
        assert_eq!(bucketed_dot(&[], &[], 2), 0);
    }

    #[test]
    fn tile_bucketing_equals_bucketed_dot_per_column() {
        const NR: usize = 8;
        prop::check("lut-tile-bucketing", 0x1009, |rng, _| {
            let bits = [1u8, 2, 4][rng.below(3) as usize];
            let len = rng.index(0, 120);
            let qa: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
            // K-major NR-wide tile of u8 weight codes.
            let wseg: Vec<u8> = (0..len * NR).map(|_| rng.below(256) as u8).collect();
            let mut buckets = [[0i32; NR]; MAX_CODES];
            bucket_panel_segment::<NR>(&qa, &wseg, &mut buckets);
            let qq = collapse_buckets::<NR>(&buckets, 1 << bits);
            for jj in 0..NR {
                let col: Vec<i32> = (0..len).map(|p| wseg[p * NR + jj] as i32).collect();
                assert_eq!(
                    qq[jj] as i64,
                    bucketed_dot(&qa, &col, bits),
                    "bits={bits} len={len} jj={jj}"
                );
            }
        });
    }
}
