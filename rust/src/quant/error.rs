//! Quantization-error analysis (paper §IV.A, Fig. 2).
//!
//! Quantifies how the error shrinks as regions shrink — the mechanism behind
//! every accuracy result in §VI — and feeds the ablation bench.

use crate::quant::{quantize_matrix, RegionSpec};
use crate::tensor::Tensor;

/// Error statistics of a quantize-dequantize round trip.
#[derive(Debug, Clone)]
pub struct QuantErrorStats {
    /// Code width the round trip used.
    pub bits: u8,
    /// Region geometry the round trip used.
    pub region: RegionSpec,
    /// Largest |x - Q^-1(Q(x))|.
    pub max_abs: f32,
    /// Root mean squared error.
    pub rmse: f32,
    /// Largest quantization step across regions (error bound = step/2).
    pub max_step: f32,
    /// Signal-to-quantization-noise ratio in dB (10 log10 E[x^2]/E[e^2]).
    pub sqnr_db: f32,
}

impl QuantErrorStats {
    /// Quantize-dequantize `x` and collect the error statistics.
    pub fn measure(x: &Tensor, bits: u8, region: RegionSpec) -> QuantErrorStats {
        let q = quantize_matrix(x, bits, region);
        let dq = q.dequantize();
        let n = x.len() as f64;
        let mut max_abs = 0.0f32;
        let mut se = 0.0f64;
        let mut sx = 0.0f64;
        for (a, b) in x.data().iter().zip(dq.data()) {
            let e = a - b;
            max_abs = max_abs.max(e.abs());
            se += (e * e) as f64;
            sx += (a * a) as f64;
        }
        let rmse = (se / n).sqrt() as f32;
        let sqnr_db = if se > 0.0 { (10.0 * (sx / se).log10()) as f32 } else { f32::INFINITY };
        let max_step = q.scales.iter().cloned().fold(0.0f32, f32::max);
        QuantErrorStats { bits, region, max_abs, rmse, max_step, sqnr_db }
    }

    /// The theoretical per-element bound: half the largest step.
    pub fn bound(&self) -> f32 {
        self.max_step / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian(rows: usize, k: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(&[rows, k], rng.normal_vec(rows * k))
    }

    #[test]
    fn error_within_bound() {
        let x = gaussian(16, 64, 1);
        for bits in [2u8, 4, 8] {
            let s = QuantErrorStats::measure(&x, bits, RegionSpec::Size(8));
            assert!(s.max_abs <= s.bound() * 1.0001, "bits={bits}");
        }
    }

    #[test]
    fn more_bits_less_error() {
        let x = gaussian(16, 64, 2);
        let e2 = QuantErrorStats::measure(&x, 2, RegionSpec::PerRow).rmse;
        let e4 = QuantErrorStats::measure(&x, 4, RegionSpec::PerRow).rmse;
        let e8 = QuantErrorStats::measure(&x, 8, RegionSpec::PerRow).rmse;
        assert!(e8 < e4 && e4 < e2, "rmse should fall with bits: {e2} {e4} {e8}");
    }

    #[test]
    fn smaller_regions_less_error() {
        // Fig. 10's mechanism: shrinking g shrinks the realized error.
        let x = gaussian(8, 128, 3);
        let bits = 2;
        let e_dq = QuantErrorStats::measure(&x, bits, RegionSpec::PerTensor).rmse;
        let e_row = QuantErrorStats::measure(&x, bits, RegionSpec::PerRow).rmse;
        let e_16 = QuantErrorStats::measure(&x, bits, RegionSpec::Size(16)).rmse;
        let e_4 = QuantErrorStats::measure(&x, bits, RegionSpec::Size(4)).rmse;
        assert!(e_row <= e_dq + 1e-7);
        assert!(e_16 <= e_row + 1e-7);
        assert!(e_4 <= e_16 + 1e-7);
    }

    #[test]
    fn sqnr_improves_6db_per_bit_roughly() {
        // Classic result: +1 bit ~ +6 dB SQNR on smooth data.
        let x = gaussian(32, 256, 4);
        let s4 = QuantErrorStats::measure(&x, 4, RegionSpec::PerRow).sqnr_db;
        let s5 = QuantErrorStats::measure(&x, 5, RegionSpec::PerRow).sqnr_db;
        let gain = s5 - s4;
        assert!((3.0..9.0).contains(&gain), "per-bit SQNR gain {gain} dB");
    }
}
