//! Paper Fig. 2 — fixed-point quantization transfer curves and error curves.
//!
//! Generates the staircase `Q^-1(Q(x))` transfer function and the sawtooth
//! error `x - Q^-1(Q(x))` over a swept input range, for any bit width —
//! the illustration behind eq. (3)-(5) — plus the derived summary the rest
//! of the paper builds on: max error == step/2 == span / (2 (2^n - 1)).

/// One sampled point of the transfer/error curves.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// Swept input value.
    pub x: f32,
    /// Quantize-dequantize reconstruction of x.
    pub q: f32,
    /// Error x - q.
    pub err: f32,
}

/// Sample the quantization curves for inputs in [lo, hi] with `n` points,
/// quantized to `bits` over the same [lo, hi] range (the paper normalizes
/// the region's [x_min, x_max] to the full code range).
pub fn quant_curve(lo: f32, hi: f32, bits: u8, n: usize) -> Vec<CurvePoint> {
    assert!(hi > lo && n >= 2 && (1..=16).contains(&bits));
    let levels = ((1u32 << bits) - 1) as f32;
    let s = (hi - lo) / levels;
    (0..n)
        .map(|i| {
            let x = lo + (hi - lo) * i as f32 / (n - 1) as f32;
            let code = ((x - lo) / s).round_ties_even().clamp(0.0, levels);
            let q = code * s + lo;
            CurvePoint { x, q, err: x - q }
        })
        .collect()
}

/// The step size eq. (5): s = (max - min) / (2^n - 1).
pub fn step(lo: f32, hi: f32, bits: u8) -> f32 {
    (hi - lo) / ((1u32 << bits) - 1) as f32
}

/// Render the curves as a fixed-width ASCII table (the bench prints this).
pub fn render_curve_table(bits_list: &[u8], n: usize) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "Fig. 2 — quantization transfer + error curves over [-1, 1]").unwrap();
    writeln!(out, "{:>8} {}", "x", bits_list.iter().map(|b| format!("{:>10} {:>10}", format!("Q{b}(x)"), format!("err{b}"))).collect::<Vec<_>>().join(" ")).unwrap();
    for i in 0..n {
        let x = -1.0 + 2.0 * i as f32 / (n - 1) as f32;
        write!(out, "{x:>8.3}").unwrap();
        for &b in bits_list {
            let p = quant_curve(-1.0, 1.0, b, n)[i];
            write!(out, " {:>10.4} {:>10.4}", p.q, p.err).unwrap();
        }
        out.push('\n');
    }
    for &b in bits_list {
        writeln!(
            out,
            "bits={b}: step={:.5}  max|err|={:.5}  (= step/2: {})",
            step(-1.0, 1.0, b),
            quant_curve(-1.0, 1.0, b, 2001)
                .iter()
                .map(|p| p.err.abs())
                .fold(0.0f32, f32::max),
            step(-1.0, 1.0, b) / 2.0
        )
        .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staircase_is_monotone_and_bounded() {
        for bits in [1u8, 2, 4, 8] {
            let pts = quant_curve(-1.0, 1.0, bits, 501);
            let s = step(-1.0, 1.0, bits);
            for w in pts.windows(2) {
                assert!(w[1].q >= w[0].q, "staircase must be monotone");
            }
            for p in &pts {
                assert!(
                    p.err.abs() <= s / 2.0 + 1e-6,
                    "bits={bits}: err {} > step/2 {}",
                    p.err,
                    s / 2.0
                );
            }
        }
    }

    #[test]
    fn endpoints_exact() {
        // The code range is anchored at x_min and x_max: both reconstruct
        // exactly (Fig. 2's curves pass through the corners).
        for bits in [2u8, 4, 8] {
            let pts = quant_curve(-1.0, 1.0, bits, 101);
            assert_eq!(pts[0].q, -1.0);
            assert_eq!(pts.last().unwrap().q, 1.0);
        }
    }

    #[test]
    fn error_sawtooth_period_is_step() {
        // Adjacent error-zero crossings are one step apart.
        let bits = 3u8;
        let s = step(0.0, 7.0, bits); // = 1.0 exactly
        assert_eq!(s, 1.0);
        let pts = quant_curve(0.0, 7.0, bits, 701);
        let zeros: Vec<f32> = pts.iter().filter(|p| p.err.abs() < 1e-3).map(|p| p.x).collect();
        // Zeros at 0, 1, 2, ..., 7.
        assert!(zeros.iter().any(|&z| (z - 3.0).abs() < 0.02));
        assert!(zeros.iter().any(|&z| (z - 4.0).abs() < 0.02));
    }

    #[test]
    fn more_bits_halve_the_step() {
        assert!((step(-1.0, 1.0, 4) / step(-1.0, 1.0, 5) - 2.0).abs() < 0.07);
    }

    #[test]
    fn table_renders() {
        let t = render_curve_table(&[2, 4], 9);
        assert!(t.contains("Fig. 2"));
        assert!(t.contains("bits=2"));
    }
}
