//! Streaming range calibration.
//!
//! The paper quantizes inputs at runtime by computing each region's min/max
//! on the fly (§V.B). On devices where even that pass is too expensive, a
//! common deployment alternative is *calibrated* quantization: observe
//! ranges over a calibration stream and freeze them. This module provides
//! the observer (exact and EMA-smoothed) plus a frozen-range quantizer, and
//! the tests quantify the accuracy cost vs true runtime min/max — an
//! ablation of the paper's design choice to pay the runtime pass.

use crate::quant::region::RegionSpec;
use crate::quant::scheme::{round_half_even, QuantizedMatrix};
use crate::tensor::Tensor;

/// Observes per-region ranges over a stream of `(rows, K)` batches.
/// Regions follow the same geometry as [`crate::quant::quantize_matrix`],
/// but ranges are tracked per *column region* (shared across rows), since a
/// frozen calibration cannot depend on the individual row.
#[derive(Debug, Clone)]
pub struct RangeObserver {
    /// Reduction length the observed batches must match.
    pub k: usize,
    /// Region geometry (column regions, shared across rows).
    pub region: RegionSpec,
    /// EMA momentum in [0, 1): 0 = exact running min/max.
    pub momentum: f32,
    mins: Vec<f32>,
    maxs: Vec<f32>,
    observed: usize,
}

impl RangeObserver {
    /// Fresh observer with empty (infinite) ranges.
    pub fn new(k: usize, region: RegionSpec, momentum: f32) -> RangeObserver {
        assert!((0.0..1.0).contains(&momentum));
        let rpr = region.regions_per_row(k);
        RangeObserver {
            k,
            region,
            momentum,
            mins: vec![f32::INFINITY; rpr],
            maxs: vec![f32::NEG_INFINITY; rpr],
            observed: 0,
        }
    }

    /// Feed one batch.
    pub fn observe(&mut self, x: &Tensor) {
        assert_eq!(x.dim(1), self.k);
        let g = self.region.group_len(self.k);
        let rpr = self.region.regions_per_row(self.k);
        for row in 0..x.dim(0) {
            let xr = x.row(row);
            for r in 0..rpr {
                let seg = &xr[r * g..((r + 1) * g).min(self.k)];
                let mn = seg.iter().fold(f32::INFINITY, |m, &v| m.min(v));
                let mx = seg.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                if self.observed == 0 || self.momentum == 0.0 {
                    self.mins[r] = self.mins[r].min(mn);
                    self.maxs[r] = self.maxs[r].max(mx);
                } else {
                    let a = self.momentum;
                    self.mins[r] = a * self.mins[r] + (1.0 - a) * mn;
                    self.maxs[r] = a * self.maxs[r] + (1.0 - a) * mx;
                }
            }
        }
        self.observed += x.dim(0);
    }

    /// Freeze into a calibrated quantizer.
    pub fn freeze(&self, bits: u8) -> CalibratedQuantizer {
        assert!(self.observed > 0, "freeze() before any observation");
        CalibratedQuantizer {
            k: self.k,
            region: self.region,
            bits,
            mins: self.mins.clone(),
            maxs: self.maxs.clone(),
        }
    }
}

/// Quantizes with frozen per-region ranges (no runtime min/max pass).
/// Out-of-range values saturate to the code range.
#[derive(Debug, Clone)]
pub struct CalibratedQuantizer {
    /// Reduction length the quantized batches must match.
    pub k: usize,
    /// Region geometry the ranges were calibrated with.
    pub region: RegionSpec,
    /// Code width in bits (1..=8).
    pub bits: u8,
    mins: Vec<f32>,
    maxs: Vec<f32>,
}

impl CalibratedQuantizer {
    /// Quantize a `(rows, K)` batch with the frozen ranges (no min/max pass).
    pub fn quantize(&self, x: &Tensor) -> QuantizedMatrix {
        assert_eq!(x.dim(1), self.k);
        let rows = x.dim(0);
        let g = self.region.group_len(self.k);
        let rpr = self.region.regions_per_row(self.k);
        let levels = ((1u32 << self.bits) - 1) as f32;
        let mut codes = vec![0u8; rows * self.k];
        let mut scales = vec![0.0f32; rows * rpr];
        let mut mins = vec![0.0f32; rows * rpr];
        let mut code_sums = vec![0.0f32; rows * rpr];
        for row in 0..rows {
            let xr = x.row(row);
            for r in 0..rpr {
                let span = self.maxs[r] - self.mins[r];
                let s = if span > 0.0 { span / levels } else { 1.0 };
                scales[row * rpr + r] = s;
                mins[row * rpr + r] = self.mins[r];
                let start = r * g;
                let end = ((r + 1) * g).min(self.k);
                let mut sum = 0u32;
                for j in start..end {
                    let q = round_half_even((xr[j] - self.mins[r]) / s).clamp(0.0, levels) as u8;
                    codes[row * self.k + j] = q;
                    sum += q as u32;
                }
                code_sums[row * rpr + r] = sum as f32;
            }
        }
        QuantizedMatrix {
            rows,
            k: self.k,
            bits: self.bits,
            region: self.region,
            codes,
            scales,
            mins,
            code_sums,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn batch(rng: &mut Rng, rows: usize, k: usize) -> Tensor {
        Tensor::new(&[rows, k], rng.normal_vec(rows * k))
    }

    #[test]
    fn exact_observer_covers_stream() {
        let mut rng = Rng::new(1);
        let mut obs = RangeObserver::new(16, RegionSpec::Size(4), 0.0);
        let batches: Vec<Tensor> = (0..5).map(|_| batch(&mut rng, 8, 16)).collect();
        for b in &batches {
            obs.observe(b);
        }
        let q = obs.freeze(8);
        // Every element quantizes without saturating the code range badly:
        // reconstruct within one step of the original.
        for b in &batches {
            let qm = q.quantize(b);
            let dq = qm.dequantize();
            let max_step = qm.scales.iter().cloned().fold(0.0f32, f32::max);
            assert!(dq.max_abs_diff(b) <= max_step / 2.0 + 1e-5);
        }
    }

    #[test]
    fn unseen_outliers_saturate() {
        let mut obs = RangeObserver::new(4, RegionSpec::Size(4), 0.0);
        obs.observe(&Tensor::new(&[1, 4], vec![0.0, 0.5, 1.0, 0.2]));
        let q = obs.freeze(8);
        let wild = Tensor::new(&[1, 4], vec![-5.0, 0.5, 10.0, 0.2]);
        let qm = q.quantize(&wild);
        assert_eq!(qm.codes[0], 0, "below-range saturates to code 0");
        assert_eq!(qm.codes[2], 255, "above-range saturates to max code");
    }

    #[test]
    fn ema_tracks_shifting_range() {
        let mut obs = RangeObserver::new(4, RegionSpec::PerRow, 0.9);
        for i in 0..200 {
            let v = 1.0 + i as f32 * 0.01;
            obs.observe(&Tensor::new(&[1, 4], vec![-v, 0.0, v, 0.1]));
        }
        let q = obs.freeze(8);
        // EMA should have converged near the final range (~3.0 wide), not
        // stuck at the first batch (~2.0 wide).
        let qm = q.quantize(&Tensor::new(&[1, 4], vec![-2.9, 0.0, 2.9, 0.0]));
        let dq = qm.dequantize();
        assert!(dq.max_abs_diff(&Tensor::new(&[1, 4], vec![-2.9, 0.0, 2.9, 0.0])) < 0.2);
    }

    #[test]
    fn calibrated_worse_than_runtime_minmax() {
        // The ablation: frozen shared ranges cannot beat the paper's
        // per-row runtime pass (which adapts to each patch).
        let mut rng = Rng::new(3);
        let train: Vec<Tensor> = (0..4).map(|_| batch(&mut rng, 16, 32)).collect();
        let mut obs = RangeObserver::new(32, RegionSpec::Size(8), 0.0);
        for b in &train {
            obs.observe(b);
        }
        let calib = obs.freeze(2);
        let test = batch(&mut rng, 32, 32);
        let e_calib = calib.quantize(&test).dequantize().max_abs_diff(&test);
        let e_runtime = crate::quant::fake_quant(&test, 2, RegionSpec::Size(8)).max_abs_diff(&test);
        assert!(
            e_runtime <= e_calib,
            "runtime min/max ({e_runtime}) should beat frozen calibration ({e_calib})"
        );
    }
}
