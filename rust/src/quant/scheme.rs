//! Quantize / dequantize with local regions (paper eq. 3–7).
//!
//! Mirrors `python/compile/quant.py` exactly, including numpy's
//! round-half-to-even, so codes computed here match the build-time python
//! side bit-for-bit (pinned by `rust/tests/quant_parity.rs`).

use crate::quant::region::RegionSpec;
use crate::tensor::Tensor;

/// numpy-compatible rounding: round half to even (IEEE roundTiesToEven —
/// a single `roundps` on x86, and exactly what `jnp.round` does).
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// A quantized `(rows, K)` operand: integer codes plus per-region affine
/// parameters. Codes are stored one-per-byte here (`u8`, bits <= 8); the
/// packed form for storage/footprint accounting lives in [`crate::quant::codec`].
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    /// Number of rows (activation rows / output channels).
    pub rows: usize,
    /// Reduction length (columns).
    pub k: usize,
    /// Code width in bits (1..=8).
    pub bits: u8,
    /// Region geometry the codes were quantized with.
    pub region: RegionSpec,
    /// rows * k codes in [0, 2^bits - 1], row-major.
    pub codes: Vec<u8>,
    /// Per-region scale s_k. Layout: rows * regions_per_row (PerTensor stores
    /// the single shared value replicated per row for uniform indexing).
    pub scales: Vec<f32>,
    /// Per-region minimum x_min.
    pub mins: Vec<f32>,
    /// Precomputed per-region code sums (sum of codes in the region) —
    /// the `S_qw` term of eq. 7, built offline for weights.
    pub code_sums: Vec<f32>,
}

impl QuantizedMatrix {
    /// Number of quantization regions along each row.
    pub fn regions_per_row(&self) -> usize {
        self.region.regions_per_row(self.k)
    }

    /// Effective region length along K (the tail region may be shorter).
    pub fn group_len(&self) -> usize {
        self.region.group_len(self.k)
    }

    /// Scale `s_k` of region `r` in `row`.
    #[inline]
    pub fn scale(&self, row: usize, r: usize) -> f32 {
        self.scales[row * self.regions_per_row() + r]
    }

    /// Minimum `x_min` of region `r` in `row`.
    #[inline]
    pub fn min(&self, row: usize, r: usize) -> f32 {
        self.mins[row * self.regions_per_row() + r]
    }

    /// Codes of row `i` (`k` bytes) — panel-building / kernel accessor.
    #[inline]
    pub fn row_codes(&self, i: usize) -> &[u8] {
        &self.codes[i * self.k..(i + 1) * self.k]
    }

    /// `(scales, mins, code_sums)` of row `i`: `regions_per_row`-long slices,
    /// region-indexed — the affine triple the panel correction consumes.
    #[inline]
    pub fn affine_row(&self, i: usize) -> (&[f32], &[f32], &[f32]) {
        let rpr = self.regions_per_row();
        let o = i * rpr;
        (&self.scales[o..o + rpr], &self.mins[o..o + rpr], &self.code_sums[o..o + rpr])
    }

    /// `(start, end)` bounds of region `r` along K (tail may be short).
    #[inline]
    pub fn region_bounds(&self, r: usize) -> (usize, usize) {
        let g = self.group_len();
        (r * g, ((r + 1) * g).min(self.k))
    }

    /// Reconstruct the f32 tensor (error <= s_k/2 per element).
    pub fn dequantize(&self) -> Tensor {
        let g = self.group_len();
        let rpr = self.regions_per_row();
        let mut out = vec![0.0f32; self.rows * self.k];
        for row in 0..self.rows {
            for r in 0..rpr {
                let s = self.scales[row * rpr + r];
                let m = self.mins[row * rpr + r];
                let start = r * g;
                let end = ((r + 1) * g).min(self.k);
                for j in start..end {
                    out[row * self.k + j] = self.codes[row * self.k + j] as f32 * s + m;
                }
            }
        }
        Tensor::new(&[self.rows, self.k], out)
    }

    /// Bytes needed for the packed representation (codes bit-packed +
    /// f32 scale/min pairs per region) — the paper's memory-saving claim.
    pub fn packed_bytes(&self) -> usize {
        let code_bits = self.rows * self.k * self.bits as usize;
        let side = if self.region.per_tensor() { 1 } else { self.rows * self.regions_per_row() };
        code_bits.div_ceil(8) + side * 8
    }
}

/// Region min/max: two separate folds — each vectorizes to vminps/vmaxps
/// reductions; a tuple fold would not.
#[inline]
pub(crate) fn region_minmax(seg: &[f32]) -> (f32, f32) {
    (
        seg.iter().fold(f32::INFINITY, |m, &v| m.min(v)),
        seg.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)),
    )
}

/// Encode one region segment given its min/max: writes the codes and returns
/// `(scale, code_sum)`. This is the single primitive both [`quantize_matrix`]
/// and the fused conv lowering (`fixedpoint::im2col::im2col_quantized`)
/// compile to, so the two paths stay bit-identical by construction.
///
/// NB: true division, not reciprocal-multiply — bit-exact parity with the
/// python reference is pinned by rust/tests/quant_parity.
#[inline]
pub(crate) fn encode_region(seg: &[f32], mn: f32, mx: f32, levels: f32, codes: &mut [u8]) -> (f32, f32) {
    debug_assert_eq!(seg.len(), codes.len());
    let span = mx - mn;
    let s = if span > 0.0 { span / levels } else { 1.0 };
    // Codes (roundps + clamp, vectorizes to u8 stores).
    for (c, &v) in codes.iter_mut().zip(seg) {
        *c = round_half_even((v - mn) / s).clamp(0.0, levels) as u8;
    }
    // Integer code sum (u8 -> u32 reduction, vectorizes).
    let sum = codes.iter().map(|&c| c as u32).sum::<u32>() as f32;
    (s, sum)
}

/// Quantize a rank-2 tensor along its last axis with `region` granularity.
pub fn quantize_matrix(x: &Tensor, bits: u8, region: RegionSpec) -> QuantizedMatrix {
    assert!(x.rank() == 2, "quantize_matrix needs rank-2, got {:?}", x.shape());
    assert!((1..=8).contains(&bits), "bits must be 1..=8, got {bits}");
    let rows = x.dim(0);
    let k = x.dim(1);
    let levels = ((1u32 << bits) - 1) as f32;

    // PerTensor (DQ): single min/max over everything, then same code path.
    let (global_min, global_max) = if region.per_tensor() {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in x.data() {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        (mn, mx)
    } else {
        (0.0, 0.0)
    };

    let g = region.group_len(k);
    let rpr = region.regions_per_row(k);
    let mut codes = vec![0u8; rows * k];
    let mut scales = vec![0.0f32; rows * rpr];
    let mut mins = vec![0.0f32; rows * rpr];
    let mut code_sums = vec![0.0f32; rows * rpr];

    for row in 0..rows {
        let xr = x.row(row);
        let crow = &mut codes[row * k..(row + 1) * k];
        for r in 0..rpr {
            let start = r * g;
            let end = ((r + 1) * g).min(k);
            let seg = &xr[start..end];
            let (mn, mx) = if region.per_tensor() {
                (global_min, global_max)
            } else {
                region_minmax(seg)
            };
            let idx = row * rpr + r;
            let (s, sum) = encode_region(seg, mn, mx, levels, &mut crow[start..end]);
            scales[idx] = s;
            mins[idx] = mn;
            code_sums[idx] = sum;
        }
    }
    QuantizedMatrix { rows, k, bits, region, codes, scales, mins, code_sums }
}

/// Quantize-dequantize round trip — the value the fixed-point pipeline sees.
pub fn fake_quant(x: &Tensor, bits: u8, region: RegionSpec) -> Tensor {
    quantize_matrix(x, bits, region).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_half_even_matches_numpy() {
        // numpy: round(0.5)=0, round(1.5)=2, round(2.5)=2, round(-0.5)=-0
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4), 1.0);
        assert_eq!(round_half_even(1.6), 2.0);
    }

    #[test]
    fn constant_region_is_exact() {
        let x = Tensor::filled(&[2, 8], 3.25);
        let fq = fake_quant(&x, 2, RegionSpec::Size(4));
        assert_eq!(fq.max_abs_diff(&x), 0.0);
    }

    #[test]
    fn roundtrip_error_bound() {
        // |x - Q^-1(Q(x))| <= s/2 for every element, every bits/region combo.
        prop::check("quant-roundtrip-bound", 0xA11CE, |rng, _| {
            let (rows, k) = prop::gen_dims(rng, 24);
            let x = Tensor::new(&[rows, k], prop::gen_values(rng, rows * k));
            let bits = prop::gen_bits(rng) as u8;
            let region = match rng.below(3) {
                0 => RegionSpec::PerTensor,
                1 => RegionSpec::PerRow,
                _ => RegionSpec::Size(rng.index(1, k + 1)),
            };
            let q = quantize_matrix(&x, bits, region);
            let dq = q.dequantize();
            let g = q.group_len();
            let rpr = q.regions_per_row();
            for row in 0..rows {
                for j in 0..k {
                    let s = q.scales[row * rpr + j / g];
                    let err = (x.at2(row, j) - dq.at2(row, j)).abs();
                    assert!(
                        err <= s / 2.0 + 1e-5 * s.max(1.0),
                        "err {err} > s/2 ({s}) at ({row},{j}) bits={bits} region={region}"
                    );
                }
            }
        });
    }

    #[test]
    fn lq_never_worse_than_dq() {
        // Smaller regions => smaller (or equal) max error. The paper's core claim.
        prop::check("lq-beats-dq", 0xBEEF, |rng, _| {
            let (rows, k) = prop::gen_dims(rng, 24);
            let x = Tensor::new(&[rows, k], prop::gen_values(rng, rows * k));
            let bits = prop::gen_bits(rng) as u8;
            // Per-element *effective* error bound: s/2 for live regions, 0
            // for flat regions (the sentinel scale 1.0 reconstructs exactly).
            let bound = |q: &QuantizedMatrix, x: &Tensor, row: usize, j: usize| -> f32 {
                let g = q.group_len();
                let rpr = q.regions_per_row();
                let r = j / g;
                let start = r * g;
                let end = ((r + 1) * g).min(q.k);
                let xr = x.row(row);
                let span = xr[start..end].iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                    - xr[start..end].iter().cloned().fold(f32::INFINITY, f32::min);
                if span > 0.0 {
                    q.scales[row * rpr + r] / 2.0
                } else {
                    0.0
                }
            };
            let dq_q = quantize_matrix(&x, bits, RegionSpec::PerTensor);
            let lq_q = quantize_matrix(&x, bits, RegionSpec::Size(4));
            let lq_fq = lq_q.dequantize();
            for row in 0..rows {
                for j in 0..k {
                    // LQ's bound never exceeds DQ's bound: sub-region span
                    // <= global span.
                    let bl = bound(&lq_q, &x, row, j);
                    let bd = bound(&dq_q, &x, row, j);
                    assert!(bl <= bd + 1e-6 * bd.max(1e-20), "LQ bound {bl} > DQ bound {bd}");
                    // Realized LQ error respects its own bound.
                    let e = (x.at2(row, j) - lq_fq.at2(row, j)).abs();
                    assert!(e <= bl + 1e-5 * bl.max(1e-30) + f32::EPSILON * x.at2(row, j).abs());
                }
            }
        });
    }

    #[test]
    fn codes_within_levels() {
        prop::check("codes-in-range", 0xC0DE, |rng, _| {
            let (rows, k) = prop::gen_dims(rng, 16);
            let x = Tensor::new(&[rows, k], prop::gen_values(rng, rows * k));
            let bits = prop::gen_bits(rng) as u8;
            let q = quantize_matrix(&x, bits, RegionSpec::Size(5));
            let max_code = (1u16 << bits) - 1;
            assert!(q.codes.iter().all(|&c| (c as u16) <= max_code));
        });
    }

    #[test]
    fn code_sums_match_codes() {
        let x = Tensor::new(&[1, 6], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let q = quantize_matrix(&x, 2, RegionSpec::Size(3));
        let rpr = q.regions_per_row();
        assert_eq!(rpr, 2);
        for r in 0..rpr {
            let s: f32 = (r * 3..(r + 1) * 3).map(|j| q.codes[j] as f32).sum();
            assert_eq!(s, q.code_sums[r]);
        }
    }

    #[test]
    fn packed_bytes_shrink_with_bits() {
        let x = Tensor::from_fn(&[8, 64], |i| (i as f32).sin());
        let b8 = quantize_matrix(&x, 8, RegionSpec::PerRow).packed_bytes();
        let b2 = quantize_matrix(&x, 2, RegionSpec::PerRow).packed_bytes();
        assert!(b2 < b8, "2-bit {b2} should be smaller than 8-bit {b8}");
    }
}
