//! `.lqz` — the packed deployment format (the paper's §VI.C workflow:
//! "deep neural networks are supplied and quantified offline").
//!
//! A `.lqz` file holds every layer of a network quantized offline with LQ:
//! bit-packed codes + per-region scale/min side-cars. This is what actually
//! ships to the IoT device — the f32 npz never leaves the build host. The
//! rust engine reconstructs a [`QuantizedMatrix`] per layer with zero
//! recomputation (codes and side-cars are stored, not re-derived).
//!
//! Layout (little-endian):
//! ```text
//! magic "LQZ1" | u32 n_entries
//! per entry:
//!   u16 name_len | name bytes
//!   u8 bits | u8 region_tag (0=per-tensor, 1=per-row, 2=size) | u32 region_g
//!   u32 rows | u32 k
//!   u32 n_words | n_words x u64 packed codes
//!   (rows*regions) x f32 scales | (rows*regions) x f32 mins
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::codec::{pack, unpack, Packed};
use crate::quant::region::RegionSpec;
use crate::quant::scheme::QuantizedMatrix;

const MAGIC: &[u8; 4] = b"LQZ1";

/// One named quantized operand.
#[derive(Debug, Clone)]
pub struct LqzEntry {
    /// Layer/parameter name (e.g. `"c1.w"`).
    pub name: String,
    /// The reconstructed operand (codes one-per-byte, side-cars attached).
    pub matrix: QuantizedMatrix,
}

fn region_tag(r: RegionSpec) -> (u8, u32) {
    match r {
        RegionSpec::PerTensor => (0, 0),
        RegionSpec::PerRow => (1, 0),
        RegionSpec::Size(g) => (2, g as u32),
    }
}

fn tag_region(tag: u8, g: u32) -> Result<RegionSpec> {
    Ok(match tag {
        0 => RegionSpec::PerTensor,
        1 => RegionSpec::PerRow,
        2 => RegionSpec::Size(g as usize),
        t => bail!("bad region tag {t}"),
    })
}

/// Serialize entries to a `.lqz` file.
pub fn write_lqz(path: impl AsRef<Path>, entries: &[LqzEntry]) -> Result<()> {
    let mut w =
        std::io::BufWriter::new(std::fs::File::create(&path).context("create lqz")?);
    w.write_all(MAGIC)?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for e in entries {
        let q = &e.matrix;
        let name = e.name.as_bytes();
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name)?;
        let (tag, g) = region_tag(q.region);
        w.write_all(&[q.bits, tag])?;
        w.write_all(&g.to_le_bytes())?;
        w.write_all(&(q.rows as u32).to_le_bytes())?;
        w.write_all(&(q.k as u32).to_le_bytes())?;
        let packed = pack(&q.codes, q.bits);
        w.write_all(&(packed.words.len() as u32).to_le_bytes())?;
        for word in &packed.words {
            w.write_all(&word.to_le_bytes())?;
        }
        for s in &q.scales {
            w.write_all(&s.to_le_bytes())?;
        }
        for m in &q.mins {
            w.write_all(&m.to_le_bytes())?;
        }
    }
    Ok(())
}

fn rd<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut b = [0u8; N];
    r.read_exact(&mut b)?;
    Ok(b)
}

/// Load a `.lqz` file.
pub fn read_lqz(path: impl AsRef<Path>) -> Result<Vec<LqzEntry>> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(&path)
            .with_context(|| format!("open {}", path.as_ref().display()))?,
    );
    if &rd::<4>(&mut r)? != MAGIC {
        bail!("not an lqz file");
    }
    let n = u32::from_le_bytes(rd::<4>(&mut r)?) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = u16::from_le_bytes(rd::<2>(&mut r)?) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("entry name not utf8")?;
        let [bits, tag] = rd::<2>(&mut r)?;
        let g = u32::from_le_bytes(rd::<4>(&mut r)?);
        let rows = u32::from_le_bytes(rd::<4>(&mut r)?) as usize;
        let k = u32::from_le_bytes(rd::<4>(&mut r)?) as usize;
        let region = tag_region(tag, g)?;
        let n_words = u32::from_le_bytes(rd::<4>(&mut r)?) as usize;
        let mut words = Vec::with_capacity(n_words);
        for _ in 0..n_words {
            words.push(u64::from_le_bytes(rd::<8>(&mut r)?));
        }
        let codes = unpack(&Packed { bits, len: rows * k, words });
        let rpr = region.regions_per_row(k);
        let side = rows * rpr;
        let mut scales = Vec::with_capacity(side);
        for _ in 0..side {
            scales.push(f32::from_le_bytes(rd::<4>(&mut r)?));
        }
        let mut mins = Vec::with_capacity(side);
        for _ in 0..side {
            mins.push(f32::from_le_bytes(rd::<4>(&mut r)?));
        }
        // Recompute code sums (cheap; keeps the file format minimal).
        let gl = region.group_len(k);
        let mut code_sums = vec![0.0f32; side];
        for row in 0..rows {
            for rr in 0..rpr {
                let start = rr * gl;
                let end = ((rr + 1) * gl).min(k);
                code_sums[row * rpr + rr] = codes[row * k + start..row * k + end]
                    .iter()
                    .map(|&c| c as u32)
                    .sum::<u32>() as f32;
            }
        }
        out.push(LqzEntry {
            name,
            matrix: QuantizedMatrix { rows, k, bits, region, codes, scales, mins, code_sums },
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_matrix;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lqr_{}_{}", std::process::id(), name))
    }

    #[test]
    fn roundtrip_all_configs() {
        let mut rng = Rng::new(0xF11E);
        let mut entries = Vec::new();
        for (i, (bits, region)) in [
            (8u8, RegionSpec::PerRow),
            (2, RegionSpec::Size(5)),
            (4, RegionSpec::PerTensor),
            (1, RegionSpec::Size(3)),
            (6, RegionSpec::Size(16)),
        ]
        .iter()
        .enumerate()
        {
            let rows = 3 + i;
            let k = 17 + 3 * i;
            let x = Tensor::new(&[rows, k], rng.normal_vec(rows * k));
            entries.push(LqzEntry {
                name: format!("layer{i}.w"),
                matrix: quantize_matrix(&x, *bits, *region),
            });
        }
        let path = tmp("roundtrip.lqz");
        write_lqz(&path, &entries).unwrap();
        let back = read_lqz(&path).unwrap();
        assert_eq!(back.len(), entries.len());
        for (a, b) in entries.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.matrix.codes, b.matrix.codes, "{}", a.name);
            assert_eq!(a.matrix.scales, b.matrix.scales);
            assert_eq!(a.matrix.mins, b.matrix.mins);
            assert_eq!(a.matrix.code_sums, b.matrix.code_sums);
            assert_eq!(a.matrix.region, b.matrix.region);
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn file_size_tracks_bits() {
        let mut rng = Rng::new(1);
        let x = Tensor::new(&[16, 256], rng.normal_vec(16 * 256));
        let sizes: Vec<u64> = [8u8, 2]
            .iter()
            .map(|&bits| {
                let path = tmp(&format!("size{bits}.lqz"));
                write_lqz(
                    &path,
                    &[LqzEntry {
                        name: "w".into(),
                        matrix: quantize_matrix(&x, bits, RegionSpec::PerRow),
                    }],
                )
                .unwrap();
                let s = std::fs::metadata(&path).unwrap().len();
                std::fs::remove_file(path).unwrap();
                s
            })
            .collect();
        let ratio = sizes[0] as f64 / sizes[1] as f64;
        assert!(ratio > 3.0, "8-bit/2-bit file ratio {ratio}");
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.lqz");
        std::fs::write(&path, b"definitely not lqz").unwrap();
        assert!(read_lqz(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }
}
