//! Dense bit-packing of quantization codes (1..8 bits per code).
//!
//! The paper's memory/bandwidth saving comes from shipping n-bit codes, not
//! bytes. Codes are packed little-endian into a contiguous `u64` stream —
//! code i occupies bits [i*n, (i+1)*n) of the stream. 6-bit codes straddle
//! word boundaries; the codec handles splits transparently. The packed GEMM
//! (`fixedpoint::gemm_packed`) reads this format directly.

/// Packed code stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Packed {
    /// Code width in bits (1..=8).
    pub bits: u8,
    /// Number of codes in the stream.
    pub len: usize,
    /// Little-endian bitstream: code `i` occupies bits `[i*bits, (i+1)*bits)`.
    pub words: Vec<u64>,
}

/// Pack `codes` (each < 2^bits) into a dense bitstream.
pub fn pack(codes: &[u8], bits: u8) -> Packed {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u8;
    let total_bits = codes.len() * bits as usize;
    let mut words = vec![0u64; total_bits.div_ceil(64)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c & !mask == 0, "code {c} exceeds {bits} bits");
        let bit = i * bits as usize;
        let word = bit / 64;
        let off = bit % 64;
        words[word] |= (c as u64) << off;
        if off + bits as usize > 64 {
            words[word + 1] |= (c as u64) >> (64 - off);
        }
    }
    Packed { bits, len: codes.len(), words }
}

/// Unpack back to one-code-per-byte.
pub fn unpack(p: &Packed) -> Vec<u8> {
    let mut out = vec![0u8; p.len];
    unpack_into(p, &mut out);
    out
}

/// Unpack into a caller-provided buffer (first `p.len` bytes) — the
/// allocation-free variant the panel GEMM M-block scratch uses on every
/// packed GEMM.
pub fn unpack_into(p: &Packed, out: &mut [u8]) {
    assert!(out.len() >= p.len, "unpack_into: buffer {} < {} codes", out.len(), p.len);
    let bits = p.bits as usize;
    let mask = ((1u16 << bits) - 1) as u64;
    if 64 % bits == 0 {
        // 1/2/4/8-bit codes never straddle a word: walk one word at a time
        // with a running shift instead of a per-code word index division.
        let per = 64 / bits;
        for (wi, chunk) in out[..p.len].chunks_mut(per).enumerate() {
            let mut v = p.words[wi];
            for o in chunk.iter_mut() {
                *o = (v & mask) as u8;
                v >>= bits;
            }
        }
        return;
    }
    for (i, o) in out[..p.len].iter_mut().enumerate() {
        let bit = i * bits;
        let word = bit / 64;
        let off = bit % 64;
        let mut v = p.words[word] >> off;
        if off + bits > 64 {
            v |= p.words[word + 1] << (64 - off);
        }
        *o = (v & mask) as u8;
    }
}

impl Packed {
    /// Read code `i` without unpacking the stream.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        let bits = self.bits as usize;
        let mask = ((1u16 << bits) - 1) as u64;
        let bit = i * bits;
        let word = bit / 64;
        let off = bit % 64;
        let mut v = self.words[word] >> off;
        if off + bits > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        (v & mask) as u8
    }

    /// Storage bytes of the packed stream.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_all_widths() {
        prop::check("codec-roundtrip", 0x9ACC, |rng, _| {
            let bits = prop::gen_bits(rng) as u8;
            let n = rng.index(0, 300);
            let mask = ((1u16 << bits) - 1) as u8;
            let codes: Vec<u8> = (0..n).map(|_| (rng.below(256) as u8) & mask).collect();
            let p = pack(&codes, bits);
            assert_eq!(unpack(&p), codes);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(p.get(i), c, "random access mismatch at {i}");
            }
        });
    }

    #[test]
    fn word_straddle_6bit() {
        // 6-bit codes: code 10 starts at bit 60 and straddles the word edge.
        let codes: Vec<u8> = (0..32).map(|i| (i * 7 % 64) as u8).collect();
        let p = pack(&codes, 6);
        assert_eq!(unpack(&p), codes);
    }

    #[test]
    fn density() {
        let codes = vec![1u8; 64];
        assert_eq!(pack(&codes, 1).words.len(), 1); // 64 bits exactly
        assert_eq!(pack(&codes, 2).words.len(), 2);
        assert_eq!(pack(&codes, 8).words.len(), 8);
    }

    #[test]
    fn empty_stream() {
        let p = pack(&[], 4);
        assert_eq!(p.words.len(), 0);
        assert_eq!(unpack(&p), Vec::<u8>::new());
    }
}
