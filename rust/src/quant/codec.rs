//! Dense bit-packing of quantization codes (1..8 bits per code).
//!
//! The paper's memory/bandwidth saving comes from shipping n-bit codes, not
//! bytes. Two layouts:
//!
//! - **Code-major** ([`Packed`], [`pack`] / [`unpack`]): codes packed
//!   little-endian into a contiguous `u64` stream — code i occupies bits
//!   [i*n, (i+1)*n) of the stream. 6-bit codes straddle word boundaries; the
//!   codec handles splits transparently. The packed GEMM
//!   (`fixedpoint::gemm_packed`) reads this format directly.
//! - **Plane-major** ([`Planes`], [`pack_planes`] / [`unpack_planes`]):
//!   bit `b` of every code gathered into its own dense `u64` lane stream
//!   (bit-plane decomposition). This is the operand layout of the
//!   bit-serial popcount GEMM (`fixedpoint::bitserial`), where a dot
//!   product over n-bit codes becomes `n^2` AND+popcount passes over the
//!   plane pairs — compute cost finally scales with bit width.

/// Packed code stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Packed {
    /// Code width in bits (1..=8).
    pub bits: u8,
    /// Number of codes in the stream.
    pub len: usize,
    /// Little-endian bitstream: code `i` occupies bits `[i*bits, (i+1)*bits)`.
    pub words: Vec<u64>,
}

/// Pack `codes` (each < 2^bits) into a dense bitstream.
pub fn pack(codes: &[u8], bits: u8) -> Packed {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u8;
    let total_bits = codes.len() * bits as usize;
    let mut words = vec![0u64; total_bits.div_ceil(64)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c & !mask == 0, "code {c} exceeds {bits} bits");
        let bit = i * bits as usize;
        let word = bit / 64;
        let off = bit % 64;
        words[word] |= (c as u64) << off;
        if off + bits as usize > 64 {
            words[word + 1] |= (c as u64) >> (64 - off);
        }
    }
    Packed { bits, len: codes.len(), words }
}

/// Unpack back to one-code-per-byte.
pub fn unpack(p: &Packed) -> Vec<u8> {
    let mut out = vec![0u8; p.len];
    unpack_into(p, &mut out);
    out
}

/// Unpack into a caller-provided buffer (first `p.len` bytes) — the
/// allocation-free variant the panel GEMM M-block scratch uses on every
/// packed GEMM.
pub fn unpack_into(p: &Packed, out: &mut [u8]) {
    assert!(out.len() >= p.len, "unpack_into: buffer {} < {} codes", out.len(), p.len);
    let bits = p.bits as usize;
    let mask = ((1u16 << bits) - 1) as u64;
    if 64 % bits == 0 {
        // 1/2/4/8-bit codes never straddle a word: walk one word at a time
        // with a running shift instead of a per-code word index division.
        let per = 64 / bits;
        for (wi, chunk) in out[..p.len].chunks_mut(per).enumerate() {
            let mut v = p.words[wi];
            for o in chunk.iter_mut() {
                *o = (v & mask) as u8;
                v >>= bits;
            }
        }
        return;
    }
    for (i, o) in out[..p.len].iter_mut().enumerate() {
        let bit = i * bits;
        let word = bit / 64;
        let off = bit % 64;
        let mut v = p.words[word] >> off;
        if off + bits > 64 {
            v |= p.words[word + 1] << (64 - off);
        }
        *o = (v & mask) as u8;
    }
}

/// Plane-major bit-plane streams: plane `b` holds bit `b` of every code,
/// one bit per position, packed little-endian into `u64` words (position
/// `p` lives at bit `p % 64` of word `p / 64` of its plane).
#[derive(Debug, Clone, PartialEq)]
pub struct Planes {
    /// Code width in bits (1..=8) — one plane per bit.
    pub bits: u8,
    /// Number of codes in the stream.
    pub len: usize,
    /// Words per plane (`ceil(len / 64)`; tail bits zero-padded).
    pub words_per_plane: usize,
    /// `bits * words_per_plane` words, layout `[plane][word]`.
    pub words: Vec<u64>,
}

/// Decompose `codes` (each < 2^bits) into plane-major bit-plane streams.
pub fn pack_planes(codes: &[u8], bits: u8) -> Planes {
    let wpp = codes.len().div_ceil(64);
    let mut words = vec![0u64; bits as usize * wpp];
    pack_planes_into(codes, bits, wpp, &mut words);
    Planes { bits, len: codes.len(), words_per_plane: wpp, words }
}

/// Core plane-packing primitive: scatter `codes` into `bits` bit-planes at
/// `stride` words per plane. `stride` may exceed `ceil(len / 64)` — the
/// bit-serial GEMM uses this to keep every quantization region word-aligned
/// (each region's planes start at a word boundary, tail regions zero-pad).
/// The full `stride` of every plane is rewritten (pad words zeroed), so a
/// reused scratch buffer never leaks stale bits into the popcounts.
pub fn pack_planes_into(codes: &[u8], bits: u8, stride: usize, out: &mut [u64]) {
    assert!((1..=8).contains(&bits));
    let bits = bits as usize;
    assert!(
        stride >= codes.len().div_ceil(64),
        "pack_planes_into: stride {stride} < {} words",
        codes.len().div_ceil(64)
    );
    assert!(
        out.len() >= bits * stride,
        "pack_planes_into: buffer {} < {} words",
        out.len(),
        bits * stride
    );
    out[..bits * stride].fill(0);
    for (wi, chunk) in codes.chunks(64).enumerate() {
        for b in 0..bits {
            let mut word = 0u64;
            for (o, &c) in chunk.iter().enumerate() {
                debug_assert!((c as usize) < (1 << bits), "code {c} exceeds {bits} bits");
                word |= (((c >> b) & 1) as u64) << o;
            }
            out[b * stride + wi] = word;
        }
    }
}

/// Reassemble codes from plane-major streams (inverse of [`pack_planes`]).
pub fn unpack_planes(p: &Planes) -> Vec<u8> {
    let mut out = vec![0u8; p.len];
    for b in 0..p.bits as usize {
        let plane = &p.words[b * p.words_per_plane..(b + 1) * p.words_per_plane];
        for (i, o) in out.iter_mut().enumerate() {
            *o |= (((plane[i / 64] >> (i % 64)) & 1) as u8) << b;
        }
    }
    out
}

impl Planes {
    /// Storage bytes of the plane streams.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

impl Packed {
    /// Read code `i` without unpacking the stream.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        let bits = self.bits as usize;
        let mask = ((1u16 << bits) - 1) as u64;
        let bit = i * bits;
        let word = bit / 64;
        let off = bit % 64;
        let mut v = self.words[word] >> off;
        if off + bits > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        (v & mask) as u8
    }

    /// Storage bytes of the packed stream.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_all_widths() {
        prop::check("codec-roundtrip", 0x9ACC, |rng, _| {
            let bits = prop::gen_bits(rng) as u8;
            let n = rng.index(0, 300);
            let mask = ((1u16 << bits) - 1) as u8;
            let codes: Vec<u8> = (0..n).map(|_| (rng.below(256) as u8) & mask).collect();
            let p = pack(&codes, bits);
            assert_eq!(unpack(&p), codes);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(p.get(i), c, "random access mismatch at {i}");
            }
        });
    }

    #[test]
    fn word_straddle_6bit() {
        // 6-bit codes: code 10 starts at bit 60 and straddles the word edge.
        let codes: Vec<u8> = (0..32).map(|i| (i * 7 % 64) as u8).collect();
        let p = pack(&codes, 6);
        assert_eq!(unpack(&p), codes);
    }

    #[test]
    fn density() {
        let codes = vec![1u8; 64];
        assert_eq!(pack(&codes, 1).words.len(), 1); // 64 bits exactly
        assert_eq!(pack(&codes, 2).words.len(), 2);
        assert_eq!(pack(&codes, 8).words.len(), 8);
    }

    #[test]
    fn empty_stream() {
        let p = pack(&[], 4);
        assert_eq!(p.words.len(), 0);
        assert_eq!(unpack(&p), Vec::<u8>::new());
    }

    #[test]
    fn plane_roundtrip_all_widths() {
        // Plane-major pack/unpack is lossless for every width and every
        // length — including lengths that are not a multiple of 64 (the K
        // tails the bit-serial GEMM pads), where the pad bits must be zero.
        prop::check("planes-roundtrip", 0x9ACD, |rng, _| {
            let bits = prop::gen_bits(rng) as u8;
            let n = rng.index(0, 300);
            let mask = ((1u16 << bits) - 1) as u8;
            let codes: Vec<u8> = (0..n).map(|_| (rng.below(256) as u8) & mask).collect();
            let p = pack_planes(&codes, bits);
            assert_eq!(p.words_per_plane, n.div_ceil(64));
            assert_eq!(p.words.len(), bits as usize * p.words_per_plane);
            assert_eq!(unpack_planes(&p), codes, "bits={bits} n={n}");
            // Pad bits past `len` are zero in every plane: an AND against a
            // padded stream can never contribute phantom popcounts.
            if n % 64 != 0 && !codes.is_empty() {
                for b in 0..bits as usize {
                    let last = p.words[(b + 1) * p.words_per_plane - 1];
                    assert_eq!(last >> (n % 64), 0, "pad bits set in plane {b}");
                }
            }
            // Bit b of code i lands at bit i%64 of word i/64 of plane b.
            for (i, &c) in codes.iter().enumerate() {
                for b in 0..bits as usize {
                    let got = (p.words[b * p.words_per_plane + i / 64] >> (i % 64)) & 1;
                    assert_eq!(got as u8, (c >> b) & 1, "plane {b} code {i}");
                }
            }
        });
    }

    #[test]
    fn plane_pack_with_oversized_stride() {
        // The region-aligned layout packs short segments at a wider stride;
        // the pad words must come out zero even from a dirty buffer.
        let codes: Vec<u8> = (0..70).map(|i| (i % 4) as u8).collect();
        let stride = 4; // ceil(70/64) = 2, two pad words per plane
        let mut out = vec![u64::MAX; 2 * stride];
        pack_planes_into(&codes, 2, stride, &mut out);
        for b in 0..2usize {
            assert_eq!(out[b * stride + 2], 0, "pad word not zeroed");
            assert_eq!(out[b * stride + 3], 0, "pad word not zeroed");
        }
        // Same bits as the tight pack.
        let tight = pack_planes(&codes, 2);
        for b in 0..2usize {
            assert_eq!(
                &out[b * stride..b * stride + 2],
                &tight.words[b * 2..(b + 1) * 2],
                "plane {b} differs from tight pack"
            );
        }
    }
}
