//! Region geometry for local quantization.

/// How a `(rows, K)` operand is split into quantization regions along K.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegionSpec {
    /// One region spanning the entire tensor — dynamic fixed point (DQ),
    /// the prior per-layer scheme of paper §IV.B.
    PerTensor,
    /// One region per row spanning all of K (per-kernel / per-patch scale —
    /// the paper's LQ default, where the region is the conv kernel size).
    PerRow,
    /// Regions of `g` consecutive elements along K within each row
    /// (§VI.F "smaller local quantization region").
    Size(usize),
}

impl RegionSpec {
    /// Effective region length for reduction dimension `k`.
    pub fn group_len(&self, k: usize) -> usize {
        match *self {
            RegionSpec::PerTensor | RegionSpec::PerRow => k,
            RegionSpec::Size(g) => g.clamp(1, k.max(1)),
        }
    }

    /// Number of regions per row for reduction dimension `k`.
    pub fn regions_per_row(&self, k: usize) -> usize {
        let g = self.group_len(k);
        k.div_ceil(g)
    }

    /// True if scales are shared across rows (DQ).
    pub fn per_tensor(&self) -> bool {
        matches!(self, RegionSpec::PerTensor)
    }

    /// Length of region `r` (the tail region may be short).
    pub fn region_len(&self, k: usize, r: usize) -> usize {
        let g = self.group_len(k);
        (k - r * g).min(g)
    }

    /// Parse "dq", "row", or a number.
    pub fn parse(s: &str) -> Option<RegionSpec> {
        match s {
            "dq" | "tensor" => Some(RegionSpec::PerTensor),
            "row" | "kernel" | "0" => Some(RegionSpec::PerRow),
            _ => s.parse::<usize>().ok().filter(|&g| g > 0).map(RegionSpec::Size),
        }
    }
}

impl std::fmt::Display for RegionSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegionSpec::PerTensor => write!(f, "dq"),
            RegionSpec::PerRow => write!(f, "kernel"),
            RegionSpec::Size(g) => write!(f, "{g}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_len_clamps() {
        assert_eq!(RegionSpec::Size(1000).group_len(75), 75);
        assert_eq!(RegionSpec::Size(16).group_len(75), 16);
        assert_eq!(RegionSpec::PerRow.group_len(75), 75);
    }

    #[test]
    fn region_counts() {
        assert_eq!(RegionSpec::Size(16).regions_per_row(75), 5);
        assert_eq!(RegionSpec::Size(16).region_len(75, 4), 11); // tail region
        assert_eq!(RegionSpec::PerRow.regions_per_row(75), 1);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(RegionSpec::parse("dq"), Some(RegionSpec::PerTensor));
        assert_eq!(RegionSpec::parse("kernel"), Some(RegionSpec::PerRow));
        assert_eq!(RegionSpec::parse("32"), Some(RegionSpec::Size(32)));
        assert_eq!(RegionSpec::parse("x"), None);
        assert_eq!(RegionSpec::parse("0"), Some(RegionSpec::PerRow));
    }
}
