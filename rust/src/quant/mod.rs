//! S1/S2 — the paper's contribution: local-based quantization (LQ).
//!
//! A 2-D operand `(rows, K)` is quantized along K in *regions* of `g`
//! consecutive elements; each region gets its own step
//! `s_k = (max_k - min_k)/(2^n - 1)` (paper eq. 5/7). Dynamic fixed point
//! (DQ, the prior scheme of §IV.B) is the degenerate case of one region
//! spanning the whole tensor. Semantics mirror `python/compile/quant.py`
//! element-for-element (including round-half-to-even, numpy's rounding).
//!
//! - [`scheme`] — quantize / dequantize / fake-quant, [`QuantizedMatrix`].
//! - [`region`] — region geometry ([`RegionSpec`]).
//! - [`codec`] — dense bit-packing of codes (1..8 bits) for storage and the
//!   packed GEMMs; reproduces the paper's memory-footprint savings.
//! - [`lut`] — §V look-up-table scheme: code-bucketed dot products that
//!   replace multiply-accumulate with table-indexed adds.
//! - [`error`] — quantization-error analysis (bound check, RMSE, SQNR).
pub mod calib;
pub mod codec;
pub mod curves;
pub mod error;
pub mod lut;
pub mod region;
pub mod scheme;
pub mod serialize;

pub use error::QuantErrorStats;
pub use region::RegionSpec;
pub use scheme::{fake_quant, quantize_matrix, QuantizedMatrix};
