//! S4/S5 — network descriptions, the rust-native forward engine, and
//! analytic op counting.
//!
//! - [`arch`]    — layer descriptors + the architecture zoo: the trained
//!   Mini models (MiniAlexNet / MiniVGG, weights from `make artifacts`) and
//!   the *full* AlexNet / VGG-16 used analytically (Table 3, memory).
//! - [`forward`] — CPU inference engine over npz weights with selectable
//!   precision: f32 baseline, or the quantized pipeline (DQ / LQ, any bit
//!   width, any region size, optional LUT inner loop). This engine powers
//!   the accuracy experiments (Tables 1–2, Figs. 9–10).
//! - [`opcount`] — analytic multiply/add counting (Table 3) and model
//!   memory footprints.
pub mod arch;
pub mod forward;
pub mod opcount;

pub use arch::{Arch, Layer};
pub use forward::{Engine, PanelStats, Precision};
