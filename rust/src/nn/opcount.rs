//! Analytic operation counting (paper Table 3) and memory footprints.
//!
//! Counts multiply and add operations for the convolutional layers of an
//! architecture under two schemes:
//!
//! - **original** — dense multiply-accumulate: `MACs` multiplies + `MACs`
//!   adds (the paper counts one add per MAC).
//! - **2-bit LUT** (§V) — activations at 2 bits, weights 8 bits, inner loop
//!   via look-up tables. The paper's Figure 5 datapath groups activations in
//!   **triples**: one 6-bit-indexed table lookup replaces 3 MACs (so adds =
//!   MACs / 3), and each group of three lookup partial-sums is combined with
//!   one fixed-point rescale multiply (so multiplies = MACs / 9). These are
//!   the constants that reproduce Table 3's 666 -> 74 / 222 (AlexNet) and
//!   15347 -> 1705 / 5116 (VGG-16) exactly.
//! - **bit-serial** — the `fixedpoint::bitserial` popcount GEMM: both
//!   operands at <= 4 bits, the inner loop decomposed into bit-planes so
//!   each output costs `bits_a * bits_w * ceil(K/64)` AND+popcount word ops
//!   over 64-bit lanes instead of `K` MACs. This is the accounting that
//!   makes `table3_opcount` reflect the paper's "largely save transistors"
//!   complexity claim for sub-8-bit schemes on word-oriented hardware.

use crate::nn::arch::{Arch, Layer};

/// LUT grouping parameters (see module docs). `group` activations per table
/// index; one rescale multiply per `combine` lookups.
#[derive(Debug, Clone, Copy)]
pub struct LutCostModel {
    pub group: usize,
    pub combine: usize,
}

impl Default for LutCostModel {
    fn default() -> Self {
        // The paper's Fig. 5 configuration (2-bit codes, triple grouping).
        LutCostModel { group: 3, combine: 3 }
    }
}

/// Op counts for one layer or a whole network (convolution layers only —
/// Table 3's protocol).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub multiplies: u64,
    pub adds: u64,
}

impl OpCounts {
    fn add(&mut self, o: OpCounts) {
        self.multiplies += o.multiplies;
        self.adds += o.adds;
    }
}

/// Multiply-accumulate count of a conv layer (per image).
pub fn conv_macs(arch: &Arch, l: &Layer) -> u64 {
    let (mut h, mut w) = (arch.input.1, arch.input.2);
    for layer in &arch.layers {
        match *layer {
            Layer::Conv { cout, k, stride, pad, groups, pool, cin, .. } => {
                let ho = (h + 2 * pad - k) / stride + 1;
                let wo = (w + 2 * pad - k) / stride + 1;
                if std::ptr::eq(layer, l) {
                    return (cout as u64) * (cin / groups * k * k) as u64 * (ho * wo) as u64;
                }
                h = ho;
                w = wo;
                if pool {
                    h /= 2;
                    w /= 2;
                }
            }
            Layer::Fc { .. } => {}
        }
    }
    panic!("layer not in arch");
}

/// Table 3, "original" row: dense MAC counts over conv layers.
pub fn original_ops(arch: &Arch) -> OpCounts {
    let mut total = OpCounts::default();
    for l in &arch.layers {
        if matches!(l, Layer::Conv { .. }) {
            let macs = conv_macs(arch, l);
            total.add(OpCounts { multiplies: macs, adds: macs });
        }
    }
    total
}

/// Table 3, "2-bit LUT" row.
pub fn lut_ops(arch: &Arch, m: LutCostModel) -> OpCounts {
    let mut total = OpCounts::default();
    for l in &arch.layers {
        if matches!(l, Layer::Conv { .. }) {
            let macs = conv_macs(arch, l);
            let lookups = macs / m.group as u64; // one lookup per `group` MACs
            total.add(OpCounts {
                adds: lookups,                          // one add per lookup
                multiplies: lookups / m.combine as u64, // one rescale per `combine` lookups
            });
        }
    }
    total
}

/// Bit-serial popcount GEMM cost over conv layers (the
/// `fixedpoint::bitserial` path, Table 3 protocol): each output element of
/// a layer with reduction length `K = cin/groups * k * k` costs
/// `bits_a * bits_w * ceil(K / 64)` AND+popcount **word ops** (reported as
/// `adds` — one 64-lane AND + population count + accumulate each), and the
/// eq. 7 per-region affine epilogue costs 4 multiplies per region per
/// output (one kernel-sized region per output under the paper's PerRow
/// default, reported as `multiplies`). Compute scales with the *product of
/// bit widths*: 2-bit codes cost 16x fewer word ops than one-MAC-per-element
/// — the complexity story Fig. 8 tells for the FPGA, realized on 64-bit
/// cores.
pub fn bitserial_ops(arch: &Arch, bits_a: u8, bits_w: u8) -> OpCounts {
    let mut total = OpCounts::default();
    for l in &arch.layers {
        if let Layer::Conv { cin, k, groups, .. } = *l {
            let macs = conv_macs(arch, l);
            let kdim = (cin / groups * k * k) as u64;
            let outputs = macs / kdim; // cout * ho * wo
            total.add(OpCounts {
                adds: outputs * bits_a as u64 * bits_w as u64 * kdim.div_ceil(64),
                multiplies: outputs * 4,
            });
        }
    }
    total
}

/// fc-layer MACs (not in Table 3, used by the Edison cost model).
pub fn fc_macs(arch: &Arch) -> u64 {
    arch.layers
        .iter()
        .map(|l| match *l {
            Layer::Fc { cin, cout, .. } => (cin * cout) as u64,
            _ => 0,
        })
        .sum()
}

/// Weight memory in bytes at a given bit width (+ f32 scale/min pairs per
/// kernel region for quantized variants) — the paper's footprint argument
/// ("32-bit floating point VGG-16 is too large for Edison ... 1GB").
pub fn weight_bytes(arch: &Arch, bits: usize) -> u64 {
    let mut total = 0u64;
    for l in &arch.layers {
        let (params, regions): (u64, u64) = match *l {
            Layer::Conv { cin, cout, k, groups, .. } => {
                ((cout * (cin / groups) * k * k) as u64, cout as u64)
            }
            Layer::Fc { cin, cout, .. } => ((cin * cout) as u64, cout as u64),
        };
        total += (params * bits as u64).div_ceil(8);
        if bits < 32 {
            total += regions * 8; // scale + min per region (PerRow)
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::arch::Arch;

    const M: u64 = 1_000_000;

    #[test]
    fn alexnet_matches_paper_table3() {
        let a = Arch::alexnet_full();
        let orig = original_ops(&a);
        // Paper: 666M multiplies / 666M adds.
        assert_eq!(orig.multiplies / M, 665, "AlexNet conv MACs = {}", orig.multiplies);
        let lut = lut_ops(&a, LutCostModel::default());
        // Paper: 74M multiplies / 222M adds.
        assert_eq!(lut.adds / M, 221, "LUT adds = {}", lut.adds);
        assert_eq!(lut.multiplies / M, 73, "LUT multiplies = {}", lut.multiplies);
    }

    #[test]
    fn vgg16_matches_paper_table3() {
        let a = Arch::vgg16_full();
        let orig = original_ops(&a);
        // Paper: 15347M. Canonical VGG-16 conv MACs are 15346.6M.
        assert!((15_300..15_400).contains(&(orig.multiplies / M)), "{}", orig.multiplies);
        let lut = lut_ops(&a, LutCostModel::default());
        assert!((5_100..5_120).contains(&(lut.adds / M)), "{}", lut.adds);
        assert!((1_700..1_710).contains(&(lut.multiplies / M)), "{}", lut.multiplies);
    }

    #[test]
    fn vgg16_f32_weights_too_big_for_edison() {
        // The paper's footnote: f32 VGG-16 does not fit the 1GB Edison.
        let a = Arch::vgg16_full();
        let f32_bytes = weight_bytes(&a, 32);
        assert!(f32_bytes > 500_000_000, "{f32_bytes}");
        let q8 = weight_bytes(&a, 8);
        assert!(q8 < f32_bytes / 3, "8-bit {q8} vs f32 {f32_bytes}");
    }

    #[test]
    fn bitserial_word_ops_scale_with_bit_width() {
        for a in [Arch::alexnet_full(), Arch::vgg16_full()] {
            let o = original_ops(&a);
            let b1 = bitserial_ops(&a, 1, 1);
            let b2 = bitserial_ops(&a, 2, 2);
            let b4 = bitserial_ops(&a, 4, 4);
            // Compute scales with the product of bit widths (shared ceil(K/64)).
            assert_eq!(b2.adds, 4 * b1.adds, "{}", a.name);
            assert_eq!(b4.adds, 4 * b2.adds, "{}", a.name);
            // 2-bit: 4 plane pairs over 64-lane words ≈ 16x fewer word ops
            // than MACs (per-layer ceil(K/64) keeps it a bit under 16x).
            let ratio2 = o.adds as f64 / b2.adds as f64;
            assert!((12.0..=16.0).contains(&ratio2), "{}: {ratio2}", a.name);
            // The epilogue multiply count is bit-width independent and tiny
            // next to the dense multiply count.
            assert_eq!(b1.multiplies, b4.multiplies, "{}", a.name);
            assert!(b2.multiplies * 20 < o.multiplies, "{}", a.name);
            // Mixed widths multiply out: 2-bit acts x 4-bit weights.
            let b24 = bitserial_ops(&a, 2, 4);
            assert_eq!(b24.adds, 2 * b2.adds, "{}", a.name);
        }
    }

    #[test]
    fn lut_reduction_ratios() {
        // Who-wins shape: ~9x fewer multiplies, ~3x fewer adds.
        for a in [Arch::alexnet_full(), Arch::vgg16_full()] {
            let o = original_ops(&a);
            let l = lut_ops(&a, LutCostModel::default());
            let mul_ratio = o.multiplies as f64 / l.multiplies as f64;
            let add_ratio = o.adds as f64 / l.adds as f64;
            assert!((8.5..9.5).contains(&mul_ratio), "{}: mul ratio {mul_ratio}", a.name);
            assert!((2.9..3.1).contains(&add_ratio), "{}: add ratio {add_ratio}", a.name);
        }
    }
}
