//! Rust-native CPU inference engine (the "BLAImark" role from paper §VI.C).
//!
//! Loads npz weights for an [`Arch`] and runs the forward pass at a chosen
//! [`Precision`]:
//!
//! - `F32` — baseline: im2col + blocked f32 GEMM (the MKL stand-in).
//! - `Quant` — the paper's pipeline: weights quantized *offline* (static
//!   8-bit by default, per-kernel regions), activations quantized *at
//!   runtime* with DQ (per-layer scale) or LQ (per-region scale), integer
//!   GEMM via eq. 7, optional LUT inner loop for <= 4-bit activations.
//!   Layers where *both* operands are <= 4 bits run the bit-serial
//!   popcount GEMM (`fixedpoint::bitserial`) instead of the widened u8
//!   tile — bit-exact, with compute cost scaling as `bits_a * bits_w`
//!   (`LQR_FORCE_U8PANEL=1` opts back into the u8 path).
//!
//! The engine is deliberately identical in layout to the build-time python
//! path (im2col layout, region geometry), so its accuracy numbers are the
//! paper's Tables 1–2 / Figs. 9–10 protocol.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::fixedpoint::bitserial::{bitserial_eligible, force_u8panel};
use crate::fixedpoint::{
    gemm_bitserial, gemm_f32, gemm_lut_panel, gemm_panel, im2col, WeightPanel,
};
use crate::fixedpoint::im2col::{col2im_output, im2col_quantized};
use crate::nn::arch::{Arch, Layer};
use crate::quant::{quantize_matrix, QuantizedMatrix, RegionSpec};
use crate::tensor::{read_npz, Tensor};

/// Activation-quantization scheme for the quantized pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Dynamic fixed point (paper §IV.B): one scale per layer.
    Dq,
    /// Local quantization (the paper's contribution): per-region scales.
    Lq,
}

impl Scheme {
    /// Region granularity this scheme quantizes *activations* at (weights
    /// always use the configured local region, see `quantized_weights`).
    pub fn act_region(self, region: RegionSpec) -> RegionSpec {
        match self {
            Scheme::Dq => RegionSpec::PerTensor,
            Scheme::Lq => region,
        }
    }
}

/// Numeric configuration of a forward pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precision {
    F32,
    Quant {
        scheme: Scheme,
        /// Activation bits (the paper sweeps 8/6/4/2).
        bits_a: u8,
        /// Weight bits (the paper fixes 8).
        bits_w: u8,
        /// LQ region size for activations & weights; `PerRow` = the paper's
        /// kernel-sized default, `Size(g)` = §VI.F smaller regions.
        region: RegionSpec,
        /// Use the §V LUT (bucketed) inner loop (needs bits_a <= 4).
        lut: bool,
    },
}

impl Precision {
    /// The paper's default LQ configuration at a given activation width.
    pub fn lq(bits_a: u8) -> Precision {
        Precision::Quant { scheme: Scheme::Lq, bits_a, bits_w: 8, region: RegionSpec::PerRow, lut: false }
    }

    /// The prior-work DQ configuration at a given activation width.
    pub fn dq(bits_a: u8) -> Precision {
        Precision::Quant { scheme: Scheme::Dq, bits_a, bits_w: 8, region: RegionSpec::PerTensor, lut: false }
    }
}

/// Snapshot of the engine's prepared-panel cache (see
/// [`Engine::panel_stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanelStats {
    /// Cached `(layer, bits_w, region)` entries.
    pub panels: usize,
    /// Resident bytes across all cached panels (codes + params + bit-plane
    /// sidecars).
    pub bytes: usize,
}

/// Weights + cached offline-quantized weights for one network.
pub struct Engine {
    pub arch: Arch,
    params: HashMap<String, Tensor>,
    /// Offline weight preparation cache keyed by (layer, bits_w, region):
    /// the shared GEMM weight panel (`fixedpoint::panel`), built once per
    /// config and reused across every forward pass, so panel prep amortizes
    /// over batches and sweep images. The intermediate `QuantizedMatrix` is
    /// not retained — the panel carries everything the kernels consume.
    wq_cache: std::sync::Mutex<HashMap<(String, u8, String), std::sync::Arc<WeightPanel>>>,
    pub threads: usize,
}

impl Engine {
    /// Load weights from an npz produced by `python -m compile.train`.
    pub fn from_npz(arch: Arch, path: impl AsRef<Path>) -> Result<Engine> {
        arch.validate().map_err(|e| anyhow::anyhow!("bad arch: {e}"))?;
        let entries = read_npz(&path).with_context(|| "loading weights npz")?;
        let mut params = HashMap::new();
        for mut e in entries {
            // Move the decoded storage straight into the parameter map — the
            // archive bytes are read once and never duplicated.
            let name = std::mem::take(&mut e.name);
            params.insert(name, e.into_tensor());
        }
        let eng = Engine { arch, params, wq_cache: Default::default(), threads: default_threads() };
        eng.check_params()?;
        Ok(eng)
    }

    /// Build from an in-memory parameter map (tests, synthetic weights).
    pub fn from_params(arch: Arch, params: HashMap<String, Tensor>) -> Result<Engine> {
        let eng = Engine { arch, params, wq_cache: Default::default(), threads: default_threads() };
        eng.check_params()?;
        Ok(eng)
    }

    fn check_params(&self) -> Result<()> {
        for l in &self.arch.layers {
            let (wname, bname) = (format!("{}.w", l.name()), format!("{}.b", l.name()));
            let w = self.params.get(&wname).with_context(|| format!("missing {wname}"))?;
            self.params.get(&bname).with_context(|| format!("missing {bname}"))?;
            match *l {
                Layer::Conv { cin, cout, k, groups, .. } => {
                    if groups != 1 {
                        bail!("{}: grouped conv unsupported by the engine", l.name());
                    }
                    if w.shape() != [cout, cin, k, k] {
                        bail!("{wname}: shape {:?} != [{cout},{cin},{k},{k}]", w.shape());
                    }
                }
                Layer::Fc { cin, cout, .. } => {
                    if w.shape() != [cin, cout] {
                        bail!("{wname}: shape {:?} != [{cin},{cout}]", w.shape());
                    }
                }
            }
        }
        Ok(())
    }

    pub fn param(&self, name: &str) -> &Tensor {
        &self.params[name]
    }

    /// Quantize the whole network offline into `.lqz` deployment entries
    /// (weights at `bits_w`/`region` in GEMM layout; biases at 8-bit).
    /// This is the artifact that ships to the device — see `quant::serialize`.
    pub fn to_lqz_entries(&self, bits_w: u8, region: RegionSpec) -> Vec<crate::quant::serialize::LqzEntry> {
        use crate::quant::serialize::LqzEntry;
        let wregion = match region {
            RegionSpec::PerTensor => RegionSpec::PerRow,
            r => r,
        };
        let mut entries = Vec::new();
        for l in &self.arch.layers {
            let w = &self.params[&format!("{}.w", l.name())];
            let wmat = match *l {
                Layer::Conv { cout, .. } => w.reshape(&[cout, l.patch()]).unwrap(),
                Layer::Fc { .. } => w.transpose2(),
            };
            entries.push(LqzEntry {
                name: format!("{}.w", l.name()),
                matrix: quantize_matrix(&wmat, bits_w, wregion),
            });
            let b = &self.params[&format!("{}.b", l.name())];
            let brow = b.reshape(&[1, b.len()]).unwrap();
            entries.push(LqzEntry {
                name: format!("{}.b", l.name()),
                matrix: quantize_matrix(&brow, 8, RegionSpec::PerRow),
            });
        }
        entries
    }

    /// Build an engine from a `.lqz` deployment file: no f32 weights needed.
    /// The stored quantized weights seed the offline cache (so the quantized
    /// forward path reuses the shipped codes exactly); the f32 parameter map
    /// is reconstructed by dequantization for bias adds and the f32 path.
    pub fn from_lqz(arch: Arch, path: impl AsRef<Path>) -> Result<Engine> {
        use crate::quant::serialize::read_lqz;
        arch.validate().map_err(|e| anyhow::anyhow!("bad arch: {e}"))?;
        let entries = read_lqz(&path)?;
        let by_name: HashMap<String, crate::quant::serialize::LqzEntry> =
            entries.into_iter().map(|e| (e.name.clone(), e)).collect();
        let mut params = HashMap::new();
        let mut cache: HashMap<(String, u8, String), std::sync::Arc<WeightPanel>> =
            HashMap::new();
        for l in &arch.layers {
            let wname = format!("{}.w", l.name());
            let bname = format!("{}.b", l.name());
            let we = by_name.get(&wname).with_context(|| format!("lqz missing {wname}"))?;
            let be = by_name.get(&bname).with_context(|| format!("lqz missing {bname}"))?;
            // f32 reconstruction in the engine's storage layout.
            let wmat = we.matrix.dequantize();
            let w = match *l {
                Layer::Conv { cin, cout, k, .. } => {
                    wmat.reshape(&[cout, cin, k, k]).unwrap()
                }
                Layer::Fc { .. } => wmat.transpose2(),
            };
            params.insert(wname.clone(), w);
            let b = be.matrix.dequantize();
            params.insert(bname, b.reshape(&[b.len()]).unwrap());
            cache.insert(
                (l.name().to_string(), we.matrix.bits, we.matrix.region.to_string()),
                std::sync::Arc::new(WeightPanel::from_quantized(&we.matrix)),
            );
        }
        let eng = Engine {
            arch,
            params,
            wq_cache: std::sync::Mutex::new(cache),
            threads: default_threads(),
        };
        eng.check_params()?;
        Ok(eng)
    }

    /// Offline weight preparation (cached): quantize (rows = output
    /// channels) and repack into the shared GEMM weight panel.
    fn quantized_weights(
        &self,
        layer: &Layer,
        bits_w: u8,
        region: RegionSpec,
    ) -> std::sync::Arc<WeightPanel> {
        let key = (layer.name().to_string(), bits_w, region.to_string());
        if let Some(q) = self.wq_cache.lock().unwrap().get(&key) {
            return q.clone();
        }
        let w = &self.params[&format!("{}.w", layer.name())];
        let wmat = match *layer {
            Layer::Conv { cout, .. } => w.reshape(&[cout, layer.patch()]).unwrap(),
            Layer::Fc { .. } => w.transpose2(), // (out, in): rows contract over K
        };
        // Weights are quantized offline with *local* (per-kernel) regions in
        // every configuration — the paper quantizes kernels with LQ even when
        // comparing DQ activations (§VI.E).
        let wregion = match region {
            RegionSpec::PerTensor => RegionSpec::PerRow,
            r => r,
        };
        let wq = quantize_matrix(&wmat, bits_w, wregion);
        let panel = std::sync::Arc::new(WeightPanel::from_quantized(&wq));
        self.wq_cache.lock().unwrap().insert(key, panel.clone());
        panel
    }

    /// Eagerly build every layer's weight panel for `precision` so the
    /// first request never pays quantize+pack latency (a no-op for `F32`,
    /// which has no offline preparation). Returns the number of panels
    /// prepared or already cached for this configuration.
    ///
    /// With one engine shared behind an `Arc` across all workers (see
    /// `coordinator::backend::shared_native_factory`), one pre-warm pass
    /// covers the whole pool — and supervisor-restarted workers reattach to
    /// the same panels instead of re-quantizing.
    pub fn prewarm(&self, precision: Precision) -> usize {
        match precision {
            Precision::F32 => 0,
            Precision::Quant { bits_w, region, .. } => {
                for l in &self.arch.layers {
                    let _ = self.quantized_weights(l, bits_w, region);
                }
                self.arch.layers.len()
            }
        }
    }

    /// Aggregate panel-cache state: entry count and resident panel bytes.
    /// This is the memory that sharing one engine de-duplicates N× across a
    /// worker pool.
    pub fn panel_stats(&self) -> PanelStats {
        let g = self.wq_cache.lock().unwrap();
        PanelStats { panels: g.len(), bytes: g.values().map(|p| p.bytes()).sum() }
    }

    /// The cached weight panel for a layer, if a forward pass (or `.lqz`
    /// load) has prepared it. Exposed so tests can pin cache reuse by
    /// pointer identity.
    pub fn cached_panel(
        &self,
        layer_name: &str,
        bits_w: u8,
        region: RegionSpec,
    ) -> Option<std::sync::Arc<WeightPanel>> {
        // Same key scheme as `quantized_weights`: the *requested* region
        // (PerTensor requests still quantize weights PerRow, but cache under
        // the requested key).
        let key = (layer_name.to_string(), bits_w, region.to_string());
        self.wq_cache.lock().unwrap().get(&key).cloned()
    }

    /// Quantize activations at runtime per the scheme.
    fn quantize_acts(a: &Tensor, scheme: Scheme, bits_a: u8, region: RegionSpec) -> QuantizedMatrix {
        quantize_matrix(a, bits_a, scheme.act_region(region))
    }

    /// Panel GEMM over already-quantized activations + bias add — the
    /// shared tail of the quantized conv and fc paths. Both consume the
    /// cached weight panel, so weight prep cost is paid once per
    /// (layer, bits, region), not per GEMM call.
    ///
    /// Kernel selection per layer: the §V LUT loop when asked for; else the
    /// bit-serial popcount GEMM when both operands are <= 4 bits (the panel
    /// then carries the bit-plane sidecar; compute scales with bit width);
    /// else the widened u8 panel microkernel. The bit-serial and u8 paths
    /// are bit-exact against each other, so `LQR_FORCE_U8PANEL=1` flips
    /// performance only, never numerics.
    fn quant_gemm(
        &self,
        aq: &QuantizedMatrix,
        layer: &Layer,
        bias: &Tensor,
        bits_w: u8,
        region: RegionSpec,
        lut: bool,
    ) -> Tensor {
        let wp = self.quantized_weights(layer, bits_w, region);
        let mut out = if lut {
            gemm_lut_panel(aq, &wp, self.threads)
        } else if wp.bit_planes().is_some()
            && bitserial_eligible(aq.bits, bits_w)
            && !force_u8panel()
        {
            gemm_bitserial(aq, &wp, self.threads)
        } else {
            gemm_panel(aq, &wp, self.threads)
        };
        add_bias(&mut out, bias);
        out
    }

    /// One GEMM at the configured precision: `a (M,K) x w^T (N,K) + bias`.
    fn gemm(
        &self,
        a: &Tensor,
        layer: &Layer,
        bias: &Tensor,
        precision: Precision,
    ) -> Tensor {
        match precision {
            Precision::F32 => {
                let w = &self.params[&format!("{}.w", layer.name())];
                let wmat = match *layer {
                    Layer::Conv { cout, .. } => {
                        w.reshape(&[cout, layer.patch()]).unwrap().transpose2()
                    }
                    Layer::Fc { .. } => w.clone(), // already (in, out)
                };
                let mut out = gemm_f32(a, &wmat, self.threads);
                add_bias(&mut out, bias);
                out
            }
            Precision::Quant { scheme, bits_a, bits_w, region, lut } => {
                let aq = Self::quantize_acts(a, scheme, bits_a, region);
                self.quant_gemm(&aq, layer, bias, bits_w, region, lut)
            }
        }
    }

    /// Forward pass: `x (B, C, H, W)` -> logits `(B, num_classes)`.
    pub fn forward(&self, x: &Tensor, precision: Precision) -> Tensor {
        let mut act = x.clone();
        let mut flattened = false;
        for l in &self.arch.layers {
            let bias = &self.params[&format!("{}.b", l.name())];
            match *l {
                Layer::Conv { k, stride, pad, pool, .. } => {
                    let (y, (b, ho, wo)) = match precision {
                        Precision::F32 => {
                            let (cols, dims) = im2col(&act, k, stride, pad);
                            (self.gemm(&cols, l, bias, precision), dims)
                        }
                        Precision::Quant { scheme, bits_a, bits_w, region, lut } => {
                            // Fused lowering: activation codes come straight
                            // out of the patch copies — the f32 patch matrix
                            // never exists on the quantized path.
                            let (aq, dims) = im2col_quantized(
                                &act, k, stride, pad, bits_a, scheme.act_region(region),
                                self.threads,
                            );
                            (self.quant_gemm(&aq, l, bias, bits_w, region, lut), dims)
                        }
                    };
                    act = col2im_output(&y.max_scalar(0.0), b, ho, wo);
                    if pool {
                        act = maxpool2(&act);
                    }
                }
                Layer::Fc { cin, relu, .. } => {
                    if !flattened {
                        act = act.reshape(&[act.dim(0), cin]).unwrap();
                        flattened = true;
                    }
                    // Quantized fc contracts (B,K) x (N,K): pass act rows.
                    act = self.gemm(&act, l, bias, precision);
                    if relu {
                        act = act.max_scalar(0.0);
                    }
                }
            }
        }
        act
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Broadcast-add the per-channel bias over every output row.
fn add_bias(out: &mut Tensor, bias: &Tensor) {
    let n = out.dim(1);
    for i in 0..out.dim(0) {
        let row = &mut out.data_mut()[i * n..(i + 1) * n];
        for (o, b) in row.iter_mut().zip(bias.data()) {
            *o += b;
        }
    }
}

/// 2x2 stride-2 max pool on NCHW.
pub fn maxpool2(x: &Tensor) -> Tensor {
    let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * c * ho * wo];
    let xd = x.data();
    for bc in 0..b * c {
        for y in 0..ho {
            for xx in 0..wo {
                let base = bc * h * w + 2 * y * w + 2 * xx;
                let m = xd[base]
                    .max(xd[base + 1])
                    .max(xd[base + w])
                    .max(xd[base + w + 1]);
                out[bc * ho * wo + y * wo + xx] = m;
            }
        }
    }
    Tensor::new(&[b, c, ho, wo], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_engine(seed: u64) -> Engine {
        // A 2-conv + 2-fc net small enough for exhaustive testing.
        let arch = Arch {
            name: "tiny",
            input: (2, 8, 8),
            num_classes: 4,
            layers: vec![
                Layer::Conv { name: "c1", cin: 2, cout: 4, k: 3, stride: 1, pad: 1, groups: 1, pool: true },
                Layer::Conv { name: "c2", cin: 4, cout: 8, k: 3, stride: 1, pad: 1, groups: 1, pool: true },
                Layer::Fc { name: "f1", cin: 8 * 2 * 2, cout: 16, relu: true },
                Layer::Fc { name: "f2", cin: 16, cout: 4, relu: false },
            ],
        };
        arch.validate().unwrap();
        let mut rng = Rng::new(seed);
        let mut params = HashMap::new();
        for l in &arch.layers {
            let (wshape, blen): (Vec<usize>, usize) = match *l {
                Layer::Conv { cin, cout, k, .. } => (vec![cout, cin, k, k], cout),
                Layer::Fc { cin, cout, .. } => (vec![cin, cout], cout),
            };
            let n: usize = wshape.iter().product();
            params.insert(
                format!("{}.w", l.name()),
                Tensor::new(&wshape, rng.normal_vec(n).iter().map(|v| v * 0.3).collect()),
            );
            params.insert(format!("{}.b", l.name()), Tensor::new(&[blen], rng.normal_vec(blen)));
        }
        Engine::from_params(arch, params).unwrap()
    }

    #[test]
    fn f32_forward_shapes() {
        let eng = tiny_engine(1);
        let mut rng = Rng::new(2);
        let x = Tensor::new(&[3, 2, 8, 8], rng.normal_vec(3 * 2 * 8 * 8));
        let y = eng.forward(&x, Precision::F32);
        assert_eq!(y.shape(), &[3, 4]);
        assert!(y.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quant8_close_to_f32() {
        let eng = tiny_engine(3);
        let mut rng = Rng::new(4);
        let x = Tensor::new(&[2, 2, 8, 8], rng.uniform_vec(2 * 2 * 8 * 8, 0.0, 1.0));
        let f = eng.forward(&x, Precision::F32);
        let q = eng.forward(&x, Precision::lq(8));
        let rel = f.max_abs_diff(&q) / f.max_abs().max(1e-6);
        assert!(rel < 0.05, "8-bit LQ logits deviate {rel}");
    }

    #[test]
    fn lut_matches_integer_path() {
        let eng = tiny_engine(5);
        let mut rng = Rng::new(6);
        let x = Tensor::new(&[2, 2, 8, 8], rng.uniform_vec(2 * 2 * 8 * 8, 0.0, 1.0));
        let base = Precision::Quant {
            scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::PerRow, lut: false,
        };
        let with_lut = Precision::Quant {
            scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region: RegionSpec::PerRow, lut: true,
        };
        let a = eng.forward(&x, base);
        let b = eng.forward(&x, with_lut);
        assert!(a.max_abs_diff(&b) <= 1e-4 * a.max_abs().max(1.0));
    }

    #[test]
    fn lq_beats_dq_at_2bit() {
        // The paper's headline mechanism: when activation magnitude varies
        // across receptive fields (here: across images in the batch), the
        // per-layer DQ scale clips the small-magnitude samples to nothing
        // while per-region LQ scales adapt. Compare *relative* logit error.
        let eng = tiny_engine(7);
        let mut rng = Rng::new(8);
        let mut data = rng.uniform_vec(4 * 2 * 8 * 8, 0.0, 1.0);
        let per = 2 * 8 * 8;
        for (i, mag) in [0.01f32, 0.1, 1.0, 10.0].iter().enumerate() {
            for v in &mut data[i * per..(i + 1) * per] {
                *v *= mag;
            }
        }
        let x = Tensor::new(&[4, 2, 8, 8], data);
        let f = eng.forward(&x, Precision::F32);
        let lq = eng.forward(&x, Precision::lq(2));
        let dq = eng.forward(&x, Precision::dq(2));
        let rel = |q: &Tensor, img: usize| {
            let fr = f.row(img);
            let qr = q.row(img);
            let num: f32 = fr.iter().zip(qr).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = fr.iter().map(|a| a * a).sum::<f32>().max(1e-12);
            (num / den).sqrt()
        };
        // The small-magnitude images are where DQ collapses.
        let e_lq = rel(&lq, 0) + rel(&lq, 1);
        let e_dq = rel(&dq, 0) + rel(&dq, 1);
        assert!(e_lq < e_dq, "LQ rel err {e_lq} should beat DQ rel err {e_dq}");
    }

    #[test]
    fn prewarm_builds_every_panel_once() {
        let eng = tiny_engine(9);
        assert_eq!(eng.panel_stats().panels, 0);
        let p = Precision::lq(2);
        assert_eq!(eng.prewarm(p), 4, "one panel per layer");
        let stats = eng.panel_stats();
        assert_eq!(stats.panels, 4);
        assert!(stats.bytes > 0, "panels must report resident bytes");
        // Pin identity: a forward pass reuses the prewarmed panels (no
        // rebuild, same Arc), and a second prewarm is a no-op.
        let before = eng.cached_panel("c1", 8, RegionSpec::PerRow).unwrap();
        let mut rng = Rng::new(10);
        let x = Tensor::new(&[1, 2, 8, 8], rng.uniform_vec(2 * 8 * 8, 0.0, 1.0));
        let _ = eng.forward(&x, p);
        assert_eq!(eng.prewarm(p), 4);
        let after = eng.cached_panel("c1", 8, RegionSpec::PerRow).unwrap();
        assert!(std::sync::Arc::ptr_eq(&before, &after));
        assert_eq!(eng.panel_stats(), stats);
        // F32 has nothing to prepare.
        assert_eq!(eng.prewarm(Precision::F32), 0);
    }

    #[test]
    fn maxpool_basic() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(maxpool2(&x).data(), &[4.0]);
    }
}
