//! Architecture descriptors.
//!
//! The Mini models mirror `python/compile/model.py` exactly (same layer
//! names, shapes and pooling) — the npz weights from `make artifacts` load
//! into them 1:1. The full AlexNet / VGG-16 descriptors carry the canonical
//! hyper-parameters (including AlexNet's grouped convolutions) so the
//! analytic experiments reproduce the paper's absolute op counts.

/// One layer of a feed-forward CNN.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layer {
    /// Convolution (+ ReLU), optionally followed by 2x2 max-pool.
    Conv {
        name: &'static str,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        /// Grouped convolution (AlexNet conv2/4/5 use groups = 2).
        groups: usize,
        /// Append a 2x2/s2 max-pool after the activation.
        pool: bool,
    },
    /// Fully connected (+ optional ReLU).
    Fc { name: &'static str, cin: usize, cout: usize, relu: bool },
}

impl Layer {
    pub fn name(&self) -> &'static str {
        match self {
            Layer::Conv { name, .. } | Layer::Fc { name, .. } => name,
        }
    }

    /// im2col reduction length = the paper's default LQ region size.
    pub fn patch(&self) -> usize {
        match *self {
            Layer::Conv { cin, k, groups, .. } => cin / groups * k * k,
            Layer::Fc { cin, .. } => cin,
        }
    }
}

/// A network: ordered layers + input geometry.
#[derive(Debug, Clone)]
pub struct Arch {
    pub name: &'static str,
    /// (C, H, W) input.
    pub input: (usize, usize, usize),
    pub num_classes: usize,
    pub layers: Vec<Layer>,
}

fn conv(
    name: &'static str,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    pool: bool,
) -> Layer {
    Layer::Conv { name, cin, cout, k, stride, pad, groups: 1, pool }
}

fn gconv(
    name: &'static str,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    pool: bool,
) -> Layer {
    Layer::Conv { name, cin, cout, k, stride, pad, groups, pool }
}

fn fc(name: &'static str, cin: usize, cout: usize, relu: bool) -> Layer {
    Layer::Fc { name, cin, cout, relu }
}

impl Arch {
    /// MiniAlexNet — the trained 32x32 stand-in (matches python model.py).
    pub fn minialexnet() -> Arch {
        Arch {
            name: "minialexnet",
            input: (3, 32, 32),
            num_classes: 16,
            layers: vec![
                conv("conv1", 3, 32, 5, 1, 2, true),
                conv("conv2", 32, 64, 5, 1, 2, true),
                conv("conv3", 64, 128, 3, 1, 1, true),
                fc("fc1", 128 * 4 * 4, 256, true),
                fc("fc2", 256, 16, false),
            ],
        }
    }

    /// MiniVGG — the trained 32x32 stand-in (matches python model.py).
    pub fn minivgg() -> Arch {
        Arch {
            name: "minivgg",
            input: (3, 32, 32),
            num_classes: 16,
            layers: vec![
                conv("conv1_1", 3, 32, 3, 1, 1, false),
                conv("conv1_2", 32, 32, 3, 1, 1, true),
                conv("conv2_1", 32, 64, 3, 1, 1, false),
                conv("conv2_2", 64, 64, 3, 1, 1, true),
                conv("conv3_1", 64, 128, 3, 1, 1, false),
                conv("conv3_2", 128, 128, 3, 1, 1, true),
                fc("fc1", 128 * 4 * 4, 256, true),
                fc("fc2", 256, 16, false),
            ],
        }
    }

    /// Full AlexNet (Krizhevsky et al. 2012), canonical 227x227 geometry with
    /// grouped conv2/4/5 — used analytically (Table 3: 666M conv multiplies).
    pub fn alexnet_full() -> Arch {
        Arch {
            name: "alexnet",
            input: (3, 227, 227),
            num_classes: 1000,
            layers: vec![
                conv("conv1", 3, 96, 11, 4, 0, true),
                gconv("conv2", 96, 256, 5, 1, 2, 2, true),
                conv("conv3", 256, 384, 3, 1, 1, false),
                gconv("conv4", 384, 384, 3, 1, 1, 2, false),
                gconv("conv5", 384, 256, 3, 1, 1, 2, true),
                fc("fc6", 256 * 6 * 6, 4096, true),
                fc("fc7", 4096, 4096, true),
                fc("fc8", 4096, 1000, false),
            ],
        }
    }

    /// Full VGG-16 (Simonyan & Zisserman 2014), all 3x3 receptive fields —
    /// used analytically (Table 3: 15347M conv multiplies).
    pub fn vgg16_full() -> Arch {
        Arch {
            name: "vgg16",
            input: (3, 224, 224),
            num_classes: 1000,
            layers: vec![
                conv("conv1_1", 3, 64, 3, 1, 1, false),
                conv("conv1_2", 64, 64, 3, 1, 1, true),
                conv("conv2_1", 64, 128, 3, 1, 1, false),
                conv("conv2_2", 128, 128, 3, 1, 1, true),
                conv("conv3_1", 128, 256, 3, 1, 1, false),
                conv("conv3_2", 256, 256, 3, 1, 1, false),
                conv("conv3_3", 256, 256, 3, 1, 1, true),
                conv("conv4_1", 256, 512, 3, 1, 1, false),
                conv("conv4_2", 512, 512, 3, 1, 1, false),
                conv("conv4_3", 512, 512, 3, 1, 1, true),
                conv("conv5_1", 512, 512, 3, 1, 1, false),
                conv("conv5_2", 512, 512, 3, 1, 1, false),
                conv("conv5_3", 512, 512, 3, 1, 1, true),
                fc("fc6", 512 * 7 * 7, 4096, true),
                fc("fc7", 4096, 4096, true),
                fc("fc8", 4096, 1000, false),
            ],
        }
    }

    pub fn by_name(name: &str) -> Option<Arch> {
        match name {
            "minialexnet" => Some(Arch::minialexnet()),
            "minivgg" => Some(Arch::minivgg()),
            "alexnet" => Some(Arch::alexnet_full()),
            "vgg16" => Some(Arch::vgg16_full()),
            _ => None,
        }
    }

    /// Spatial size after each layer; validates the geometry chains up.
    pub fn validate(&self) -> Result<(), String> {
        let (mut c, mut h, mut w) = self.input;
        let mut flattened = false;
        for l in &self.layers {
            match *l {
                Layer::Conv { name, cin, cout, k, stride, pad, groups, pool } => {
                    if flattened {
                        return Err(format!("{name}: conv after flatten"));
                    }
                    if cin != c {
                        return Err(format!("{name}: cin {cin} != incoming {c}"));
                    }
                    if cin % groups != 0 || cout % groups != 0 {
                        return Err(format!("{name}: groups {groups} must divide channels"));
                    }
                    h = (h + 2 * pad - k) / stride + 1;
                    w = (w + 2 * pad - k) / stride + 1;
                    if pool {
                        h /= 2;
                        w /= 2;
                    }
                    c = cout;
                }
                Layer::Fc { name, cin, cout, .. } => {
                    let incoming = if flattened { c } else { c * h * w };
                    if cin != incoming {
                        return Err(format!("{name}: cin {cin} != incoming {incoming}"));
                    }
                    flattened = true;
                    c = cout;
                }
            }
        }
        if c != self.num_classes {
            return Err(format!("final width {c} != num_classes {}", self.num_classes));
        }
        Ok(())
    }

    /// Total parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match *l {
                Layer::Conv { cin, cout, k, groups, .. } => cout * (cin / groups) * k * k + cout,
                Layer::Fc { cin, cout, .. } => cin * cout + cout,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_archs_validate() {
        for a in [
            Arch::minialexnet(),
            Arch::minivgg(),
            Arch::alexnet_full(),
            Arch::vgg16_full(),
        ] {
            a.validate().unwrap_or_else(|e| panic!("{}: {e}", a.name));
        }
    }

    #[test]
    fn alexnet_param_count_canonical() {
        // ~61M parameters is the canonical AlexNet figure.
        let p = Arch::alexnet_full().param_count();
        assert!((58_000_000..64_000_000).contains(&p), "alexnet params {p}");
    }

    #[test]
    fn vgg16_param_count_canonical() {
        // ~138M parameters is the canonical VGG-16 figure.
        let p = Arch::vgg16_full().param_count();
        assert!((135_000_000..141_000_000).contains(&p), "vgg16 params {p}");
    }

    #[test]
    fn patch_is_kernel_region() {
        // Paper §VI.D: AlexNet conv1's region = 11*11*3 = 363.
        let a = Arch::alexnet_full();
        assert_eq!(a.layers[0].patch(), 363);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["minialexnet", "minivgg", "alexnet", "vgg16"] {
            assert_eq!(Arch::by_name(n).unwrap().name, n);
        }
        assert!(Arch::by_name("nope").is_none());
    }
}
