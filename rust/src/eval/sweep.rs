//! Experiment drivers: one function per paper table/figure.
//!
//! Both the benches (`rust/benches/*`) and the examples call these, so every
//! reported number comes from a single implementation. Each driver returns a
//! rendered [`TableFmt`] matching the paper's row/column layout.

use std::time::Instant;

use anyhow::Result;

use crate::dataset::Dataset;
use crate::eval::accuracy::{evaluate, AccuracyResult};
use crate::eval::table::TableFmt;
use crate::nn::forward::Scheme;
use crate::nn::opcount::{bitserial_ops, lut_ops, original_ops, LutCostModel};
use crate::nn::{Arch, Engine, Precision};
use crate::platform::edison::{EdisonModel, NumFmt};
use crate::platform::fpga::perf::perf;
use crate::platform::fpga::resource::{estimate, CuConfig};
use crate::quant::RegionSpec;
use crate::tensor::Tensor;

/// Load the trained engine for a mini model from the artifacts dir.
pub fn load_engine(artifacts: &str, model: &str) -> Result<Engine> {
    let arch = Arch::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    Engine::from_npz(arch, format!("{artifacts}/weights_{model}.npz"))
}

fn pct(v: f64) -> String {
    AccuracyResult::pct(v)
}

/// Table 1 — top-1/top-5, f32 baseline vs 8-bit LQ, both mini models.
pub fn table1(artifacts: &str, limit: usize) -> Result<TableFmt> {
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?.take(limit);
    let mut t = TableFmt::new(
        "Table 1 — accuracy, 32-bit float baseline vs 8-bit LQ fixed point",
        &["model", "scheme", "top-1", "top-5"],
    );
    for model in ["minialexnet", "minivgg"] {
        let engine = load_engine(artifacts, model)?;
        let f = evaluate(&engine, &ds, Precision::F32, 32, None);
        let q = evaluate(&engine, &ds, Precision::lq(8), 32, None);
        t.row(&[model.into(), "32-bit float".into(), pct(f.top1), pct(f.top5)]);
        t.row(&[model.into(), "8-bit LQ".into(), pct(q.top1), pct(q.top5)]);
    }
    Ok(t)
}

/// Table 2 / Fig. 9 — DQ vs LQ across 8/6/4/2-bit activations.
pub fn table2(artifacts: &str, bits: &[usize], limit: usize) -> Result<TableFmt> {
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?.take(limit);
    let mut t = TableFmt::new(
        "Table 2 / Fig. 9 — accuracy vs activation precision (weights 8-bit LQ)",
        &["model", "metric", "scheme", "8-bit", "6-bit", "4-bit", "2-bit"],
    );
    for model in ["minialexnet", "minivgg"] {
        let engine = load_engine(artifacts, model)?;
        let mut rows: Vec<(String, Vec<AccuracyResult>)> = Vec::new();
        for scheme in ["DQ", "LQ"] {
            let mut res = Vec::new();
            for &b in bits {
                let p = if scheme == "DQ" {
                    Precision::dq(b as u8)
                } else {
                    Precision::lq(b as u8)
                };
                res.push(evaluate(&engine, &ds, p, 32, None));
            }
            rows.push((scheme.into(), res));
        }
        for metric in ["top-1", "top-5"] {
            for (scheme, res) in &rows {
                let mut cells = vec![model.to_string(), metric.into(), scheme.clone()];
                for r in res {
                    cells.push(pct(if metric == "top-1" { r.top1 } else { r.top5 }));
                }
                t.row(&cells);
            }
        }
    }
    Ok(t)
}

/// Fig. 10 — 2-bit accuracy vs LQ region size (VGG stand-in).
pub fn fig10(artifacts: &str, regions: &[usize], limit: usize) -> Result<TableFmt> {
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?.take(limit);
    let engine = load_engine(artifacts, "minivgg")?;
    let mut t = TableFmt::new(
        "Fig. 10 — 2-bit accuracy vs local quantization region size (minivgg)",
        &["region", "top-1", "top-5"],
    );
    // Kernel-sized region first (the paper's default / leftmost point).
    let base = evaluate(&engine, &ds, Precision::lq(2), 32, None);
    t.row(&["kernel".into(), pct(base.top1), pct(base.top5)]);
    for &g in regions {
        let p = Precision::Quant {
            scheme: Scheme::Lq,
            bits_a: 2,
            bits_w: 8,
            region: RegionSpec::Size(g),
            lut: false,
        };
        let r = evaluate(&engine, &ds, p, 32, None);
        t.row(&[g.to_string(), pct(r.top1), pct(r.top5)]);
    }
    Ok(t)
}

/// Table 3 — conv-layer multiply/add counts: original vs 2-bit LUT (the
/// paper's absolute numbers) plus the repo's bit-serial popcount path
/// (adds column = AND+popcount 64-lane word ops, multiply column = eq. 7
/// epilogue rescales), on the *full* AlexNet / VGG-16.
pub fn table3() -> TableFmt {
    let mut t = TableFmt::new(
        "Table 3 — conv multiply/add operations per image (millions)",
        &["network", "scheme", "multiply (M)", "add (M)"],
    );
    const M: u64 = 1_000_000;
    for arch in [Arch::alexnet_full(), Arch::vgg16_full()] {
        let o = original_ops(&arch);
        let l = lut_ops(&arch, LutCostModel::default());
        t.row(&[
            arch.name.into(),
            "original".into(),
            (o.multiplies / M).to_string(),
            (o.adds / M).to_string(),
        ]);
        t.row(&[
            arch.name.into(),
            "2-bit LUT".into(),
            (l.multiplies / M).to_string(),
            (l.adds / M).to_string(),
        ]);
        let b = bitserial_ops(&arch, 2, 2);
        t.row(&[
            arch.name.into(),
            "2-bit bit-serial (word ops)".into(),
            (b.multiplies / M).to_string(),
            (b.adds / M).to_string(),
        ]);
    }
    t
}

/// Tables 4+5 — FPGA resources, timing, throughput and power.
pub fn table45() -> TableFmt {
    let mut t = TableFmt::new(
        "Tables 4+5 — Matrix Multiplier on XC6VLX240T (structural model)",
        &["configuration", "LUT#", "FF#", "max freq", "latency", "Gops @max @90%", "mW @200MHz"],
    );
    for cfg in CuConfig::paper_rows() {
        let r = estimate(cfg);
        let p = perf(cfg);
        t.row(&[
            cfg.label(),
            r.luts.to_string(),
            r.ffs.to_string(),
            format!("{:.0} MHz", r.fmax_mhz),
            r.latency.to_string(),
            format!("{:.0}", p.gops_at_max),
            format!("{:.0}", p.power_mw_200),
        ]);
    }
    t
}

/// Fig. 8 — per-image runtime, f32 vs 8-bit fixed point.
///
/// Two sections: *measured* on this host with the rust engine over the mini
/// models, and *modelled* for the full AlexNet/VGG-16 on the Edison cost
/// model (the paper's actual testbed, which we cannot run).
pub fn fig8(artifacts: &str, measure_images: usize) -> Result<TableFmt> {
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?;
    let mut t = TableFmt::new(
        "Fig. 8 — per-image runtime: f32 baseline vs 8-bit LQ fixed point",
        &["network", "platform", "f32 ms/img", "8-bit ms/img", "speedup"],
    );
    for model in ["minialexnet", "minivgg"] {
        let engine = load_engine(artifacts, model)?;
        let time_per_image = |p: Precision| -> f64 {
            // One warmup pass then timed single-image runs (the paper's
            // protocol: latency of recognizing ONE image).
            let x = ds.image(0);
            let _ = engine.forward(&x, p);
            let t0 = Instant::now();
            for i in 0..measure_images {
                let x: Tensor = ds.image(i);
                std::hint::black_box(engine.forward(&x, p));
            }
            t0.elapsed().as_secs_f64() / measure_images as f64
        };
        let f = time_per_image(Precision::F32);
        let q = time_per_image(Precision::lq(8));
        t.row(&[
            model.into(),
            "host (measured)".into(),
            format!("{:.2}", f * 1e3),
            format!("{:.2}", q * 1e3),
            format!("{:.2}x", f / q),
        ]);
    }
    let edison = EdisonModel::default();
    for arch in [Arch::alexnet_full(), Arch::vgg16_full()] {
        let f = edison.image_time(&arch, NumFmt::F32);
        let q = edison.image_time(&arch, NumFmt::Fixed(8));
        t.row(&[
            arch.name.into(),
            "Edison (modelled)".into(),
            format!("{:.0}", f * 1e3),
            format!("{:.0}", q * 1e3),
            format!("{:.2}x", f / q),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_rows_render() {
        let s = table3().render();
        assert!(s.contains("alexnet"));
        assert!(s.contains("665") || s.contains("666"));
        assert!(s.contains("2-bit LUT"));
        assert!(s.contains("2-bit bit-serial"));
    }

    #[test]
    fn table45_rows_render() {
        let s = table45().render();
        assert!(s.contains("FP 32x32"));
        assert!(s.contains("Fixed 8x2"));
    }
}
