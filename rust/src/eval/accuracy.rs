//! Top-k accuracy evaluation over a [`Dataset`] with any [`Precision`].

use crate::dataset::Dataset;
use crate::nn::{Engine, Precision};

/// Result of one accuracy run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyResult {
    pub n: usize,
    pub top1: f64,
    pub top5: f64,
}

impl AccuracyResult {
    pub fn pct(v: f64) -> String {
        format!("{:.1}%", v * 100.0)
    }
}

/// Does `label` fall in the top-k of `logits`?
pub fn topk_hit(logits: &[f32], label: i32, k: usize) -> bool {
    let target = logits[label as usize];
    // Count strictly-greater entries; ties resolved in favour of the label
    // (deterministic, matches argsort-stable protocols).
    let greater = logits.iter().filter(|&&v| v > target).count();
    greater < k
}

/// Evaluate `engine` at `precision` over (a subset of) `ds`.
pub fn evaluate(
    engine: &Engine,
    ds: &Dataset,
    precision: Precision,
    batch: usize,
    limit: Option<usize>,
) -> AccuracyResult {
    let n = limit.unwrap_or(ds.len()).min(ds.len());
    let mut hit1 = 0usize;
    let mut hit5 = 0usize;
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let x = ds.batch(i, b);
        let logits = engine.forward(&x, precision);
        for r in 0..b {
            let row = logits.row(r);
            let label = ds.labels[i + r];
            if topk_hit(row, label, 1) {
                hit1 += 1;
            }
            if topk_hit(row, label, 5) {
                hit5 += 1;
            }
        }
        i += b;
    }
    AccuracyResult { n, top1: hit1 as f64 / n as f64, top5: hit5 as f64 / n as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_semantics() {
        let logits = [0.1f32, 0.9, 0.5, 0.3];
        assert!(topk_hit(&logits, 1, 1));
        assert!(!topk_hit(&logits, 2, 1));
        assert!(topk_hit(&logits, 2, 2));
        assert!(topk_hit(&logits, 0, 4));
        assert!(!topk_hit(&logits, 0, 3));
    }

    #[test]
    fn topk_tie_favours_label() {
        let logits = [0.5f32, 0.5, 0.1];
        assert!(topk_hit(&logits, 0, 1));
        assert!(topk_hit(&logits, 1, 1));
    }
}
