//! Fixed-width table rendering for experiment reports (the benches print the
//! same rows as the paper's tables).

/// Builder for an aligned text table.
#[derive(Debug, Default)]
pub struct TableFmt {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableFmt {
    pub fn new(title: &str, header: &[&str]) -> TableFmt {
        TableFmt {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableFmt::new("Demo", &["name", "value"]);
        t.row_str(&["a", "1"]).row_str(&["longer-name", "22"]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns aligned: "value" starts at same offset in all rows
        let col = lines[1].find("value").unwrap();
        assert_eq!(&lines[3][col..col + 1], "1");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn wrong_width_panics() {
        TableFmt::new("t", &["a", "b"]).row_str(&["only-one"]);
    }
}
