//! S12 — evaluation harness: top-k accuracy, sweep drivers, table rendering.
//!
//! The accuracy experiments (Tables 1–2, Figs. 9–10) all reduce to "run the
//! engine at precision P over the validation set and report top-1/top-5";
//! this module owns that loop plus the fixed-width table formatter the
//! benches/examples print (mirroring the paper's table rows).
pub mod accuracy;
pub mod sweep;
pub mod table;

pub use accuracy::{evaluate, topk_hit, AccuracyResult};
pub use table::TableFmt;
