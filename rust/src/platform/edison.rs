//! Intel Edison / Silvermont analytic cost model (paper §VI.B, Fig. 8).
//!
//! The Edison's Silvermont core executes 128-bit SIMD: 4 f32 lanes (one
//! `mulps` + `addps` pair per MAC, no FMA) or 16 8-bit lanes with
//! `pmaddubsw`-style integer MAC. Per-layer runtime is the max of a compute
//! term (MACs / effective MAC throughput) and a memory term (operand traffic
//! / bandwidth), plus the runtime quantization pass for fixed-point inputs.
//!
//! The constants below are calibrated to public Silvermont/Edison figures
//! (500 MHz Atom-class SIMD, ~1.3 GB/s effective stream bandwidth) —
//! absolute times are estimates; the *ratio* between f32 and fixed-point
//! (the paper's "about 2x") is driven by lane count vs quantization overhead
//! and survives constant changes (see tests).

use crate::nn::arch::{Arch, Layer};
use crate::nn::opcount;

/// One numeric configuration on the Edison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumFmt {
    F32,
    /// Fixed point with this many activation bits (weights 8-bit).
    Fixed(u8),
}

/// Machine constants (public defaults; override for sensitivity studies).
#[derive(Debug, Clone, Copy)]
pub struct EdisonModel {
    /// Core clock in Hz.
    pub freq: f64,
    /// SIMD register width in bits.
    pub simd_bits: usize,
    /// Cycles per SIMD integer MAC op (multiply-add over a full register).
    pub int_mac_cycles: f64,
    /// Cycles per SIMD f32 MAC (mul + add, no FMA on Silvermont).
    pub f32_mac_cycles: f64,
    /// Effective streaming bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Cycles per element for the runtime input-quantization pass.
    pub quant_cycles_per_elem: f64,
}

impl Default for EdisonModel {
    fn default() -> Self {
        EdisonModel {
            freq: 500e6,
            simd_bits: 128,
            // unpack + pmadd + widen-accumulate chain per 8-wide group
            int_mac_cycles: 2.0,
            f32_mac_cycles: 2.0, // mulps + addps
            mem_bw: 1.3e9,
            quant_cycles_per_elem: 1.5,
        }
    }
}

/// Per-layer estimate breakdown.
#[derive(Debug, Clone, Copy)]
pub struct LayerEstimate {
    pub compute_s: f64,
    pub memory_s: f64,
    pub quantize_s: f64,
}

impl LayerEstimate {
    /// Compute and memory overlap (streamed); quantization is a serial pass.
    pub fn total(&self) -> f64 {
        self.compute_s.max(self.memory_s) + self.quantize_s
    }
}

impl EdisonModel {
    /// Effective SIMD MAC lanes for a numeric width. Sub-byte codes are
    /// unpacked to 8-bit lanes for arithmetic (no sub-8-bit ISA — paper
    /// §V.A); integer MACs go through `pmaddubsw`/`pmaddwd`, which pair the
    /// 16 byte lanes into 8 multiply-add results per instruction; *memory
    /// traffic* still shrinks with bits.
    pub fn lanes(&self, fmt: NumFmt) -> usize {
        match fmt {
            NumFmt::F32 => self.simd_bits / 32,
            NumFmt::Fixed(_) => self.simd_bits / 16,
        }
    }

    fn mac_cycles(&self, fmt: NumFmt) -> f64 {
        match fmt {
            NumFmt::F32 => self.f32_mac_cycles,
            NumFmt::Fixed(_) => self.int_mac_cycles,
        }
    }

    /// Bytes moved per weight / activation element.
    fn elem_bytes(&self, fmt: NumFmt, weight: bool) -> f64 {
        match fmt {
            NumFmt::F32 => 4.0,
            NumFmt::Fixed(bits) => {
                if weight {
                    1.0 // weights stored as 8-bit codes
                } else {
                    bits as f64 / 8.0 // packed activation codes
                }
            }
        }
    }

    /// Estimate one layer at batch size 1.
    pub fn layer_estimate(&self, arch: &Arch, layer: &Layer, fmt: NumFmt) -> LayerEstimate {
        let (macs, w_elems, a_elems): (f64, f64, f64) = match *layer {
            Layer::Conv { cin, cout, k, groups, .. } => {
                let macs = opcount::conv_macs(arch, layer) as f64;
                let w = (cout * (cin / groups) * k * k) as f64;
                // im2col activation reads: one patch per output position.
                let a = macs / cout as f64;
                (macs, w, a)
            }
            Layer::Fc { cin, cout, .. } => {
                let macs = (cin * cout) as f64;
                (macs, macs, cin as f64)
            }
        };
        let compute = macs * self.mac_cycles(fmt) / (self.lanes(fmt) as f64) / self.freq;
        let bytes = w_elems * self.elem_bytes(fmt, true) + a_elems * self.elem_bytes(fmt, false);
        let memory = bytes / self.mem_bw;
        let quantize = match fmt {
            NumFmt::F32 => 0.0,
            NumFmt::Fixed(_) => a_elems * self.quant_cycles_per_elem / self.freq,
        };
        LayerEstimate { compute_s: compute, memory_s: memory, quantize_s: quantize }
    }

    /// Whole-network per-image runtime estimate (seconds).
    pub fn image_time(&self, arch: &Arch, fmt: NumFmt) -> f64 {
        arch.layers.iter().map(|l| self.layer_estimate(arch, l, fmt).total()).sum()
    }

    /// Fig. 8's headline: f32 time / fixed time.
    pub fn speedup(&self, arch: &Arch, fmt: NumFmt) -> f64 {
        self.image_time(arch, NumFmt::F32) / self.image_time(arch, fmt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::arch::Arch;

    #[test]
    fn fig8_shape_8bit_about_2x() {
        // The paper reports "about 2 times" on both networks.
        let m = EdisonModel::default();
        for arch in [Arch::alexnet_full(), Arch::vgg16_full()] {
            let s = m.speedup(&arch, NumFmt::Fixed(8));
            assert!(
                (1.5..3.5).contains(&s),
                "{}: 8-bit speedup {s} outside the paper's ballpark",
                arch.name
            );
        }
    }

    #[test]
    fn lower_bits_never_slower() {
        let m = EdisonModel::default();
        let arch = Arch::vgg16_full();
        let t8 = m.image_time(&arch, NumFmt::Fixed(8));
        let t4 = m.image_time(&arch, NumFmt::Fixed(4));
        let t2 = m.image_time(&arch, NumFmt::Fixed(2));
        assert!(t4 <= t8 + 1e-12, "4-bit {t4} vs 8-bit {t8}");
        assert!(t2 <= t4 + 1e-12);
    }

    #[test]
    fn vgg_slower_than_alexnet() {
        // Fig. 8's bars: VGG-16 per-image time >> AlexNet (23x the MACs).
        let m = EdisonModel::default();
        let ta = m.image_time(&Arch::alexnet_full(), NumFmt::F32);
        let tv = m.image_time(&Arch::vgg16_full(), NumFmt::F32);
        assert!(tv > 5.0 * ta, "alexnet {ta}s vgg {tv}s");
    }

    #[test]
    fn estimates_positive_and_finite() {
        let m = EdisonModel::default();
        let arch = Arch::minialexnet();
        for l in &arch.layers {
            for fmt in [NumFmt::F32, NumFmt::Fixed(8), NumFmt::Fixed(2)] {
                let e = m.layer_estimate(&arch, l, fmt);
                assert!(e.total().is_finite() && e.total() > 0.0);
            }
        }
    }
}
