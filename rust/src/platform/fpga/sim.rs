//! Cycle-level functional simulator of the 4x4 CU Matrix Multiplier
//! (paper Fig. 11–12).
//!
//! Dataflow: an output-stationary systolic array. The ISC streams rows of
//! the (quantized) input matrix from the west edge; the PSC streams columns
//! of the parameter matrix from the north edge; operands hop one CU per
//! cycle with the classic diagonal skew, and each CU multiply-accumulates
//! the pair it sees each cycle. After `M + N + K - 2` beats (plus the CU
//! pipeline latency) CU(i,j) holds `sum_k A[i,k] * B[k,j]`.
//!
//! For matrices larger than the 4x4 grid the schedule tiles the output and
//! re-streams operand panels, accumulating partial products in place —
//! exactly what the ISC/PSC address generators do in the paper's design.
//!
//! This proves the datapath computes the exact integer product (tests pin
//! it against a plain GEMM) and provides honest cycle counts for the
//! throughput discussion.

use crate::platform::fpga::resource::{estimate, CuConfig};

/// Grid dimension (paper: 4x4).
pub const GRID: usize = 4;

/// Result of simulating one matrix multiplication.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Exact product A*B as i64, row-major (m, n).
    pub out: Vec<i64>,
    pub m: usize,
    pub n: usize,
    /// Total beats (array cycles) including drain, excluding CU latency.
    pub cycles: u64,
    /// MAC operations actually performed by CUs (utilization numerator).
    pub macs: u64,
}

impl SimResult {
    /// Fraction of CU-cycles doing useful MACs.
    pub fn utilization(&self) -> f64 {
        self.macs as f64 / (self.cycles as f64 * (GRID * GRID) as f64)
    }
}

/// One CU: a registered multiply-accumulator with operand forwarding.
#[derive(Debug, Clone, Copy, Default)]
struct Cu {
    acc: i64,
    a_reg: Option<i32>,
    b_reg: Option<i32>,
}

/// Simulate `A (m,k) x B (k,n)` on the systolic array, cycle by cycle.
///
/// `a` and `b` are integer operands (quantization codes); values must fit
/// the configured widths — checked against `cfg` so the simulation honestly
/// models the hardware's operand range.
pub fn simulate(cfg: CuConfig, a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> SimResult {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    if let CuConfig::Fixed { wp, wi } = cfg {
        let a_max = (1i32 << wi) - 1;
        let b_max = (1i32 << wp) - 1;
        assert!(
            a.iter().all(|&v| (0..=a_max).contains(&v)),
            "input codes exceed {wi}-bit range"
        );
        assert!(
            b.iter().all(|&v| (0..=b_max).contains(&v)),
            "parameter codes exceed {wp}-bit range"
        );
    }

    let mut out = vec![0i64; m * n];
    let mut cycles = 0u64;
    let mut macs = 0u64;

    // Tile the output grid; re-stream the K panels for each tile.
    for ti in (0..m).step_by(GRID) {
        for tj in (0..n).step_by(GRID) {
            let th = GRID.min(m - ti);
            let tw = GRID.min(n - tj);
            let mut grid = [[Cu::default(); GRID]; GRID];
            // Skewed streaming: beat t injects a[i][t - i] at row i's west
            // edge and b[t - j][j] at column j's north edge.
            let beats = k + th + tw - 2 + 1;
            for t in 0..beats {
                // Shift east/south from the far corner backwards.
                for i in (0..th).rev() {
                    for j in (0..tw).rev() {
                        let a_in = if j == 0 {
                            let kk = t as isize - i as isize;
                            if kk >= 0 && (kk as usize) < k {
                                Some(a[(ti + i) * k + kk as usize])
                            } else {
                                None
                            }
                        } else {
                            grid[i][j - 1].a_reg
                        };
                        let b_in = if i == 0 {
                            let kk = t as isize - j as isize;
                            if kk >= 0 && (kk as usize) < k {
                                Some(b[kk as usize * n + (tj + j)])
                            } else {
                                None
                            }
                        } else {
                            grid[i - 1][j].b_reg
                        };
                        // MAC happens on the freshly arriving pair. The skew
                        // guarantees a[i][kk] and b[kk][j] meet at CU(i,j).
                        if let (Some(av), Some(bv)) = (a_in, b_in) {
                            grid[i][j].acc += av as i64 * bv as i64;
                            macs += 1;
                        }
                        grid[i][j].a_reg = a_in;
                        grid[i][j].b_reg = b_in;
                    }
                }
                cycles += 1;
            }
            for i in 0..th {
                for j in 0..tw {
                    out[(ti + i) * n + (tj + j)] += grid[i][j].acc;
                }
            }
        }
    }
    // Account for the CU pipeline depth once per tile drain.
    let r = estimate(cfg);
    let tiles = m.div_ceil(GRID) as u64 * n.div_ceil(GRID) as u64;
    cycles += tiles * r.latency as u64;

    SimResult { out, m, n, cycles, macs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ref_gemm(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i64> {
        let mut out = vec![0i64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for p in 0..k {
                    acc += a[i * k + p] as i64 * b[p * n + j] as i64;
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn codes(rng: &mut Rng, len: usize, bits: u8) -> Vec<i32> {
        (0..len).map(|_| rng.below(1 << bits) as i32).collect()
    }

    #[test]
    fn exact_product_all_configs() {
        let mut rng = Rng::new(0x51);
        for &(m, k, n) in &[(4usize, 4usize, 4usize), (4, 16, 4), (7, 5, 9), (1, 1, 1), (3, 12, 2)] {
            for cfg in [
                CuConfig::Fixed { wp: 8, wi: 8 },
                CuConfig::Fixed { wp: 8, wi: 4 },
                CuConfig::Fixed { wp: 8, wi: 2 },
            ] {
                let wi = match cfg {
                    CuConfig::Fixed { wi, .. } => wi,
                    _ => unreachable!(),
                };
                let a = codes(&mut rng, m * k, wi);
                let b = codes(&mut rng, k * n, 8);
                let sim = simulate(cfg, &a, &b, m, k, n);
                assert_eq!(sim.out, ref_gemm(&a, &b, m, k, n), "{m}x{k}x{n} {cfg:?}");
            }
        }
    }

    #[test]
    fn cycle_count_scales_with_k() {
        let mut rng = Rng::new(1);
        let cfg = CuConfig::Fixed { wp: 8, wi: 8 };
        let a16 = codes(&mut rng, 4 * 16, 8);
        let b16 = codes(&mut rng, 16 * 4, 8);
        let a64 = codes(&mut rng, 4 * 64, 8);
        let b64 = codes(&mut rng, 64 * 4, 8);
        let s16 = simulate(cfg, &a16, &b16, 4, 16, 4);
        let s64 = simulate(cfg, &a64, &b64, 4, 64, 4);
        assert!(s64.cycles > s16.cycles * 2, "{} vs {}", s64.cycles, s16.cycles);
    }

    #[test]
    fn utilization_improves_with_larger_k() {
        let mut rng = Rng::new(2);
        let cfg = CuConfig::Fixed { wp: 8, wi: 8 };
        let mk = |k: usize, rng: &mut Rng| {
            let a = codes(rng, 4 * k, 8);
            let b = codes(rng, k * 4, 8);
            simulate(cfg, &a, &b, 4, k, 4).utilization()
        };
        let u4 = mk(4, &mut rng);
        let u64_ = mk(64, &mut rng);
        assert!(u64_ > u4, "util should rise with K: {u4} -> {u64_}");
        assert!(u64_ > 0.7, "long-K utilization {u64_}");
    }

    #[test]
    #[should_panic(expected = "input codes exceed")]
    fn rejects_out_of_range_codes() {
        let cfg = CuConfig::Fixed { wp: 8, wi: 2 };
        simulate(cfg, &[5], &[1], 1, 1, 1); // 5 needs 3 bits
    }
}
