//! Structural resource/timing estimator for the Matrix Multiplier (Table 4).
//!
//! The paper's design (Fig. 11–12): a 4x4 grid of Computing Units (CUs);
//! each CU is a multiply-accumulator of width `Wp x Wi` fed by the Input /
//! Parameter Stream Controllers. Fixed-point multiplier area on a LUT6
//! fabric scales ~ Wp*Wi (partial-product array) plus an accumulator of
//! `Wp + Wi + guard` bits; FP32 adds alignment/normalisation barrel
//! shifters, which is why its CU is ~10x larger and 3 cycles deeper.
//!
//! Constants calibrated against the paper's ISE 13.4 synthesis (Table 4);
//! see tests for the tolerance we hold (±20% per entry, exact orderings).

/// One CU configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CuConfig {
    /// IEEE-754 single precision MAC.
    Fp32,
    /// Fixed point: weight bits x input bits.
    Fixed { wp: u8, wi: u8 },
}

impl CuConfig {
    pub fn label(&self) -> String {
        match self {
            CuConfig::Fp32 => "FP 32x32".into(),
            CuConfig::Fixed { wp, wi } => format!("Fixed {wp}x{wi}"),
        }
    }

    /// The four rows of Table 4/5.
    pub fn paper_rows() -> Vec<CuConfig> {
        vec![
            CuConfig::Fp32,
            CuConfig::Fixed { wp: 8, wi: 8 },
            CuConfig::Fixed { wp: 8, wi: 4 },
            CuConfig::Fixed { wp: 8, wi: 2 },
        ]
    }
}

/// Synthesis estimate for the whole 4x4 Matrix Multiplier module.
#[derive(Debug, Clone, Copy)]
pub struct ResourceEstimate {
    pub luts: u64,
    pub ffs: u64,
    pub fmax_mhz: f64,
    /// Pipeline latency in cycles (input to accumulated output).
    pub latency: u32,
}

/// CUs in the module (paper: "Our Matrix Multiplier has 4x4 CU").
pub const GRID_CUS: u64 = 16;

/// Available LUTs on the XC6VLX240T.
pub const DEVICE_LUTS: u64 = 150_720;

/// Estimate one CU configuration.
pub fn estimate(cfg: CuConfig) -> ResourceEstimate {
    match cfg {
        CuConfig::Fp32 => {
            // FP32 MAC on LUT fabric (no DSP48 inference, as in the paper's
            // area-focused design): 24x24 significand multiplier + barrel
            // shifters for alignment/normalisation dominate.
            let lut_cu = 1062.0;
            let ff_cu = 690.0;
            ResourceEstimate {
                luts: (lut_cu * GRID_CUS as f64 + stream_controllers(32.0, 32.0)) as u64,
                ffs: (ff_cu * GRID_CUS as f64 + stream_ffs(32.0, 32.0)) as u64,
                fmax_mhz: 269.0, // long normalise path; matches ISE synthesis
                latency: 8,      // mult (3) + align (2) + add (2) + normalise (1)
            }
        }
        CuConfig::Fixed { wp, wi } => {
            let (wp, wi) = (wp as f64, wi as f64);
            // Partial-product array (~1.2 LUT6 per product bit incl. the
            // compressor tree) + accumulator/control overhead per CU.
            let lut_cu = 1.2 * wp * wi + 11.0;
            // FFs: pipeline registers across the product + operand staging.
            let ff_cu = 0.75 * wp * wi + 2.6 * (wp + wi) - 10.0;
            // Critical path: up to 32 partial products the compressor tree
            // retimes into the 2-3 stage pipeline and the path is dominated
            // by the carry chain (shallow growth); the 8x8 array exceeds one
            // LUT level per row and the tree depth takes over.
            let pp = wp * wi;
            let delay_ns =
                if pp <= 32.0 { 1.72 + 0.005 * pp } else { 0.95 + 0.36 * pp.log2() };
            let latency = if pp <= 16.0 { 2 } else { 3 };
            ResourceEstimate {
                luts: (lut_cu * GRID_CUS as f64 + stream_controllers(wp, wi)) as u64,
                ffs: (ff_cu * GRID_CUS as f64 + stream_ffs(wp, wi)) as u64,
                fmax_mhz: 1000.0 / delay_ns,
                latency,
            }
        }
    }
}

/// ISC + PSC (Fig. 11): operand fan-out registers and address counters,
/// scaling with operand width across the 4-wide row/column buses.
fn stream_controllers(wp: f64, wi: f64) -> f64 {
    8.0 * (wp + wi) + 32.0
}

fn stream_ffs(wp: f64, wi: f64) -> f64 {
    10.0 * (wp + wi) + 24.0
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 4 reference values.
    const PAPER: [(&str, u64, u64, f64, u32); 4] = [
        ("FP 32x32", 17534, 11586, 269.0, 8),
        ("Fixed 8x8", 1571, 1442, 322.0, 3),
        ("Fixed 8x4", 923, 962, 532.0, 3),
        ("Fixed 8x2", 535, 562, 556.0, 2),
    ];

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn table4_within_20pct() {
        for (cfg, &(label, luts, ffs, fmax, lat)) in
            CuConfig::paper_rows().iter().zip(PAPER.iter())
        {
            let e = estimate(*cfg);
            assert_eq!(cfg.label(), label);
            assert!(
                rel_err(e.luts as f64, luts as f64) < 0.20,
                "{label}: LUTs {} vs paper {luts}",
                e.luts
            );
            assert!(
                rel_err(e.ffs as f64, ffs as f64) < 0.20,
                "{label}: FFs {} vs paper {ffs}",
                e.ffs
            );
            assert!(
                rel_err(e.fmax_mhz, fmax) < 0.20,
                "{label}: Fmax {} vs paper {fmax}",
                e.fmax_mhz
            );
            assert_eq!(e.latency, lat, "{label}: latency");
        }
    }

    #[test]
    fn orderings_match_paper() {
        let rows: Vec<ResourceEstimate> =
            CuConfig::paper_rows().into_iter().map(estimate).collect();
        // LUTs strictly decreasing FP32 > 8x8 > 8x4 > 8x2; Fmax increasing.
        for w in rows.windows(2) {
            assert!(w[0].luts > w[1].luts);
            assert!(w[0].ffs > w[1].ffs);
            assert!(w[0].fmax_mhz < w[1].fmax_mhz);
            assert!(w[0].latency >= w[1].latency);
        }
    }

    #[test]
    fn narrower_inputs_cheaper() {
        let l8 = estimate(CuConfig::Fixed { wp: 8, wi: 8 }).luts;
        let l1 = estimate(CuConfig::Fixed { wp: 8, wi: 1 }).luts;
        assert!(l1 < l8 / 2, "1-bit CU should be much smaller: {l1} vs {l8}");
    }
}
