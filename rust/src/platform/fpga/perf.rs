//! Throughput / power model for the Matrix Multiplier (Table 5).
//!
//! - **Performance @ max freq @ 90% utilization of LUTs** (paper note 1):
//!   fill 90% of the device with multiplier modules of the given config and
//!   run them at their Fmax; each CU contributes one multiply + one add per
//!   cycle (2 ops).
//! - **Power @ 200 MHz** (paper note 2): dynamic (clock/logic/signal) power
//!   of a *single* multiplier module, modelled as a base clock-tree term
//!   plus a per-LUT switching term — the standard first-order CV²f model
//!   with constants fit to the paper's XPower numbers.

use crate::platform::fpga::resource::{estimate, CuConfig, DEVICE_LUTS, GRID_CUS};

/// Table 5 row for one configuration.
#[derive(Debug, Clone, Copy)]
pub struct PerfEstimate {
    /// Giga-ops/s (or Gflops for FP32) at 90% utilization and max frequency.
    pub gops_at_max: f64,
    /// Dynamic power of one module at 200 MHz, in mW.
    pub power_mw_200: f64,
    /// Modules that fit in 90% of the device.
    pub modules: u64,
}

/// Per-LUT dynamic power at 200 MHz (mW) and clock-tree base (mW), fit to
/// the paper's four XPower measurements.
const MW_PER_LUT: f64 = 0.0358;
const MW_BASE: f64 = 15.0;

pub fn perf(cfg: CuConfig) -> PerfEstimate {
    let r = estimate(cfg);
    let budget = (DEVICE_LUTS as f64) * 0.90;
    let modules = (budget / r.luts as f64).floor() as u64;
    let cus = modules * GRID_CUS;
    let gops_at_max = cus as f64 * 2.0 * r.fmax_mhz * 1e6 / 1e9;
    let power_mw_200 = MW_BASE + MW_PER_LUT * r.luts as f64;
    PerfEstimate { gops_at_max, power_mw_200, modules }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 5: (config, Gops@max, mW@200MHz).
    const PAPER: [(f64, f64); 4] =
        [(67.0, 643.0), (890.0, 71.0), (2502.0, 51.0), (4511.0, 37.0)];

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn table5_performance_within_25pct() {
        for (cfg, &(gops, _)) in CuConfig::paper_rows().iter().zip(PAPER.iter()) {
            let p = perf(*cfg);
            assert!(
                rel_err(p.gops_at_max, gops) < 0.25,
                "{}: {} Gops vs paper {gops}",
                cfg.label(),
                p.gops_at_max
            );
        }
    }

    #[test]
    fn table5_power_within_25pct() {
        for (cfg, &(_, mw)) in CuConfig::paper_rows().iter().zip(PAPER.iter()) {
            let p = perf(*cfg);
            assert!(
                rel_err(p.power_mw_200, mw) < 0.25,
                "{}: {} mW vs paper {mw}",
                cfg.label(),
                p.power_mw_200
            );
        }
    }

    #[test]
    fn low_bits_dominate_perf_per_watt() {
        // The paper's conclusion: each halving of input width improves both
        // throughput and power.
        let rows: Vec<PerfEstimate> = CuConfig::paper_rows().into_iter().map(perf).collect();
        for w in rows.windows(2) {
            assert!(w[1].gops_at_max > w[0].gops_at_max);
            assert!(w[1].power_mw_200 < w[0].power_mw_200);
        }
        let fp = &rows[0];
        let f82 = &rows[3];
        let ratio = (f82.gops_at_max / f82.power_mw_200) / (fp.gops_at_max / fp.power_mw_200);
        assert!(ratio > 50.0, "8x2 perf/W should crush FP32: {ratio}x");
    }
}
