//! Whole-network mapping onto the Matrix Multiplier substrate.
//!
//! Extends Tables 4–5 from a single module to a full deployment estimate:
//! tile every conv/fc layer of an [`Arch`] into 4x4 GEMM panels, count the
//! exact cycles the systolic schedule needs (same formula the cycle-level
//! simulator realizes, validated against it in tests), and combine with the
//! per-configuration Fmax/power models to estimate per-image latency and
//! energy at each precision — the end-to-end version of the paper's §VI.H
//! conclusion that narrow CUs win on both speed and power.

use crate::nn::arch::{Arch, Layer};
use crate::nn::opcount::conv_macs;
use crate::platform::fpga::perf::perf;
use crate::platform::fpga::resource::{estimate, CuConfig};
use crate::platform::fpga::sim::GRID;

/// Per-image deployment estimate for one (network, CU config) pair.
#[derive(Debug, Clone, Copy)]
pub struct MappingEstimate {
    /// Total array beats across all layer tiles (one module).
    pub cycles: u64,
    /// Latency per image at the configuration's Fmax, milliseconds.
    pub latency_ms: f64,
    /// Energy per image at 200 MHz operating point, millijoules.
    pub energy_mj: f64,
    /// MAC utilization of the schedule (MACs / (cycles * 16 CUs)).
    pub utilization: f64,
}

/// Cycles for one (m, k, n) GEMM tiled on the 4x4 array: each 4x4 output
/// tile streams K with skew fill/drain, plus the CU pipeline latency per
/// tile. Mirrors `sim::simulate`'s accounting exactly (pinned by tests).
pub fn gemm_cycles(cfg: CuConfig, m: usize, k: usize, n: usize) -> u64 {
    let r = estimate(cfg);
    let tiles_m = m.div_ceil(GRID) as u64;
    let tiles_n = n.div_ceil(GRID) as u64;
    let mut cycles = 0u64;
    // Tail tiles have smaller th/tw: beats = k + th + tw - 1.
    for ti in 0..tiles_m {
        let th = GRID.min(m - ti as usize * GRID) as u64;
        for tj in 0..tiles_n {
            let tw = GRID.min(n - tj as usize * GRID) as u64;
            cycles += k as u64 + th + tw - 1;
        }
    }
    cycles + tiles_m * tiles_n * r.latency as u64
}

/// GEMM geometry of a layer at batch 1 (im2col formulation).
fn layer_gemm(arch: &Arch, l: &Layer) -> (usize, usize, usize) {
    match *l {
        Layer::Conv { cout, cin, k, groups, .. } => {
            let macs = conv_macs(arch, l);
            let patch = cin / groups * k * k;
            let positions = (macs / (cout as u64 * patch as u64)) as usize;
            (positions * groups, patch, cout / groups)
        }
        Layer::Fc { cin, cout, .. } => (1, cin, cout),
    }
}

/// Map the whole network at batch 1.
pub fn map_network(arch: &Arch, cfg: CuConfig) -> MappingEstimate {
    let r = estimate(cfg);
    let p = perf(cfg);
    let mut cycles = 0u64;
    let mut macs = 0u64;
    for l in &arch.layers {
        let (m, k, n) = layer_gemm(arch, l);
        cycles += gemm_cycles(cfg, m, k, n);
        macs += (m * k * n) as u64;
    }
    let latency_ms = cycles as f64 / (r.fmax_mhz * 1e6) * 1e3;
    // Energy at the 200 MHz measurement point: P * t(200MHz).
    let t200_s = cycles as f64 / 200e6;
    let energy_mj = p.power_mw_200 * t200_s;
    MappingEstimate {
        cycles,
        latency_ms,
        energy_mj,
        utilization: macs as f64 / (cycles as f64 * (GRID * GRID) as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::fpga::sim::simulate;
    use crate::util::rng::Rng;

    #[test]
    fn cycles_match_simulator_exactly() {
        let mut rng = Rng::new(7);
        let cfg = CuConfig::Fixed { wp: 8, wi: 2 };
        for &(m, k, n) in &[(4usize, 8usize, 4usize), (7, 20, 9), (16, 363, 12), (1, 5, 1)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.below(4) as i32).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
            let sim = simulate(cfg, &a, &b, m, k, n);
            assert_eq!(
                gemm_cycles(cfg, m, k, n),
                sim.cycles,
                "analytic cycles diverge from the simulator at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn narrower_inputs_faster_and_cheaper() {
        // The §VI.H conclusion at whole-network scale.
        let arch = crate::nn::Arch::alexnet_full();
        let rows: Vec<MappingEstimate> = [
            CuConfig::Fixed { wp: 8, wi: 8 },
            CuConfig::Fixed { wp: 8, wi: 4 },
            CuConfig::Fixed { wp: 8, wi: 2 },
        ]
        .into_iter()
        .map(|c| map_network(&arch, c))
        .collect();
        for w in rows.windows(2) {
            assert!(w[1].latency_ms <= w[0].latency_ms, "latency must not rise");
            assert!(w[1].energy_mj < w[0].energy_mj, "energy must fall");
        }
        // Near-identical cycle count (same schedule; only the per-tile
        // pipeline latency differs) — the gain is Fmax + power.
        let rel = (rows[0].cycles as f64 - rows[2].cycles as f64).abs() / rows[0].cycles as f64;
        assert!(rel < 0.005, "schedules should match within pipeline latency: {rel}");
    }

    #[test]
    fn long_k_layers_dominate_utilization() {
        let arch = crate::nn::Arch::vgg16_full();
        let e = map_network(&arch, CuConfig::Fixed { wp: 8, wi: 8 });
        assert!(e.utilization > 0.8, "VGG's long reductions should keep CUs busy: {}", e.utilization);
    }

    #[test]
    fn fp32_much_slower_than_fixed() {
        let arch = crate::nn::Arch::alexnet_full();
        let fp = map_network(&arch, CuConfig::Fp32);
        let f82 = map_network(&arch, CuConfig::Fixed { wp: 8, wi: 2 });
        assert!(fp.latency_ms > 1.5 * f82.latency_ms);
        assert!(fp.energy_mj > 10.0 * f82.energy_mj);
    }
}
