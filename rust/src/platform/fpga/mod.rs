//! Xilinx Virtex-6 Matrix Multiplier substrate (paper §VI.H, Fig. 11–12).
//!
//! Three pieces:
//! - [`resource`] — structural LUT/FF/Fmax/latency estimator per CU
//!   configuration (Table 4) calibrated to LUT6 costs on XC6VLX240T.
//! - [`perf`]     — throughput @ 90% device utilization and dynamic power
//!   @ 200 MHz (Table 5).
//! - [`sim`]      — cycle-level functional simulator of the 4x4 CU array
//!   with ISC/PSC operand streaming; proves the dataflow computes exact
//!   integer matrix products and measures cycle counts.
pub mod mapper;
pub mod perf;
pub mod resource;
pub mod sim;

pub use resource::{CuConfig, ResourceEstimate};
