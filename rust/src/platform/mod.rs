//! S9/S10 — platform models standing in for the paper's hardware testbeds.
//!
//! - [`edison`] — Intel Edison (Silvermont) analytic cost model: SIMD
//!   throughput + memory bandwidth per numeric width. Regenerates the Fig. 8
//!   speedup shape for the *full* AlexNet / VGG-16 (which we cannot run with
//!   real weights) alongside the measured mini-model numbers.
//! - [`fpga`] — Xilinx Virtex-6 matrix-multiplier substrate: structural
//!   LUT/FF resource estimation, timing and power models (Tables 4–5), and a
//!   cycle-level functional simulator of the 4x4 CU array with ISC/PSC
//!   operand streaming that proves the datapath computes exact products.
pub mod edison;
pub mod fpga;
