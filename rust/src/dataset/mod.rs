//! S11 — dataset access for the runtime side.
//!
//! The build-time python generator (`python/compile/datagen.py`) writes the
//! synthetic 16-class shape dataset to `artifacts/data/{train,val}.npz`; this
//! module loads those for the accuracy experiments and samples them to drive
//! serving workloads. A pure-noise generator is provided for load tests that
//! do not care about labels.

use std::path::Path;

use anyhow::{Context, Result};

use crate::tensor::{read_npz, Tensor};
use crate::util::rng::Rng;

/// An in-memory labelled image set (NCHW f32 in [0,1]).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Tensor,
    pub labels: Vec<i32>,
}

impl Dataset {
    /// Load `{split}.npz` (keys: x f32 (N,C,H,W), y int (N,)).
    pub fn load(dir: impl AsRef<Path>, split: &str) -> Result<Dataset> {
        let path = dir.as_ref().join(format!("{split}.npz"));
        let entries = read_npz(&path)
            .with_context(|| format!("loading {} (run `make artifacts`)", path.display()))?;
        let mut images = None;
        let mut labels = None;
        for e in entries {
            match e.name.as_str() {
                // `into_tensor` moves the decoded storage: the dataset is
                // the largest npz in the repo, and this load used to copy it.
                "x" => images = Some(e.into_tensor()),
                "y" => {
                    labels = Some(match e.as_i32() {
                        Some(v) => v.to_vec(),
                        None => e.into_tensor().into_data().iter().map(|&f| f as i32).collect(),
                    })
                }
                _ => {}
            }
        }
        let images = images.context("npz missing 'x'")?;
        let labels = labels.context("npz missing 'y'")?;
        anyhow::ensure!(images.rank() == 4, "x must be NCHW, got {:?}", images.shape());
        anyhow::ensure!(images.dim(0) == labels.len(), "x/y length mismatch");
        Ok(Dataset { images, labels })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// (C, H, W) of one image.
    pub fn image_shape(&self) -> (usize, usize, usize) {
        (self.images.dim(1), self.images.dim(2), self.images.dim(3))
    }

    /// Copy image `i` as a `(1, C, H, W)` tensor.
    pub fn image(&self, i: usize) -> Tensor {
        let (c, h, w) = self.image_shape();
        let per = c * h * w;
        Tensor::new(&[1, c, h, w], self.images.data()[i * per..(i + 1) * per].to_vec())
    }

    /// Copy images `[start, start+n)` as an `(n, C, H, W)` batch.
    pub fn batch(&self, start: usize, n: usize) -> Tensor {
        let (c, h, w) = self.image_shape();
        let per = c * h * w;
        assert!(start + n <= self.len());
        Tensor::new(
            &[n, c, h, w],
            self.images.data()[start * per..(start + n) * per].to_vec(),
        )
    }

    /// First `n` examples as a smaller dataset (cheap experiment subsets).
    pub fn take(&self, n: usize) -> Dataset {
        let n = n.min(self.len());
        Dataset { images: self.batch(0, n), labels: self.labels[..n].to_vec() }
    }

    /// Sample a random index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.index(0, self.len())
    }
}

/// Random-noise image batch `(n, C, H, W)` in [0, 1] — for load tests.
pub fn noise_batch(rng: &mut Rng, n: usize, c: usize, h: usize, w: usize) -> Tensor {
    Tensor::new(&[n, c, h, w], rng.uniform_vec(n * c * h * w, 0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_batch_shape_and_range() {
        let mut rng = Rng::new(1);
        let b = noise_batch(&mut rng, 2, 3, 8, 8);
        assert_eq!(b.shape(), &[2, 3, 8, 8]);
        assert!(b.data().iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn batch_slicing() {
        let images = Tensor::from_fn(&[4, 1, 2, 2], |i| i as f32);
        let ds = Dataset { images, labels: vec![0, 1, 2, 3] };
        let b = ds.batch(1, 2);
        assert_eq!(b.shape(), &[2, 1, 2, 2]);
        assert_eq!(b.data()[0], 4.0); // starts at image 1
        let one = ds.image(3);
        assert_eq!(one.data()[0], 12.0);
        assert_eq!(ds.take(2).len(), 2);
    }

    // Loading the real artifacts npz is covered in rust/tests/npz_interop.rs.
}
