//! Request / response / error types for the serving path.
//!
//! Every submitted request resolves to **exactly one** typed outcome: a
//! successful [`InferResponse`] or a typed [`InferError`]. Workers and the
//! queue send the reply; clients never have to interpret a channel
//! disconnect (`RecvError`) as a failure signal. The full protocol is
//! documented in `docs/serving-robustness.md`.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::tensor::Tensor;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// Scheduling lane for a request. Interactive traffic is formed into
/// batches ahead of bulk whenever both lanes have releasable work, and
/// lane-aware shedding victimizes bulk first — see
/// `docs/serving-robustness.md` ("Scale plane").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive traffic (the default): scheduled first, shed last.
    #[default]
    Interactive,
    /// Throughput traffic (offline scoring, backfills): scheduled when no
    /// interactive batch is releasable, and the first lane shed under
    /// overload.
    Bulk,
}

impl Priority {
    /// Parse a CLI-style name (`interactive` | `bulk`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "bulk" => Some(Priority::Bulk),
            _ => None,
        }
    }

    /// Wire encoding of the lane tag (the optional trailing byte after the
    /// route name — see `coordinator/net.rs`).
    pub fn to_wire(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Bulk => 1,
        }
    }

    /// Decode the wire lane tag; `None` for unknown bytes (typed
    /// `BadRequest` at the ingress, never a default-lane guess).
    pub fn from_wire(b: u8) -> Option<Priority> {
        match b {
            0 => Some(Priority::Interactive),
            1 => Some(Priority::Bulk),
            _ => None,
        }
    }

    /// Stable lane index: 0 = interactive, 1 = bulk.
    pub fn lane(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Bulk => 1,
        }
    }
}

/// Why a request was shed before execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The queue was full and the policy rejects new arrivals.
    QueueFull,
    /// The queue was full and the policy dropped this (oldest) request to
    /// admit a newer one.
    DropOldest,
}

/// Typed failure outcome for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// The backend returned an error (or panicked) and bisection retries
    /// could not complete this request.
    BackendFailed { message: String },
    /// Load shedding dropped the request before execution.
    Shed { reason: ShedReason },
    /// The request's deadline expired before a batch could execute it.
    DeadlineExceeded,
    /// The image shape did not match the batch's expected shape (one route
    /// serves one input geometry).
    ShapeMismatch { expected: Vec<usize>, got: Vec<usize> },
    /// The coordinator shut down before the request could execute.
    ShuttingDown,
    /// The worker pool is irrecoverably dead; no backend will ever run this.
    NoWorkers,
}

impl InferError {
    /// True for transient overload/lifecycle outcomes a client may
    /// reasonably retry (after backoff, or against another replica):
    /// shed, deadline expiry, shutdown. Backend, shape and dead-pool
    /// failures are terminal for the request as posed. The wire path
    /// (`coordinator/net.rs`) forwards this split to remote clients via
    /// `WireStatus::retryable`.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            InferError::Shed { .. } | InferError::DeadlineExceeded | InferError::ShuttingDown
        )
    }
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::BackendFailed { message } => write!(f, "backend failed: {message}"),
            InferError::Shed { reason } => match reason {
                ShedReason::QueueFull => write!(f, "shed: queue full (reject-newest)"),
                ShedReason::DropOldest => write!(f, "shed: dropped oldest under overload"),
            },
            InferError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            InferError::ShapeMismatch { expected, got } => {
                write!(f, "image shape {got:?} does not match route shape {expected:?}")
            }
            InferError::ShuttingDown => write!(f, "coordinator shutting down"),
            InferError::NoWorkers => write!(f, "no live workers (pool is dead)"),
        }
    }
}

impl std::error::Error for InferError {}

/// What a request's receiver gets: exactly one of these.
pub type InferReply = Result<InferResponse, InferError>;

/// One inference request: a single image (1, C, H, W).
#[derive(Debug)]
pub struct InferRequest {
    pub id: RequestId,
    pub image: Tensor,
    pub submitted_at: Instant,
    /// Absolute deadline; requests still queued past it are expired with
    /// [`InferError::DeadlineExceeded`] instead of occupying batch slots.
    pub deadline: Option<Instant>,
    /// Scheduling lane (interactive vs bulk); ignored when the queue runs
    /// with priority lanes disabled.
    pub priority: Priority,
    /// Completion channel; exactly one [`InferReply`] is sent.
    pub reply: mpsc::Sender<InferReply>,
    /// Buffer-reuse hook for the zero-copy wire path: when set, the image's
    /// float storage is handed back through this bounded channel at reply
    /// time — the single point every outcome (success, shed, expiry,
    /// backend failure, shutdown) funnels through, and the last moment the
    /// image is needed (poison bisection re-reads it until then). The send
    /// is `try_send`: a full ring just drops the buffer to the allocator.
    pub recycle: Option<mpsc::SyncSender<Vec<f32>>>,
}

impl InferRequest {
    /// True when the deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Hand the image storage back to the submitter's buffer ring (no-op
    /// without a recycle hook). Must run before the reply send: the
    /// submitter reuses the buffer for its next frame as soon as it wakes.
    fn recycle_image(&mut self) {
        if let Some(tx) = self.recycle.take() {
            let img = std::mem::replace(&mut self.image, Tensor::zeros(&[0]));
            let _ = tx.try_send(img.into_data());
        }
    }

    /// Consume the request with a successful response. The receiver may have
    /// given up; a dropped reply is fine.
    pub fn respond_ok(mut self, resp: InferResponse) {
        self.recycle_image();
        let _ = self.reply.send(Ok(resp));
    }

    /// Consume the request with a typed error, recording it in `metrics`
    /// (`shed` / `expired` / `failed` depending on the error).
    pub fn respond_err(mut self, err: InferError, metrics: &Metrics) {
        self.recycle_image();
        metrics.record_error(&err);
        let _ = self.reply.send(Err(err));
    }
}

/// Completed inference for one request.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: RequestId,
    /// Raw logits over classes.
    pub logits: Vec<f32>,
    /// argmax class.
    pub predicted: usize,
    /// Time spent queued before batch formation.
    pub queue_time: Duration,
    /// Execution time of the batch this request rode in.
    pub execute_time: Duration,
    /// Size of that batch (before padding).
    pub batch_size: usize,
}

impl InferResponse {
    pub fn from_logits(
        id: RequestId,
        logits: Vec<f32>,
        queue_time: Duration,
        execute_time: Duration,
        batch_size: usize,
    ) -> InferResponse {
        let predicted = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        InferResponse { id, logits, predicted, queue_time, execute_time, batch_size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_prediction() {
        let r = InferResponse::from_logits(
            1,
            vec![0.1, 0.7, 0.2],
            Duration::ZERO,
            Duration::ZERO,
            1,
        );
        assert_eq!(r.predicted, 1);
    }

    #[test]
    fn expiry_respects_deadline() {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let r = InferRequest {
            id: 0,
            image: Tensor::zeros(&[1, 1, 2, 2]),
            submitted_at: now,
            deadline: Some(now + Duration::from_millis(5)),
            priority: Priority::default(),
            reply: tx,
            recycle: None,
        };
        assert!(!r.expired(now));
        assert!(r.expired(now + Duration::from_millis(5)));
    }

    #[test]
    fn respond_err_records_and_delivers() {
        let m = Metrics::default();
        let (tx, rx) = mpsc::channel();
        let r = InferRequest {
            id: 3,
            image: Tensor::zeros(&[1, 1, 2, 2]),
            submitted_at: Instant::now(),
            deadline: None,
            priority: Priority::default(),
            reply: tx,
            recycle: None,
        };
        r.respond_err(InferError::DeadlineExceeded, &m);
        assert!(matches!(rx.recv().unwrap(), Err(InferError::DeadlineExceeded)));
        assert_eq!(m.expired.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn recycle_hook_returns_image_storage_on_both_outcomes() {
        let m = Metrics::default();
        let (pool_tx, pool_rx) = mpsc::sync_channel::<Vec<f32>>(2);
        let mk = |id: u64| {
            let (tx, rx) = mpsc::channel();
            (
                InferRequest {
                    id,
                    image: Tensor::filled(&[1, 1, 2, 2], id as f32),
                    submitted_at: Instant::now(),
                    deadline: None,
                    priority: Priority::default(),
                    reply: tx,
                    recycle: Some(pool_tx.clone()),
                },
                rx,
            )
        };
        let (r1, rx1) = mk(1);
        r1.respond_ok(InferResponse::from_logits(1, vec![1.0], Duration::ZERO, Duration::ZERO, 1));
        // The buffer must be back in the ring BEFORE the reply arrives.
        let buf = pool_rx.try_recv().expect("buffer recycled on success");
        assert_eq!(buf, vec![1.0; 4]);
        assert!(rx1.recv().unwrap().is_ok());
        let (r2, rx2) = mk(2);
        r2.respond_err(InferError::DeadlineExceeded, &m);
        assert_eq!(pool_rx.try_recv().expect("buffer recycled on error"), vec![2.0; 4]);
        assert!(rx2.recv().unwrap().is_err());
    }

    #[test]
    fn error_display_is_descriptive() {
        let e = InferError::ShapeMismatch { expected: vec![1, 1, 2, 2], got: vec![1, 1, 3, 3] };
        assert!(e.to_string().contains("[1, 1, 3, 3]"));
        assert!(InferError::NoWorkers.to_string().contains("no live workers"));
    }

    #[test]
    fn priority_parse_and_wire_round_trip() {
        assert_eq!(Priority::parse("interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse("bulk"), Some(Priority::Bulk));
        assert_eq!(Priority::parse("nope"), None);
        for p in [Priority::Interactive, Priority::Bulk] {
            assert_eq!(Priority::from_wire(p.to_wire()), Some(p));
        }
        assert_eq!(Priority::from_wire(2), None);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::Interactive.lane(), 0);
        assert_eq!(Priority::Bulk.lane(), 1);
    }

    #[test]
    fn retryable_split_is_transient_vs_terminal() {
        assert!(InferError::Shed { reason: ShedReason::QueueFull }.retryable());
        assert!(InferError::Shed { reason: ShedReason::DropOldest }.retryable());
        assert!(InferError::DeadlineExceeded.retryable());
        assert!(InferError::ShuttingDown.retryable());
        assert!(!InferError::BackendFailed { message: "x".into() }.retryable());
        assert!(!InferError::ShapeMismatch { expected: vec![1], got: vec![2] }.retryable());
        assert!(!InferError::NoWorkers.retryable());
    }
}
