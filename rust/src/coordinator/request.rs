//! Request / response types for the serving path.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// One inference request: a single image (1, C, H, W).
#[derive(Debug)]
pub struct InferRequest {
    pub id: RequestId,
    pub image: Tensor,
    pub submitted_at: Instant,
    /// Completion channel; the worker sends exactly one response.
    pub reply: mpsc::Sender<InferResponse>,
}

/// Completed inference for one request.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: RequestId,
    /// Raw logits over classes.
    pub logits: Vec<f32>,
    /// argmax class.
    pub predicted: usize,
    /// Time spent queued before batch formation.
    pub queue_time: Duration,
    /// Execution time of the batch this request rode in.
    pub execute_time: Duration,
    /// Size of that batch (before padding).
    pub batch_size: usize,
}

impl InferResponse {
    pub fn from_logits(
        id: RequestId,
        logits: Vec<f32>,
        queue_time: Duration,
        execute_time: Duration,
        batch_size: usize,
    ) -> InferResponse {
        let predicted = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        InferResponse { id, logits, predicted, queue_time, execute_time, batch_size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_prediction() {
        let r = InferResponse::from_logits(
            1,
            vec![0.1, 0.7, 0.2],
            Duration::ZERO,
            Duration::ZERO,
            1,
        );
        assert_eq!(r.predicted, 1);
    }
}
