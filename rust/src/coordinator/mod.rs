//! S8 — the L3 serving coordinator.
//!
//! A vLLM-router-shaped inference service for the quantized CNNs: callers
//! submit single images; the coordinator queues them per model variant,
//! forms dynamic batches (size- and deadline-bounded), executes them on
//! supervised worker threads — each owning a PJRT session or a rust-native
//! quantized engine — and returns per-request responses with queue/execute
//! timings.
//!
//! The serving plane is fault-tolerant by contract: every submitted request
//! resolves to exactly one typed outcome (success, `BackendFailed`, `Shed`,
//! `DeadlineExceeded`, `ShapeMismatch`, `ShuttingDown`, or `NoWorkers`),
//! crashed workers are restarted with capped backoff, poison requests are
//! isolated by batch bisection, and overload is shed by policy instead of
//! queueing unboundedly. See `docs/serving-robustness.md`.
//!
//! - [`request`]  — request/response/error types (the reply protocol) and
//!   the [`request::Priority`] scheduling lanes.
//! - [`batcher`]  — sharded bounded queues, shape-bucketed batch formation,
//!   work-stealing pop, priority lanes, deadline expiry, shed policy,
//!   fail-fast state.
//! - [`backend`]  — execution backends: PJRT artifacts or the native engine.
//! - [`worker`]   — supervised worker threads + poison-batch bisection.
//! - [`server`]   — the public [`server::Coordinator`] facade.
//! - [`metrics`]  — counters (incl. failed/shed/expired/restarts) +
//!   latency histograms.
//! - [`router`]   — multi-model front door mapping requests to coordinators.
//! - [`net`]      — hardened TCP ingress: bounded frames, typed
//!   [`net::WireStatus`] replies, a capped handler pool with accept-time
//!   shedding, I/O timeouts, drain-on-shutdown, and the self-healing
//!   [`net::ResilientClient`] (retry + reconnect + circuit breaker).
//! - [`chaos`]    — deterministic TCP fault-injecting proxy for resilience
//!   tests: seeded delay/truncate/corrupt/reset/black-hole/trickle faults
//!   per connection and direction.
pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod metrics;
pub mod net;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use backend::{shared_native_factory, Backend, BackendFactory, MockBackend, NativeBackend, PjrtBackend};
pub use batcher::{BatchPolicy, BatchQueue, ShedPolicy, SubmitError};
pub use chaos::{ChaosProxy, ConnFault, FaultKind};
pub use net::{
    ClientError, ImageSpec, NetClient, NetConfig, NetServer, ResilientClient, RetryPolicy,
    WireError, WireStatus,
};
pub use request::{InferError, InferReply, InferRequest, InferResponse, Priority, ShedReason};
pub use router::{RouteError, Router, RouteStatusFn};
pub use server::{Coordinator, CoordinatorConfig};
