//! S8 — the L3 serving coordinator.
//!
//! A vLLM-router-shaped inference service for the quantized CNNs: callers
//! submit single images; the coordinator queues them per model variant,
//! forms dynamic batches (size- and deadline-bounded), executes them on
//! worker threads — each owning a PJRT session or a rust-native quantized
//! engine — and returns per-request responses with queue/execute timings.
//!
//! - [`request`]  — request/response types.
//! - [`batcher`]  — bounded FIFO queue + dynamic batch formation policy.
//! - [`backend`]  — execution backends: PJRT artifacts or the native engine.
//! - [`worker`]   — worker threads draining batches into a backend.
//! - [`server`]   — the public [`server::Coordinator`] facade.
//! - [`metrics`]  — counters + latency histograms.
//! - [`router`]   — multi-model front door mapping requests to coordinators.
pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod net;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use request::{InferRequest, InferResponse};
pub use server::{Coordinator, CoordinatorConfig};
