//! TCP front door for the coordinator: a length-prefixed binary protocol so
//! external clients (other processes, other hosts) can submit images — the
//! deployment shape of paper §VI.C's "BLAImark" harness.
//!
//! Wire format (little-endian):
//! ```text
//! request : u32 route_len | route utf8 | u32 n_floats | n_floats x f32 (CHW image)
//! response: u8 status (0=ok, 1=error) |
//!           ok:   u32 n_logits | n x f32 | u32 predicted
//!           err:  u32 msg_len | msg utf8
//! ```
//! One request per connection round; connections are persistent (clients may
//! pipeline rounds sequentially). The accept loop and per-connection handlers
//! run on plain threads (the vendor set has no async runtime — and the
//! payloads are single images, so blocking I/O per connection is adequate).

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::coordinator::router::Router;
use crate::tensor::Tensor;

/// A running TCP server wrapping a [`Router`].
pub struct NetServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub connections: Arc<AtomicU64>,
}

/// Image geometry accepted by the server (validated per request).
#[derive(Debug, Clone, Copy)]
pub struct ImageSpec {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl NetServer {
    /// Bind and serve `router` on `addr` (use port 0 for an ephemeral port).
    pub fn serve(addr: &str, router: Arc<Router>, spec: ImageSpec) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let (stop2, conns2) = (Arc::clone(&stop), Arc::clone(&connections));
        let accept_thread = std::thread::Builder::new()
            .name("lqr-net-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            conns2.fetch_add(1, Ordering::Relaxed);
                            let router = Arc::clone(&router);
                            stream.set_nonblocking(false).ok();
                            std::thread::spawn(move || {
                                if let Err(e) = handle_conn(stream, &router, spec) {
                                    log::debug!("connection ended: {e:#}");
                                }
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(e) => {
                            log::error!("accept failed: {e}");
                            break;
                        }
                    }
                }
            })?;
        Ok(NetServer { addr: local, stop, accept_thread: Some(accept_thread), connections })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn rd_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn handle_conn(stream: TcpStream, router: &Router, spec: ImageSpec) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        // Route name.
        let route_len = match rd_u32(&mut reader) {
            Ok(n) => n as usize,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        if route_len > 4096 {
            bail!("route name too long");
        }
        let mut route = vec![0u8; route_len];
        reader.read_exact(&mut route)?;
        let route = String::from_utf8(route).context("route not utf8")?;
        // Image payload.
        let n_floats = rd_u32(&mut reader)? as usize;
        let expect = spec.c * spec.h * spec.w;
        let mut payload = vec![0u8; n_floats * 4];
        reader.read_exact(&mut payload)?;
        let result = if n_floats != expect {
            Err(anyhow::anyhow!("expected {expect} floats, got {n_floats}"))
        } else {
            let data: Vec<f32> = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let img = Tensor::new(&[1, spec.c, spec.h, spec.w], data);
            router.infer(&route, img)
        };
        match result {
            Ok(resp) => {
                writer.write_all(&[0u8])?;
                writer.write_all(&(resp.logits.len() as u32).to_le_bytes())?;
                for v in &resp.logits {
                    writer.write_all(&v.to_le_bytes())?;
                }
                writer.write_all(&(resp.predicted as u32).to_le_bytes())?;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                writer.write_all(&[1u8])?;
                writer.write_all(&(msg.len() as u32).to_le_bytes())?;
                writer.write_all(msg.as_bytes())?;
            }
        }
        writer.flush()?;
    }
}

/// Minimal blocking client for the wire protocol (used by tests, examples
/// and external tooling).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl NetClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).context("connect")?;
        Ok(NetClient { reader: BufReader::new(stream.try_clone()?), writer: BufWriter::new(stream) })
    }

    /// Classify one CHW image on `route`; returns (logits, predicted).
    pub fn classify(&mut self, route: &str, image: &Tensor) -> Result<(Vec<f32>, usize)> {
        self.writer.write_all(&(route.len() as u32).to_le_bytes())?;
        self.writer.write_all(route.as_bytes())?;
        self.writer.write_all(&(image.len() as u32).to_le_bytes())?;
        for v in image.data() {
            self.writer.write_all(&v.to_le_bytes())?;
        }
        self.writer.flush()?;
        let mut status = [0u8; 1];
        self.reader.read_exact(&mut status)?;
        if status[0] != 0 {
            let n = rd_u32(&mut self.reader)? as usize;
            let mut msg = vec![0u8; n];
            self.reader.read_exact(&mut msg)?;
            bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        let n = rd_u32(&mut self.reader)? as usize;
        let mut logits = Vec::with_capacity(n);
        let mut buf = [0u8; 4];
        for _ in 0..n {
            self.reader.read_exact(&mut buf)?;
            logits.push(f32::from_le_bytes(buf));
        }
        let predicted = rd_u32(&mut self.reader)? as usize;
        Ok((logits, predicted))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use crate::coordinator::server::CoordinatorConfig;
    use std::sync::atomic::AtomicU64;

    fn test_router() -> Arc<Router> {
        let mut r = Router::new();
        r.add_route(
            "mock",
            CoordinatorConfig::default(),
            Box::new(|| {
                Ok(Box::new(MockBackend {
                    classes: 4,
                    delay: std::time::Duration::ZERO,
                    calls: Arc::new(AtomicU64::new(0)),
                }) as Box<dyn Backend>)
            }),
        )
        .unwrap();
        Arc::new(r)
    }

    #[test]
    fn round_trip_over_tcp() {
        let router = test_router();
        let spec = ImageSpec { c: 1, h: 2, w: 2 };
        let server = NetServer::serve("127.0.0.1:0", router, spec).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 0.25);
        let (logits, predicted) = client.classify("mock", &img).unwrap();
        assert_eq!(logits, vec![1.0, 0.0, 0.0, 0.0]); // row sum = 4 * 0.25
        assert_eq!(predicted, 0);
        // Pipelined second round on the same connection.
        let (logits2, _) = client.classify("mock", &Tensor::filled(&[1, 1, 2, 2], 0.5)).unwrap();
        assert_eq!(logits2[0], 2.0);
        server.shutdown();
    }

    #[test]
    fn unknown_route_reports_error() {
        let router = test_router();
        let server =
            NetServer::serve("127.0.0.1:0", router, ImageSpec { c: 1, h: 2, w: 2 }).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let err = client
            .classify("nope", &Tensor::filled(&[1, 1, 2, 2], 0.1))
            .unwrap_err();
        assert!(format!("{err:#}").contains("no route"), "{err:#}");
        server.shutdown();
    }

    #[test]
    fn wrong_image_size_reports_error() {
        let router = test_router();
        let server =
            NetServer::serve("127.0.0.1:0", router, ImageSpec { c: 1, h: 2, w: 2 }).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let err = client
            .classify("mock", &Tensor::filled(&[1, 1, 3, 3], 0.1))
            .unwrap_err();
        assert!(format!("{err:#}").contains("expected 4 floats"), "{err:#}");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let router = test_router();
        let server =
            NetServer::serve("127.0.0.1:0", router, ImageSpec { c: 1, h: 2, w: 2 }).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = NetClient::connect(addr).unwrap();
                    for i in 0..8 {
                        let v = (t * 8 + i) as f32 * 0.1;
                        let (logits, _) =
                            c.classify("mock", &Tensor::filled(&[1, 1, 2, 2], v)).unwrap();
                        assert!((logits[0] - 4.0 * v).abs() < 1e-5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(server.connections.load(Ordering::Relaxed) >= 4);
        server.shutdown();
    }
}
