//! TCP front door for the coordinator: a length-prefixed binary protocol so
//! external clients (other processes, other hosts) can submit images — the
//! deployment shape of paper §VI.C's "BLAImark" harness.
//!
//! Wire format (little-endian):
//! ```text
//! request : u32 route_len | route utf8 | [u8 lane] | u32 n_floats | n_floats x f32 (CHW image)
//! reply   : u8 status (see WireStatus) |
//!           Ok:      u32 n_logits | n x f32 | u32 predicted
//!           Health:  u32 len | report utf8
//!           errors:  u32 len | message utf8
//! ```
//! The lane byte is present only when bit 31 of `route_len` ([`LANE_FLAG`])
//! is set; it selects the scheduling lane
//! ([`Priority`](crate::coordinator::request::Priority): 0 = interactive,
//! 1 = bulk). Untagged frames — everything an older client sends — default
//! to the interactive lane, so the extension is backward compatible.
//! One request per round; connections are persistent (clients pipeline
//! rounds sequentially). The accept loop and per-connection handlers run on
//! plain threads (the vendor set has no async runtime — and the payloads are
//! single images, so blocking I/O per connection is adequate).
//!
//! This is a *hardened* ingress — the wire end of the fault contract in
//! `docs/serving-robustness.md`:
//!
//! - **Bounded frames.** `route_len` and `n_floats` are validated against
//!   [`NetConfig`] limits and the route's [`ImageSpec`] *before* any
//!   payload-sized allocation; a corrupt length prefix can never make the
//!   server allocate attacker-controlled gigabytes.
//! - **Typed status codes.** Every reply opens with a [`WireStatus`] byte
//!   carrying the coordinator's typed
//!   [`InferError`](crate::coordinator::request::InferError) outcome, so
//!   [`NetClient`] can distinguish retryable overload (`Shed`, `Busy`,
//!   `DeadlineExceeded`, `ShuttingDown`) from terminal rejections.
//! - **Never desync.** A malformed-but-parseable frame gets an in-sync
//!   typed reply and the connection keeps serving; a frame that violates the
//!   wire grammar or a hard limit gets a typed reply and then the connection
//!   closes. The stream position is never ambiguous.
//! - **Bounded handler pool.** At most `max_conns` live handler threads;
//!   excess connections get a [`WireStatus::Busy`] reply at accept time and
//!   are closed. Handlers are tracked and joined — never detached.
//! - **Timeout-guarded I/O.** Per-connection read/write timeouts
//!   (`io_timeout`) bound how long a slowloris client can pin a handler.
//! - **Resilient accept loop.** Transient accept errors (`EMFILE`,
//!   `ECONNABORTED`, ...) back off and retry with a stop-aware wait; only
//!   `shutdown` stops the listener, and it is never delayed by a backoff.
//! - **Resilient client.** [`ResilientClient`] wraps [`NetClient`] with
//!   reconnect-on-transport-error, jittered exponential retry of
//!   `retryable()` statuses under an attempt/deadline budget, and a
//!   half-open circuit breaker ([`ClientError::CircuitOpen`]) so edge
//!   deployments don't re-derive fault handling.
//! - **Drain on shutdown.** [`NetServer::shutdown`] stops accepting,
//!   half-closes idle connections (their handlers see EOF and exit), waits
//!   up to `drain_timeout` for in-flight requests to resolve, force-closes
//!   stragglers, and joins every handler thread.
//! - **Alloc-free hot path.** Each handler owns a `FrameScratch` of reused
//!   buffers (route bytes, payload bytes, decoded floats, staged reply) plus
//!   a recycle ring that returns each request's float storage at reply time
//!   (`InferRequest::recycle`). Steady-state serving — a client pipelining
//!   well-formed frames — does no per-request heap allocation on the frame
//!   path: bytes decode in bulk (`chunks_exact`) into reused storage, and
//!   every reply leaves in one gathered `write_all`.

use std::collections::HashMap;
use std::io::{BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::metrics::{ClientMetrics, NetMetrics};
use crate::coordinator::request::Priority;
use crate::coordinator::router::{RouteError, Router};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Built-in route answered by the server itself with a readiness report
/// ([`WireStatus::Health`] reply). Model routes with this name are shadowed.
pub const HEALTH_ROUTE: &str = "health";

/// Flag bit on `route_len` marking a lane-tagged frame: one priority byte
/// follows the route name. Route lengths are bounded by
/// `NetConfig::max_route_len` (far below 2^31), so bit 31 is free; old
/// clients never set it, and an old server sees a flagged length as an
/// oversized route and rejects the frame rather than desyncing.
pub const LANE_FLAG: u32 = 0x8000_0000;

// ---------------------------------------------------------------- status --

/// First byte of every reply: the typed outcome of one wire round.
///
/// Codes mirror the coordinator's
/// [`InferError`](crate::coordinator::request::InferError) variants so the
/// serving plane's fault contract survives the wire instead of flattening
/// into an opaque error string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum WireStatus {
    /// Inference succeeded; body is `n_logits | logits | predicted`.
    Ok = 0,
    /// The frame violated the wire grammar or a hard limit (oversized
    /// `route_len`/`n_floats`, frame past `max_frame_bytes`). The stream
    /// position is unrecoverable: the server closes after the reply.
    BadFrame = 1,
    /// Parseable frame with invalid contents (wrong float count, empty or
    /// non-UTF-8 route name). The stream stays in sync; keep pipelining.
    BadRequest = 2,
    /// No such route registered.
    NoRoute = 3,
    /// Load-shed: queue full (reject-newest) or evicted (drop-oldest).
    Shed = 4,
    /// The request's deadline expired before a batch could execute it.
    DeadlineExceeded = 5,
    /// The backend errored or panicked on this request.
    BackendFailed = 6,
    /// The route's worker pool is irrecoverably dead.
    NoWorkers = 7,
    /// The coordinator (or server) is shutting down.
    ShuttingDown = 8,
    /// Image shape did not match the route's expected geometry.
    ShapeMismatch = 9,
    /// Accept-time shed: the handler pool is at `max_conns`. The server
    /// closes the connection after this reply; retry after backoff.
    Busy = 10,
    /// Reply to the [`HEALTH_ROUTE`] built-in; body is a text report.
    Health = 11,
}

impl WireStatus {
    pub fn from_code(c: u8) -> Option<WireStatus> {
        Some(match c {
            0 => WireStatus::Ok,
            1 => WireStatus::BadFrame,
            2 => WireStatus::BadRequest,
            3 => WireStatus::NoRoute,
            4 => WireStatus::Shed,
            5 => WireStatus::DeadlineExceeded,
            6 => WireStatus::BackendFailed,
            7 => WireStatus::NoWorkers,
            8 => WireStatus::ShuttingDown,
            9 => WireStatus::ShapeMismatch,
            10 => WireStatus::Busy,
            11 => WireStatus::Health,
            _ => return None,
        })
    }

    /// Transient conditions a client may reasonably retry (after backoff,
    /// or against another replica). Terminal codes mean the request as
    /// posed will never succeed here.
    pub fn retryable(self) -> bool {
        matches!(
            self,
            WireStatus::Shed
                | WireStatus::Busy
                | WireStatus::DeadlineExceeded
                | WireStatus::ShuttingDown
        )
    }

    /// Map a typed routing/inference failure onto its wire code + message.
    fn of_route_error(e: &RouteError) -> (WireStatus, String) {
        use crate::coordinator::batcher::SubmitError;
        use crate::coordinator::request::InferError;
        let status = match e {
            RouteError::NoRoute(_) => WireStatus::NoRoute,
            RouteError::Rejected(SubmitError::QueueFull(_)) => WireStatus::Shed,
            RouteError::Rejected(SubmitError::ShutDown) => WireStatus::ShuttingDown,
            RouteError::Rejected(SubmitError::NoWorkers) => WireStatus::NoWorkers,
            RouteError::Infer(err) => match err {
                InferError::BackendFailed { .. } => WireStatus::BackendFailed,
                InferError::Shed { .. } => WireStatus::Shed,
                InferError::DeadlineExceeded => WireStatus::DeadlineExceeded,
                InferError::ShapeMismatch { .. } => WireStatus::ShapeMismatch,
                InferError::ShuttingDown => WireStatus::ShuttingDown,
                InferError::NoWorkers => WireStatus::NoWorkers,
            },
        };
        (status, e.to_string())
    }
}

// ---------------------------------------------------------------- config --

/// Ingress resource bounds and timeouts.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Handler-pool bound: connections accepted while this many are live
    /// get a [`WireStatus::Busy`] reply and are closed.
    pub max_conns: usize,
    /// Per-connection read *and* write timeout — also the idle cap between
    /// frames, so a stalled reader or writer can pin a handler for at most
    /// this long. `Duration::ZERO` disables the timeouts.
    pub io_timeout: Duration,
    /// Hard cap on one request frame's total bytes (headers + route +
    /// payload). Frames past it get [`WireStatus::BadFrame`] and the
    /// connection closes — *before* any payload-sized allocation.
    pub max_frame_bytes: usize,
    /// Route-name length cap (grammar limit, checked before reading).
    pub max_route_len: usize,
    /// How long [`NetServer::shutdown`] waits for in-flight handlers to
    /// resolve before force-closing their connections.
    pub drain_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 64,
            io_timeout: Duration::from_secs(10),
            max_frame_bytes: 16 << 20,
            max_route_len: 4096,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

fn timeout_opt(d: Duration) -> Option<Duration> {
    if d.is_zero() {
        None
    } else {
        Some(d)
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

// ---------------------------------------------------------------- frames --

/// Image geometry accepted by the server (validated per request).
#[derive(Debug, Clone, Copy)]
pub struct ImageSpec {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

/// Reusable per-connection buffers for the steady-state frame path. Every
/// field is cleared and refilled in place each round (`clear()` +
/// `resize`/`extend` keep the allocation), so a pipelining client costs no
/// per-request heap allocation once the buffers reach their working size.
///
/// `image` is special: its storage leaves with each admitted request (the
/// coordinator owns the submitted tensor) and comes back through the
/// handler's recycle ring at reply time — see `handle_conn`.
///
/// Public (with public fields) so out-of-crate harnesses — the seeded frame
/// fuzzer in `tests/frame_fuzz.rs` — can drive [`read_frame_into`] with
/// deliberately dirty recycled buffers, exactly as the pooled reuse path
/// produces them.
pub struct FrameScratch {
    /// Route-name bytes of the current frame (UTF-8 validated by the parser).
    pub route: Vec<u8>,
    /// Raw little-endian payload bytes of the current frame.
    pub payload: Vec<u8>,
    /// Decoded image floats of the current frame.
    pub image: Vec<f32>,
    /// Staged reply bytes, sent with one gathered write.
    pub reply: Vec<u8>,
}

impl Default for FrameScratch {
    fn default() -> FrameScratch {
        FrameScratch::new()
    }
}

impl FrameScratch {
    /// Empty scratch (buffers grow to working size on first use).
    pub fn new() -> FrameScratch {
        FrameScratch {
            route: Vec::new(),
            payload: Vec::new(),
            image: Vec::new(),
            reply: Vec::new(),
        }
    }

    /// The current frame's route name. The parser only yields
    /// [`Frame::Infer`] after validating the bytes, so this never fails on
    /// that path; outside it a dirty buffer degrades to "".
    pub fn route_str(&self) -> &str {
        std::str::from_utf8(&self.route).unwrap_or("")
    }
}

/// One parsed request frame. Variable-size contents (route bytes, decoded
/// image floats) live in the caller's [`FrameScratch`], not in the variant:
/// the parser fills reused buffers instead of allocating per frame.
pub enum Frame {
    /// Well-formed inference request: route in `scratch.route`, floats in
    /// `scratch.image` (length already validated against the
    /// [`ImageSpec`]). `lane_tagged` records whether the frame carried the
    /// optional lane byte (exact byte accounting).
    Infer {
        /// Scheduling lane decoded from the optional lane byte.
        priority: Priority,
        /// Whether the frame carried the lane byte (exact byte accounting).
        lane_tagged: bool,
    },
    /// The [`HEALTH_ROUTE`] built-in.
    Health,
    /// Client closed cleanly at a frame boundary.
    Eof,
}

/// Why a frame was not parsed.
pub enum FrameError {
    /// Typed rejection. `fatal` marks the stream desynced (reply then
    /// close); otherwise the reader is positioned at the next frame and the
    /// connection keeps serving.
    Reject {
        /// Wire code sent back to the client.
        status: WireStatus,
        /// Human-readable rejection detail.
        message: String,
        /// Stream desynced: reply, then close the connection.
        fatal: bool,
    },
    /// Transport failure (mid-frame disconnect, timeout, ...).
    Io(std::io::Error),
}

impl FrameError {
    fn fatal(status: WireStatus, message: String) -> FrameError {
        FrameError::Reject { status, message, fatal: true }
    }

    fn in_sync(status: WireStatus, message: String) -> FrameError {
        FrameError::Reject { status, message, fatal: false }
    }
}

fn rd_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read and discard exactly `n` payload bytes (bounded by the frame-size
/// check upstream) so the stream stays positioned at the next frame.
fn discard(r: &mut impl Read, mut n: u64) -> Result<(), FrameError> {
    let mut buf = [0u8; 8192];
    while n > 0 {
        let take = n.min(buf.len() as u64) as usize;
        r.read_exact(&mut buf[..take]).map_err(FrameError::Io)?;
        n -= take as u64;
    }
    Ok(())
}

/// Parse one request frame into `scratch`. Every limit is enforced *before*
/// the corresponding buffer grows: the largest this function ever sizes a
/// buffer is `min(route_len, max_route_len)` + the spec-validated image
/// payload — and on the steady-state path those buffers are reused, so no
/// per-frame heap allocation happens at all once they reach working size.
///
/// Public so the deterministic fuzz harness (`tests/frame_fuzz.rs`) can
/// hammer the exact production parse path with mutated byte streams and
/// dirty recycled scratch buffers.
pub fn read_frame_into(
    r: &mut impl Read,
    spec: ImageSpec,
    cfg: &NetConfig,
    scratch: &mut FrameScratch,
) -> Result<Frame, FrameError> {
    let raw_len = match rd_u32(r) {
        Ok(n) => n,
        // EOF at the frame boundary is a clean close. (`read_exact` can't
        // distinguish 0-of-4 from 2-of-4 bytes; a client dying mid-prefix
        // folds into the same outcome, which costs nothing.)
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(Frame::Eof),
        Err(e) => return Err(FrameError::Io(e)),
    };
    let lane_tagged = raw_len & LANE_FLAG != 0;
    let route_len = (raw_len & !LANE_FLAG) as u64;
    if route_len > cfg.max_route_len as u64 {
        return Err(FrameError::fatal(
            WireStatus::BadFrame,
            format!("route_len {route_len} exceeds max_route_len {}", cfg.max_route_len),
        ));
    }
    scratch.route.clear();
    scratch.route.resize(route_len as usize, 0);
    r.read_exact(&mut scratch.route).map_err(FrameError::Io)?;
    let lane_byte = if lane_tagged {
        let mut b = [0u8; 1];
        r.read_exact(&mut b).map_err(FrameError::Io)?;
        Some(b[0])
    } else {
        None
    };
    let n_floats = rd_u32(r).map_err(FrameError::Io)? as u64;
    let payload_bytes = n_floats * 4;
    let frame_bytes = 8 + route_len + lane_tagged as u64 + payload_bytes;
    if frame_bytes > cfg.max_frame_bytes as u64 {
        return Err(FrameError::fatal(
            WireStatus::BadFrame,
            format!(
                "frame of {frame_bytes} bytes ({n_floats} floats) exceeds max_frame_bytes {}",
                cfg.max_frame_bytes
            ),
        ));
    }
    // From here the payload is within the frame budget: it can be skipped,
    // so content errors reply in sync and the connection keeps serving.
    let priority = match lane_byte {
        None => Priority::default(),
        Some(b) => match Priority::from_wire(b) {
            Some(p) => p,
            None => {
                discard(r, payload_bytes)?;
                return Err(FrameError::in_sync(
                    WireStatus::BadRequest,
                    format!("unknown lane tag {b}"),
                ));
            }
        },
    };
    if std::str::from_utf8(&scratch.route).is_err() {
        discard(r, payload_bytes)?;
        return Err(FrameError::in_sync(
            WireStatus::BadRequest,
            "route name is not valid UTF-8".into(),
        ));
    }
    if scratch.route.is_empty() {
        discard(r, payload_bytes)?;
        return Err(FrameError::in_sync(WireStatus::BadRequest, "empty route name".into()));
    }
    if scratch.route.as_slice() == HEALTH_ROUTE.as_bytes() {
        // Health probes carry no image; tolerate (and skip) a stray payload.
        discard(r, payload_bytes)?;
        return Ok(Frame::Health);
    }
    let expect = spec.c * spec.h * spec.w;
    if n_floats != expect as u64 {
        discard(r, payload_bytes)?;
        return Err(FrameError::in_sync(
            WireStatus::BadRequest,
            format!("expected {expect} floats, got {n_floats}"),
        ));
    }
    // Validated against the spec — this buffer is bounded by the model's
    // input geometry, not by client-controlled bytes.
    scratch.payload.clear();
    scratch.payload.resize(expect * 4, 0);
    r.read_exact(&mut scratch.payload).map_err(FrameError::Io)?;
    scratch.image.clear();
    scratch.image.extend(
        scratch.payload.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
    Ok(Frame::Infer { priority, lane_tagged })
}

// --------------------------------------------------------------- replies --

/// Encode an error/health reply (`status | u32 len | utf8`) into a reused
/// buffer. Messages are truncated to keep replies small and parseable.
fn encode_msg(buf: &mut Vec<u8>, status: WireStatus, msg: &str) {
    let bytes = msg.as_bytes();
    let bytes = &bytes[..bytes.len().min(4096)];
    buf.clear();
    buf.reserve(5 + bytes.len());
    buf.push(status as u8);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
}

/// Encode a success reply (`Ok | u32 n | logits | u32 predicted`) into a
/// reused buffer.
fn encode_ok(buf: &mut Vec<u8>, logits: &[f32], predicted: usize) {
    buf.clear();
    buf.reserve(9 + logits.len() * 4);
    buf.push(WireStatus::Ok as u8);
    buf.extend_from_slice(&(logits.len() as u32).to_le_bytes());
    for v in logits {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf.extend_from_slice(&(predicted as u32).to_le_bytes());
}

/// One gathered write: the whole staged reply leaves in a single
/// `write_all` on the unbuffered stream (no BufWriter copy, no flush
/// round). Returns bytes written for the metrics.
fn send_reply(w: &mut impl Write, reply: &[u8]) -> std::io::Result<u64> {
    w.write_all(reply)?;
    w.flush()?;
    Ok(reply.len() as u64)
}

// -------------------------------------------------------------- registry --

/// Tracks live connections (a control clone per handler, used to wake and
/// force-close during drain) and their joinable handler threads.
#[derive(Default)]
struct RegistryInner {
    next_id: u64,
    conns: HashMap<u64, TcpStream>,
    handles: HashMap<u64, JoinHandle<()>>,
    /// Handler ids that finished (their `JoinHandle` is now quick to join).
    finished: Vec<u64>,
}

struct Registry {
    max_conns: usize,
    inner: Mutex<RegistryInner>,
}

impl Registry {
    fn new(max_conns: usize) -> Registry {
        Registry { max_conns: max_conns.max(1), inner: Mutex::new(RegistryInner::default()) }
    }

    /// Admit a connection if the pool has a free slot; the semaphore is the
    /// map itself, so a slot frees exactly when its handler deregisters.
    fn try_admit(&self, control: TcpStream) -> Option<u64> {
        let mut g = self.inner.lock().unwrap();
        if g.conns.len() >= self.max_conns {
            return None;
        }
        let id = g.next_id;
        g.next_id += 1;
        g.conns.insert(id, control);
        Some(id)
    }

    fn attach(&self, id: u64, h: JoinHandle<()>) {
        self.inner.lock().unwrap().handles.insert(id, h);
    }

    /// Handler deregistration: frees the pool slot and marks the thread
    /// reapable. Called as the handler's last act.
    fn finish(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        g.conns.remove(&id);
        g.finished.push(id);
    }

    /// Collect handles of finished handlers (joined by the caller, outside
    /// the lock). Ids raced ahead of `attach` stay queued for next time.
    fn reap(&self) -> Vec<JoinHandle<()>> {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let mut out = Vec::new();
        let mut keep = Vec::new();
        for id in std::mem::take(&mut inner.finished) {
            match inner.handles.remove(&id) {
                Some(h) => out.push(h),
                None => keep.push(id),
            }
        }
        inner.finished = keep;
        out
    }

    fn active(&self) -> usize {
        self.inner.lock().unwrap().conns.len()
    }

    fn for_each_conn(&self, f: impl Fn(&TcpStream)) {
        for s in self.inner.lock().unwrap().conns.values() {
            f(s);
        }
    }

    fn take_handles(&self) -> Vec<JoinHandle<()>> {
        let mut g = self.inner.lock().unwrap();
        g.finished.clear();
        g.handles.drain().map(|(_, h)| h).collect()
    }
}

// ---------------------------------------------------------------- server --

/// A running TCP server wrapping a [`Router`].
pub struct NetServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    registry: Arc<Registry>,
    metrics: Arc<NetMetrics>,
    drain_timeout: Duration,
}

impl NetServer {
    /// Bind and serve `router` on `addr` (use port 0 for an ephemeral port)
    /// with default [`NetConfig`] bounds.
    pub fn serve(addr: &str, router: Arc<Router>, spec: ImageSpec) -> Result<NetServer> {
        NetServer::serve_with(addr, router, spec, NetConfig::default())
    }

    /// [`NetServer::serve`] with explicit resource bounds.
    pub fn serve_with(
        addr: &str,
        router: Arc<Router>,
        spec: ImageSpec,
        cfg: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let registry = Arc::new(Registry::new(cfg.max_conns));
        let metrics = Arc::new(NetMetrics::default());
        let (stop2, reg2, met2) = (Arc::clone(&stop), Arc::clone(&registry), Arc::clone(&metrics));
        let accept_thread = std::thread::Builder::new()
            .name("lqr-net-accept".into())
            .spawn(move || accept_loop(listener, router, spec, cfg, stop2, reg2, met2))?;
        Ok(NetServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            registry,
            metrics,
            drain_timeout: cfg.drain_timeout,
        })
    }

    /// Ingress counters (connections, rejections, timeouts, bytes).
    pub fn metrics(&self) -> &NetMetrics {
        &self.metrics
    }

    /// Live handler count (pool occupancy).
    pub fn active_connections(&self) -> usize {
        self.registry.active()
    }

    /// Stop accepting, drain in-flight requests under `drain_timeout`,
    /// force-close stragglers, and join every handler thread. Returns the
    /// ingress metrics for reporting.
    pub fn shutdown(mut self) -> Arc<NetMetrics> {
        self.teardown();
        Arc::clone(&self.metrics)
    }

    fn teardown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // Half-close every connection: handlers idle-blocked at a frame
        // boundary read EOF and exit; handlers mid-request keep their write
        // side and deliver the in-flight reply.
        self.registry.for_each_conn(|s| {
            let _ = s.shutdown(Shutdown::Read);
        });
        let deadline = Instant::now() + self.drain_timeout;
        while self.registry.active() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Stragglers (e.g. a stalled writer still inside its send timeout)
        // lose the connection; their handlers unblock and exit.
        self.registry.for_each_conn(|s| {
            let _ = s.shutdown(Shutdown::Both);
        });
        for h in self.registry.take_handles() {
            let _ = h.join();
        }
        self.metrics.active_conns.store(0, Ordering::Relaxed);
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Sleep up to `total`, waking early (returning `false`) the moment `stop`
/// flips. Sliced so an accept-error backoff (up to 500ms) never delays
/// shutdown by more than one ~5ms slice.
fn sleep_unless_stopped(stop: &AtomicBool, total: Duration) -> bool {
    let deadline = Instant::now() + total;
    loop {
        if stop.load(Ordering::Relaxed) {
            return false;
        }
        let now = Instant::now();
        if now >= deadline {
            return true;
        }
        std::thread::sleep((deadline - now).min(Duration::from_millis(5)));
    }
}

fn accept_loop(
    listener: TcpListener,
    router: Arc<Router>,
    spec: ImageSpec,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
    registry: Arc<Registry>,
    metrics: Arc<NetMetrics>,
) {
    let base_backoff = Duration::from_millis(1);
    let mut backoff = base_backoff;
    while !stop.load(Ordering::Relaxed) {
        // Reap finished handlers so the handle map stays bounded on
        // long-lived servers (joins are instant: the threads already exited).
        for h in registry.reap() {
            let _ = h.join();
        }
        match listener.accept() {
            Ok((stream, _)) => {
                backoff = base_backoff;
                metrics.total_conns.fetch_add(1, Ordering::Relaxed);
                stream.set_nonblocking(false).ok();
                admit(stream, &router, spec, &cfg, &stop, &registry, &metrics);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                // Transient resource exhaustion (EMFILE/ENFILE from an fd
                // flood, ECONNABORTED, ...): the listener must outlive the
                // spike. Back off and retry — `break` is reserved for stop.
                // The wait is stop-aware so shutdown never stalls behind a
                // backoff in progress.
                metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                log::warn!("accept failed (retrying in {backoff:?}): {e}");
                if !sleep_unless_stopped(&stop, backoff) {
                    break;
                }
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Try to hand `stream` to a pooled handler thread; shed with a typed
/// [`WireStatus::Busy`] reply when the pool (or the OS spawn path) is full.
fn admit(
    stream: TcpStream,
    router: &Arc<Router>,
    spec: ImageSpec,
    cfg: &NetConfig,
    stop: &Arc<AtomicBool>,
    registry: &Arc<Registry>,
    metrics: &Arc<NetMetrics>,
) {
    let control = match stream.try_clone() {
        Ok(c) => c,
        Err(e) => {
            log::debug!("connection dropped (clone failed): {e}");
            return;
        }
    };
    let id = match registry.try_admit(control) {
        Some(id) => id,
        None => {
            metrics.rejected_conns.fetch_add(1, Ordering::Relaxed);
            busy_reply(stream, cfg, "handler pool full (max_conns)");
            return;
        }
    };
    // Gauge before the handler runs: a health probe served by this very
    // connection must already see itself counted.
    metrics.active_conns.store(registry.active() as u64, Ordering::Relaxed);
    let (router, cfg2, stop2, reg2, met2) =
        (Arc::clone(router), *cfg, Arc::clone(stop), Arc::clone(registry), Arc::clone(metrics));
    let spawned = std::thread::Builder::new().name(format!("lqr-net-conn-{id}")).spawn(move || {
        if let Err(e) = handle_conn(stream, &router, spec, &cfg2, &stop2, &met2) {
            // Write-side timeouts land here (read-side ones close cleanly
            // inside the loop); both count as a timed-out connection.
            if is_timeout(&e) {
                met2.timed_out.fetch_add(1, Ordering::Relaxed);
            }
            log::debug!("connection {id} ended: {e}");
        }
        reg2.finish(id);
        met2.active_conns.store(reg2.active() as u64, Ordering::Relaxed);
    });
    match spawned {
        Ok(h) => registry.attach(id, h),
        Err(e) => {
            // Thread exhaustion is an overload condition like a full pool.
            registry.finish(id);
            for h in registry.reap() {
                let _ = h.join();
            }
            metrics.active_conns.store(registry.active() as u64, Ordering::Relaxed);
            metrics.rejected_conns.fetch_add(1, Ordering::Relaxed);
            log::warn!("handler spawn failed, shedding connection: {e}");
        }
    }
}

/// Best-effort `Busy` reply to a connection shed at accept time. A short
/// write timeout keeps a hostile peer from pinning the accept thread; the
/// ~40-byte reply fits any socket send buffer anyway.
fn busy_reply(mut stream: TcpStream, cfg: &NetConfig, msg: &str) {
    let t = if cfg.io_timeout.is_zero() {
        Duration::from_secs(1)
    } else {
        cfg.io_timeout.min(Duration::from_secs(1))
    };
    let _ = stream.set_write_timeout(Some(t));
    let mut reply = Vec::new();
    encode_msg(&mut reply, WireStatus::Busy, msg);
    let _ = send_reply(&mut stream, &reply);
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    spec: ImageSpec,
    cfg: &NetConfig,
    stop: &AtomicBool,
    metrics: &NetMetrics,
) -> std::io::Result<()> {
    stream.set_read_timeout(timeout_opt(cfg.io_timeout))?;
    stream.set_write_timeout(timeout_opt(cfg.io_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut scratch = FrameScratch::new();
    // Image-buffer recycle ring: the float storage submitted with each
    // request returns here at reply time (`InferRequest::recycle` fires in
    // the coordinator's respond paths, *before* the reply unblocks us), so
    // the steady-state round reuses one buffer instead of allocating per
    // request. Capacity 2 absorbs rare overlap; a synchronously rejected
    // request drops its buffer to the allocator (overload path only).
    let (pool_tx, pool_rx) = mpsc::sync_channel::<Vec<f32>>(2);
    loop {
        // Drain: after `shutdown` flips the flag, finish no further rounds.
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        // Reclaim recycled image storage before parsing the next frame.
        if let Ok(mut buf) = pool_rx.try_recv() {
            buf.clear();
            scratch.image = buf;
        }
        match read_frame_into(&mut reader, spec, cfg, &mut scratch) {
            Ok(Frame::Eof) => return Ok(()),
            Ok(Frame::Health) => {
                metrics.bytes_in.fetch_add(8 + HEALTH_ROUTE.len() as u64, Ordering::Relaxed);
                let report = health_report(router, metrics);
                encode_msg(&mut scratch.reply, WireStatus::Health, &report);
                let out = send_reply(&mut writer, &scratch.reply)?;
                metrics.bytes_out.fetch_add(out, Ordering::Relaxed);
            }
            Ok(Frame::Infer { priority, lane_tagged }) => {
                metrics.frames.fetch_add(1, Ordering::Relaxed);
                metrics.bytes_in.fetch_add(
                    8 + scratch.route.len() as u64
                        + lane_tagged as u64
                        + scratch.image.len() as u64 * 4,
                    Ordering::Relaxed,
                );
                let img = Tensor::new(
                    &[1, spec.c, spec.h, spec.w],
                    std::mem::take(&mut scratch.image),
                );
                let res = router.infer_typed_pooled(
                    scratch.route_str(),
                    img,
                    priority,
                    Some(pool_tx.clone()),
                );
                match res {
                    Ok(resp) => encode_ok(&mut scratch.reply, &resp.logits, resp.predicted),
                    Err(e) => {
                        let (status, msg) = WireStatus::of_route_error(&e);
                        encode_msg(&mut scratch.reply, status, &msg);
                    }
                }
                let out = send_reply(&mut writer, &scratch.reply)?;
                metrics.bytes_out.fetch_add(out, Ordering::Relaxed);
            }
            Err(FrameError::Reject { status, message, fatal }) => {
                metrics.malformed.fetch_add(1, Ordering::Relaxed);
                encode_msg(&mut scratch.reply, status, &message);
                let out = send_reply(&mut writer, &scratch.reply)?;
                metrics.bytes_out.fetch_add(out, Ordering::Relaxed);
                if fatal {
                    return Ok(());
                }
            }
            Err(FrameError::Io(e)) => {
                if is_timeout(&e) {
                    metrics.timed_out.fetch_add(1, Ordering::Relaxed);
                    // Idle/stalled past io_timeout: close; the client can
                    // reconnect. No reply — the stream may be mid-frame.
                    return Ok(());
                }
                return Err(e);
            }
        }
    }
}

/// Text body of a [`WireStatus::Health`] reply: readiness + per-route
/// queue/pool state + connection-pool occupancy.
fn health_report(router: &Router, metrics: &NetMetrics) -> String {
    let mut ready = false;
    let mut routes = Vec::new();
    for name in router.route_names() {
        if let Some(c) = router.coordinator(name) {
            let failed = c.is_failed();
            ready |= !failed;
            // Routes registered with a status callback (shared-engine
            // routes report pre-warm / panel-cache state) append it here.
            let extra =
                router.route_status(name).map(|s| format!(" [{s}]")).unwrap_or_default();
            // Self-healing counters ride at the end so existing substring
            // pins on the prefix (depth/state/extra) stay stable.
            let m = c.metrics();
            routes.push(format!(
                "{name} depth={}/{} {}{extra} watchdog_kills={} inflight_expired={}",
                c.queue_depth(),
                c.queue_capacity(),
                if failed { "dead" } else { "up" },
                m.watchdog_kills.load(Ordering::Relaxed),
                m.inflight_expired.load(Ordering::Relaxed),
            ));
        }
    }
    format!(
        "ready={ready} active_conns={} total_conns={} | {}",
        metrics.active_conns.load(Ordering::Relaxed),
        metrics.total_conns.load(Ordering::Relaxed),
        routes.join("; ")
    )
}

// ---------------------------------------------------------------- client --

/// A typed non-OK reply from the server.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub status: WireStatus,
    pub message: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server replied {:?}: {}", self.status, self.message)
    }
}

impl std::error::Error for WireError {}

/// What a [`NetClient`] call can fail with: a transport error or a typed
/// server rejection. The vendored `anyhow` subset has no downcasting, so
/// the client API keeps the error concrete — `?` still converts into
/// `anyhow::Error` where callers don't care.
#[derive(Debug)]
pub enum ClientError {
    /// The transport failed (connection closed, timeout, protocol desync).
    Io(std::io::Error),
    /// The server answered with a typed non-OK [`WireStatus`].
    Wire(WireError),
    /// [`ResilientClient`]'s circuit breaker is open: the endpoint failed
    /// repeatedly and the cooldown has not elapsed, so the call failed fast
    /// without touching the network. Not retryable by the client itself —
    /// callers should shed or fail over, then try again later.
    CircuitOpen,
}

impl ClientError {
    /// True when retrying (after backoff, or elsewhere) can succeed:
    /// transient overload codes only. Transport errors are *not* marked
    /// retryable — the caller can't tell whether the request executed.
    /// `CircuitOpen` is deliberately non-retryable: it exists to stop the
    /// retry traffic.
    pub fn retryable(&self) -> bool {
        matches!(self, ClientError::Wire(w) if w.status.retryable())
    }

    /// The typed status, when the server got far enough to send one.
    pub fn wire_status(&self) -> Option<WireStatus> {
        match self {
            ClientError::Wire(w) => Some(w.status),
            ClientError::Io(_) | ClientError::CircuitOpen => None,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "wire transport error: {e}"),
            ClientError::Wire(w) => write!(f, "{w}"),
            ClientError::CircuitOpen => {
                write!(f, "circuit breaker open: endpoint failing, cooldown not elapsed")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

/// Minimal blocking client for the wire protocol (used by tests, examples
/// and external tooling). Errors are typed: match on
/// [`ClientError::Wire`] / [`WireStatus`] to distinguish retryable overload
/// from terminal rejections.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Reused request-encode / reply-decode byte buffer: steady-state
    /// classify rounds do no per-request allocation on the byte path, and
    /// each request leaves in one gathered write.
    scratch: Vec<u8>,
}

/// Client-side sanity caps so a rogue server can't make *us* allocate
/// unboundedly (mirrors the server's frame limits).
const MAX_REPLY_MSG: usize = 1 << 16;
const MAX_REPLY_LOGITS: usize = 1 << 22;

impl NetClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).context("connect")?;
        Ok(NetClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            scratch: Vec::new(),
        })
    }

    /// Bound this client's own socket reads/writes (`None` = blocking).
    pub fn set_io_timeout(&mut self, t: Option<Duration>) -> std::io::Result<()> {
        self.reader.get_ref().set_read_timeout(t)?;
        self.writer.set_write_timeout(t)
    }

    /// Classify one CHW image on `route`; returns (logits, predicted).
    /// Sends an untagged frame (interactive lane) — byte-compatible with
    /// pre-lane servers.
    pub fn classify(
        &mut self,
        route: &str,
        image: &Tensor,
    ) -> Result<(Vec<f32>, usize), ClientError> {
        self.classify_frame(route, image, None)
    }

    /// [`NetClient::classify`] with an explicit scheduling lane (sends a
    /// lane-tagged frame — requires a lane-aware server).
    pub fn classify_with_priority(
        &mut self,
        route: &str,
        image: &Tensor,
        priority: Priority,
    ) -> Result<(Vec<f32>, usize), ClientError> {
        self.classify_frame(route, image, Some(priority))
    }

    fn classify_frame(
        &mut self,
        route: &str,
        image: &Tensor,
        lane: Option<Priority>,
    ) -> Result<(Vec<f32>, usize), ClientError> {
        self.send_frame(route, image.data(), lane)?;
        match self.read_reply()? {
            Reply::Ok(logits, predicted) => Ok((logits, predicted)),
            Reply::Msg(status, message) => Err(ClientError::Wire(WireError { status, message })),
        }
    }

    /// Query the [`HEALTH_ROUTE`] built-in; returns the report text.
    pub fn health(&mut self) -> Result<String, ClientError> {
        self.send_frame(HEALTH_ROUTE, &[], None)?;
        match self.read_reply()? {
            Reply::Msg(WireStatus::Health, report) => Ok(report),
            Reply::Msg(status, message) => Err(ClientError::Wire(WireError { status, message })),
            Reply::Ok(..) => Err(ClientError::Io(std::io::Error::new(
                ErrorKind::InvalidData,
                "Ok reply to a health probe",
            ))),
        }
    }

    fn send_frame(
        &mut self,
        route: &str,
        floats: &[f32],
        lane: Option<Priority>,
    ) -> Result<(), ClientError> {
        let mut len = route.len() as u32;
        if lane.is_some() {
            len |= LANE_FLAG;
        }
        let buf = &mut self.scratch;
        buf.clear();
        buf.reserve(8 + route.len() + lane.is_some() as usize + floats.len() * 4);
        buf.extend_from_slice(&len.to_le_bytes());
        buf.extend_from_slice(route.as_bytes());
        if let Some(p) = lane {
            buf.push(p.to_wire());
        }
        buf.extend_from_slice(&(floats.len() as u32).to_le_bytes());
        for v in floats {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        // One gathered write: the whole frame leaves in a single syscall
        // instead of per-field writes through a BufWriter.
        self.writer.write_all(buf)?;
        Ok(())
    }

    fn read_reply(&mut self) -> Result<Reply, ClientError> {
        let mut status = [0u8; 1];
        self.reader.read_exact(&mut status)?;
        let status = WireStatus::from_code(status[0]).ok_or_else(|| {
            ClientError::Io(std::io::Error::new(
                ErrorKind::InvalidData,
                format!("unknown wire status {}", status[0]),
            ))
        })?;
        if status == WireStatus::Ok {
            let n = rd_u32(&mut self.reader).map_err(ClientError::Io)? as usize;
            if n > MAX_REPLY_LOGITS {
                return Err(ClientError::Io(std::io::Error::new(
                    ErrorKind::InvalidData,
                    format!("implausible logits count {n}"),
                )));
            }
            // Bulk read + chunked decode: one read_exact for the whole
            // logits block into the reused scratch, not one per float.
            self.scratch.clear();
            self.scratch.resize(n * 4, 0);
            self.reader.read_exact(&mut self.scratch)?;
            let logits: Vec<f32> = self
                .scratch
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let predicted = rd_u32(&mut self.reader).map_err(ClientError::Io)? as usize;
            Ok(Reply::Ok(logits, predicted))
        } else {
            let n = rd_u32(&mut self.reader).map_err(ClientError::Io)? as usize;
            if n > MAX_REPLY_MSG {
                return Err(ClientError::Io(std::io::Error::new(
                    ErrorKind::InvalidData,
                    format!("implausible message length {n}"),
                )));
            }
            self.scratch.clear();
            self.scratch.resize(n, 0);
            self.reader.read_exact(&mut self.scratch)?;
            Ok(Reply::Msg(status, String::from_utf8_lossy(&self.scratch).into_owned()))
        }
    }
}

enum Reply {
    Ok(Vec<f32>, usize),
    Msg(WireStatus, String),
}

// ----------------------------------------------------- resilient client --

/// Knobs for [`ResilientClient`]: retry budget, backoff shape, and circuit
/// breaker thresholds. All durations are wall-clock.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per call, including the first (min 1).
    pub max_attempts: u32,
    /// Backoff before attempt 2 (doubles each retry, jittered ±50%).
    pub base_backoff: Duration,
    /// Cap on a single backoff sleep.
    pub max_backoff: Duration,
    /// Wall-clock budget for one call including backoffs; a retry whose
    /// backoff would cross this deadline returns the last error instead.
    /// `None` = bounded by `max_attempts` only.
    pub call_deadline: Option<Duration>,
    /// Consecutive failures that open the circuit.
    pub failure_threshold: u32,
    /// How long an open circuit fails fast before admitting one probe.
    pub circuit_cooldown: Duration,
    /// Seed for the jitter RNG — same seed, same backoff schedule, so
    /// fault-injection tests are deterministic.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
            call_deadline: None,
            failure_threshold: 3,
            circuit_cooldown: Duration::from_millis(200),
            seed: 0x5EED,
        }
    }
}

/// Circuit breaker state: `Closed` (traffic flows) → `Open` (fail fast)
/// after `failure_threshold` consecutive failures → `HalfOpen` (single
/// probe) once `circuit_cooldown` elapses → `Closed` on probe success or
/// back to `Open` on probe failure.
enum Circuit {
    Closed,
    Open { since: Instant },
    HalfOpen,
}

/// Self-healing wrapper around [`NetClient`]: reconnects on transport
/// errors, retries retryable outcomes with jittered exponential backoff,
/// and trips a half-open circuit breaker when the endpoint is down — the
/// client half of the end-to-end fault contract in
/// `docs/serving-robustness.md`.
///
/// Retry semantics are *at-least-once*: a transport error mid-call cannot
/// tell whether the server executed the request, and classification is
/// pure, so the client reconnects and resends. Callers needing exactly-once
/// must deduplicate above this layer.
///
/// The connection is lazy — constructing the client does no I/O, so a
/// client can be created against a not-yet-started (or currently dead)
/// endpoint and will connect on first use.
pub struct ResilientClient {
    addr: String,
    policy: RetryPolicy,
    conn: Option<NetClient>,
    circuit: Circuit,
    consecutive_failures: u32,
    ever_connected: bool,
    io_timeout: Option<Duration>,
    metrics: Arc<ClientMetrics>,
    rng: Rng,
}

impl ResilientClient {
    /// Build a client for `addr` (no I/O until the first call).
    pub fn connect_lazy(addr: impl Into<String>, policy: RetryPolicy) -> ResilientClient {
        ResilientClient::with_metrics(addr, policy, Arc::new(ClientMetrics::default()))
    }

    /// [`ResilientClient::connect_lazy`] with a shared metrics sink, so a
    /// harness can reconcile retry/circuit counters across many clients.
    pub fn with_metrics(
        addr: impl Into<String>,
        policy: RetryPolicy,
        metrics: Arc<ClientMetrics>,
    ) -> ResilientClient {
        let seed = policy.seed;
        ResilientClient {
            addr: addr.into(),
            policy,
            conn: None,
            circuit: Circuit::Closed,
            consecutive_failures: 0,
            ever_connected: false,
            io_timeout: None,
            metrics,
            rng: Rng::new(seed),
        }
    }

    /// Socket read/write timeout applied to every (re)connection
    /// (`None` = blocking). Takes effect from the next attempt.
    pub fn set_io_timeout(&mut self, t: Option<Duration>) {
        self.io_timeout = t;
        if let Some(c) = self.conn.as_mut() {
            let _ = c.set_io_timeout(t);
        }
    }

    /// Retry/reconnect/circuit counters for this client.
    pub fn metrics(&self) -> &ClientMetrics {
        &self.metrics
    }

    /// True while the breaker is open (calls fail fast with
    /// [`ClientError::CircuitOpen`] until the cooldown admits a probe).
    pub fn circuit_open(&self) -> bool {
        matches!(self.circuit, Circuit::Open { .. })
    }

    /// [`NetClient::classify`] with retries, reconnects, and the breaker.
    pub fn classify(
        &mut self,
        route: &str,
        image: &Tensor,
    ) -> Result<(Vec<f32>, usize), ClientError> {
        self.call(|c| c.classify(route, image))
    }

    /// [`NetClient::classify_with_priority`] through the resilience layer.
    pub fn classify_with_priority(
        &mut self,
        route: &str,
        image: &Tensor,
        priority: Priority,
    ) -> Result<(Vec<f32>, usize), ClientError> {
        self.call(|c| c.classify_with_priority(route, image, priority))
    }

    /// [`NetClient::health`] through the resilience layer.
    pub fn health(&mut self) -> Result<String, ClientError> {
        self.call(|c| c.health())
    }

    /// The retry loop shared by every call: circuit admission → ensure
    /// connected → attempt → on failure, classify (transport errors
    /// reconnect-and-retry; `retryable()` wire statuses retry; everything
    /// else is terminal) and back off within the attempt/deadline budget.
    fn call<T>(
        &mut self,
        mut op: impl FnMut(&mut NetClient) -> Result<T, ClientError>,
    ) -> Result<T, ClientError> {
        let start = Instant::now();
        let deadline = self.policy.call_deadline.map(|d| start + d);
        let budget = self.policy.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            if let Circuit::Open { since } = self.circuit {
                if since.elapsed() < self.policy.circuit_cooldown {
                    self.metrics.circuit_open_rejections.fetch_add(1, Ordering::Relaxed);
                    return Err(ClientError::CircuitOpen);
                }
                // Cooldown elapsed: this call is the single probe.
                self.circuit = Circuit::HalfOpen;
            }
            let result = self.ensure_connected().and_then(|()| {
                op(self.conn.as_mut().expect("ensure_connected fills conn"))
            });
            let e = match result {
                Ok(v) => {
                    self.on_success();
                    return Ok(v);
                }
                Err(e) => e,
            };
            let transport = matches!(e, ClientError::Io(_));
            if transport {
                // The stream may be desynced mid-frame; never reuse it.
                self.conn = None;
            }
            self.on_failure();
            let out_of_budget = attempt >= budget;
            // A freshly opened (or re-opened) circuit ends the call with the
            // real error; the fail-fast path serves *subsequent* calls.
            if (!transport && !e.retryable()) || out_of_budget || self.circuit_open() {
                return Err(e);
            }
            let shift = (attempt - 1).min(10);
            let exp = self
                .policy
                .base_backoff
                .saturating_mul(1u32 << shift)
                .min(self.policy.max_backoff);
            // ±50% deterministic jitter decorrelates retry storms.
            let sleep = exp.mul_f64(0.5 + self.rng.uniform());
            if let Some(d) = deadline {
                if Instant::now() + sleep >= d {
                    return Err(e);
                }
            }
            self.metrics.client_retries.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(sleep);
        }
    }

    fn ensure_connected(&mut self) -> Result<(), ClientError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut c = NetClient::connect(&self.addr[..]).map_err(|e| {
            ClientError::Io(std::io::Error::new(
                ErrorKind::ConnectionRefused,
                format!("connect {}: {e:#}", self.addr),
            ))
        })?;
        c.set_io_timeout(self.io_timeout)?;
        if self.ever_connected {
            self.metrics.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        self.ever_connected = true;
        self.conn = Some(c);
        Ok(())
    }

    fn on_success(&mut self) {
        self.consecutive_failures = 0;
        // A successful probe (or any success) closes the breaker.
        self.circuit = Circuit::Closed;
    }

    fn on_failure(&mut self) {
        self.consecutive_failures += 1;
        let trip = match self.circuit {
            // A failed probe re-opens immediately — one probe per cooldown.
            Circuit::HalfOpen => true,
            Circuit::Closed => {
                self.consecutive_failures >= self.policy.failure_threshold.max(1)
            }
            Circuit::Open { .. } => false,
        };
        if trip {
            self.circuit = Circuit::Open { since: Instant::now() };
            self.metrics.circuit_opens.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use crate::coordinator::server::CoordinatorConfig;
    use crate::util::prop;
    use std::sync::atomic::AtomicU64;

    fn test_router() -> Arc<Router> {
        let mut r = Router::new();
        r.add_route(
            "mock",
            CoordinatorConfig::default(),
            Box::new(|| {
                Ok(Box::new(MockBackend {
                    classes: 4,
                    delay: std::time::Duration::ZERO,
                    calls: Arc::new(AtomicU64::new(0)),
                }) as Box<dyn Backend>)
            }),
        )
        .unwrap();
        Arc::new(r)
    }

    const SPEC: ImageSpec = ImageSpec { c: 1, h: 2, w: 2 };

    #[test]
    fn round_trip_over_tcp() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 0.25);
        let (logits, predicted) = client.classify("mock", &img).unwrap();
        assert_eq!(logits, vec![1.0, 0.0, 0.0, 0.0]); // row sum = 4 * 0.25
        assert_eq!(predicted, 0);
        // Pipelined second round on the same connection.
        let (logits2, _) = client.classify("mock", &Tensor::filled(&[1, 1, 2, 2], 0.5)).unwrap();
        assert_eq!(logits2[0], 2.0);
        let m = server.shutdown();
        assert_eq!(m.frames.load(Ordering::Relaxed), 2);
        assert!(m.bytes_in.load(Ordering::Relaxed) > 0);
        assert!(m.bytes_out.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn unknown_route_reports_typed_error() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let err = client.classify("nope", &Tensor::filled(&[1, 1, 2, 2], 0.1)).unwrap_err();
        assert_eq!(err.wire_status(), Some(WireStatus::NoRoute));
        assert!(!err.retryable(), "NoRoute is terminal");
        assert!(err.to_string().contains("no route"), "{err}");
        server.shutdown();
    }

    #[test]
    fn wrong_image_size_reports_error_and_stays_in_sync() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let err = client.classify("mock", &Tensor::filled(&[1, 1, 3, 3], 0.1)).unwrap_err();
        assert_eq!(err.wire_status(), Some(WireStatus::BadRequest));
        assert!(err.to_string().contains("expected 4 floats"), "{err}");
        // The stream is still in sync: the next round succeeds.
        let (logits, _) = client.classify("mock", &Tensor::filled(&[1, 1, 2, 2], 1.0)).unwrap();
        assert_eq!(logits[0], 4.0);
        let m = server.shutdown();
        assert_eq!(m.malformed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_clients() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = NetClient::connect(addr).unwrap();
                    for i in 0..8 {
                        let v = (t * 8 + i) as f32 * 0.1;
                        let (logits, _) =
                            c.classify("mock", &Tensor::filled(&[1, 1, 2, 2], v)).unwrap();
                        assert!((logits[0] - 4.0 * v).abs() < 1e-5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(server.metrics().total_conns.load(Ordering::Relaxed) >= 4);
        let m = server.shutdown();
        assert_eq!(m.active_conns.load(Ordering::Relaxed), 0, "handlers must drain");
    }

    #[test]
    fn health_route_reports_readiness() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let report = client.health().unwrap();
        assert!(report.contains("ready=true"), "{report}");
        assert!(report.contains("mock"), "{report}");
        server.shutdown();
    }

    #[test]
    fn status_codes_round_trip() {
        for code in 0..=11u8 {
            let s = WireStatus::from_code(code).unwrap();
            assert_eq!(s as u8, code);
        }
        assert_eq!(WireStatus::from_code(12), None);
        assert_eq!(WireStatus::from_code(255), None);
        assert!(WireStatus::Busy.retryable());
        assert!(WireStatus::Shed.retryable());
        assert!(!WireStatus::BadFrame.retryable());
        assert!(!WireStatus::NoWorkers.retryable());
    }

    // ---- frame parser (pure, over in-memory readers) ----

    fn parse(bytes: &[u8], cfg: &NetConfig) -> (Result<Frame, FrameError>, FrameScratch) {
        let mut scratch = FrameScratch::new();
        let res =
            read_frame_into(&mut std::io::Cursor::new(bytes.to_vec()), SPEC, cfg, &mut scratch);
        (res, scratch)
    }

    fn valid_frame(route: &str, floats: &[f32]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&(route.len() as u32).to_le_bytes());
        b.extend_from_slice(route.as_bytes());
        b.extend_from_slice(&(floats.len() as u32).to_le_bytes());
        for v in floats {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b
    }

    #[test]
    fn parser_rejects_oversized_counts_before_allocating() {
        let cfg = NetConfig::default();
        // n_floats = u32::MAX: the ~16 GiB allocation must never happen;
        // the frame-size check fires on the prefix alone.
        let mut b = valid_frame("mock", &[]);
        let n = b.len();
        b[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        match parse(&b, &cfg).0 {
            Err(FrameError::Reject { status: WireStatus::BadFrame, fatal: true, .. }) => {}
            _ => panic!("oversized n_floats must be a fatal BadFrame"),
        }
        // Oversized route_len likewise.
        let mut b = vec![0u8; 4];
        b.copy_from_slice(&u32::MAX.to_le_bytes());
        match parse(&b, &cfg).0 {
            Err(FrameError::Reject { status: WireStatus::BadFrame, fatal: true, .. }) => {}
            _ => panic!("oversized route_len must be a fatal BadFrame"),
        }
    }

    #[test]
    fn parser_in_sync_rejections_consume_whole_frame() {
        let cfg = NetConfig::default();
        // Wrong float count / empty route / non-UTF-8 route: the payload is
        // consumed so the next frame parses cleanly.
        let mut cases: Vec<Vec<u8>> = Vec::new();
        cases.push(valid_frame("mock", &[1.0; 9])); // wrong count
        cases.push(valid_frame("", &[1.0; 4])); // empty route
        let mut bad_utf8 = valid_frame("mk", &[1.0; 4]);
        bad_utf8[4] = 0xFF; // corrupt a route byte
        bad_utf8[5] = 0xFE;
        cases.push(bad_utf8);
        for case in cases {
            let mut stream = case.clone();
            stream.extend_from_slice(&valid_frame("mock", &[2.0; 4]));
            let mut r = std::io::Cursor::new(stream);
            // One scratch across both frames: the reject must leave no
            // residue that corrupts the next parse.
            let mut scratch = FrameScratch::new();
            match read_frame_into(&mut r, SPEC, &cfg, &mut scratch) {
                Err(FrameError::Reject { status: WireStatus::BadRequest, fatal: false, .. }) => {}
                _ => panic!("expected in-sync BadRequest"),
            }
            match read_frame_into(&mut r, SPEC, &cfg, &mut scratch) {
                Ok(Frame::Infer { priority, lane_tagged }) => {
                    assert_eq!(scratch.route_str(), "mock");
                    assert_eq!(scratch.image, vec![2.0; 4]);
                    assert_eq!(priority, Priority::Interactive, "untagged defaults interactive");
                    assert!(!lane_tagged);
                }
                _ => panic!("stream must stay in sync after an in-sync reject"),
            }
        }
    }

    /// A lane-tagged frame: `LANE_FLAG` set on `route_len`, one lane byte
    /// between the route and the float count.
    fn lane_frame(route: &str, lane: u8, floats: &[f32]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&(route.len() as u32 | LANE_FLAG).to_le_bytes());
        b.extend_from_slice(route.as_bytes());
        b.push(lane);
        b.extend_from_slice(&(floats.len() as u32).to_le_bytes());
        for v in floats {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b
    }

    #[test]
    fn parser_decodes_lane_tag() {
        let cfg = NetConfig::default();
        match parse(&lane_frame("mock", 1, &[1.0; 4]), &cfg) {
            (Ok(Frame::Infer { priority, lane_tagged }), scratch) => {
                assert_eq!(scratch.route_str(), "mock");
                assert_eq!(priority, Priority::Bulk);
                assert!(lane_tagged);
            }
            _ => panic!("lane-tagged frame must parse"),
        }
        match parse(&lane_frame("mock", 0, &[1.0; 4]), &cfg).0 {
            Ok(Frame::Infer { priority, .. }) => assert_eq!(priority, Priority::Interactive),
            _ => panic!("lane 0 must parse"),
        }
    }

    #[test]
    fn parser_rejects_unknown_lane_in_sync() {
        let cfg = NetConfig::default();
        let mut stream = lane_frame("mock", 7, &[1.0; 4]);
        stream.extend_from_slice(&valid_frame("mock", &[2.0; 4]));
        let mut r = std::io::Cursor::new(stream);
        let mut scratch = FrameScratch::new();
        match read_frame_into(&mut r, SPEC, &cfg, &mut scratch) {
            Err(FrameError::Reject { status: WireStatus::BadRequest, fatal: false, message }) => {
                assert!(message.contains("lane"), "{message}");
            }
            _ => panic!("unknown lane must be an in-sync BadRequest"),
        }
        match read_frame_into(&mut r, SPEC, &cfg, &mut scratch) {
            Ok(Frame::Infer { .. }) => assert_eq!(scratch.route_str(), "mock"),
            _ => panic!("stream must stay in sync after a bad lane tag"),
        }
    }

    #[test]
    fn lane_tagged_round_trip_over_tcp() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", Arc::clone(&router), SPEC).unwrap();
        let mut client = NetClient::connect(server.addr).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 0.5);
        let (logits, _) = client.classify_with_priority("mock", &img, Priority::Bulk).unwrap();
        assert_eq!(logits[0], 2.0);
        let (logits, _) =
            client.classify_with_priority("mock", &img, Priority::Interactive).unwrap();
        assert_eq!(logits[0], 2.0);
        let m = router.coordinator("mock").unwrap().metrics();
        assert_eq!(m.lane_submitted[1].load(Ordering::Relaxed), 1, "bulk lane tag must land");
        assert_eq!(m.lane_submitted[0].load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    /// Collapse a parse result to a comparable tag (Frame/FrameError carry
    /// no `Eq`; variant identity + status/fatality is what must match when
    /// comparing a fresh-scratch parse against a dirty-scratch one).
    fn outcome_tag(r: &Result<Frame, FrameError>) -> String {
        match r {
            Ok(Frame::Infer { priority, lane_tagged }) => format!("infer:{priority:?}:{lane_tagged}"),
            Ok(Frame::Health) => "health".into(),
            Ok(Frame::Eof) => "eof".into(),
            Err(FrameError::Reject { status, fatal, .. }) => format!("reject:{status:?}:{fatal}"),
            Err(FrameError::Io(e)) => format!("io:{:?}", e.kind()),
        }
    }

    /// A scratch pre-filled with plausible residue from a previous request,
    /// as the pooled-buffer reuse path produces.
    fn dirty_scratch() -> FrameScratch {
        FrameScratch {
            route: b"stale-route-from-last-request".to_vec(),
            payload: vec![0xAB; 64],
            image: vec![999.0; 16],
            reply: vec![0xCD; 32],
        }
    }

    #[test]
    fn parser_never_panics_on_random_prefixes() {
        let cfg = NetConfig::default();
        prop::check("net-frame-parser-total", 0x5EED_0007, |rng, _| {
            let len = rng.below(96) as usize;
            let mut bytes = Vec::with_capacity(len);
            while bytes.len() < len {
                bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
            }
            bytes.truncate(len);
            // Half the cases: corrupt/truncate a valid frame instead of
            // pure noise, to exercise the deeper parser states.
            if rng.below(2) == 0 {
                let mut f = valid_frame("mock", &[1.0, 2.0, 3.0, 4.0]);
                let cut = rng.below(f.len() as u64 + 1) as usize;
                f.truncate(cut);
                if !f.is_empty() {
                    let i = rng.below(f.len() as u64) as usize;
                    f[i] ^= rng.next_u64() as u8;
                }
                bytes = f;
            }
            // Parse the same bytes twice: into a fresh scratch and into a
            // deliberately dirty recycled one. Neither may panic, outcomes
            // must match exactly, the same bytes must be consumed, and no
            // stale bytes from the recycled buffers may leak through.
            let mut fresh = FrameScratch::new();
            let mut ra = std::io::Cursor::new(bytes.clone());
            let a = read_frame_into(&mut ra, SPEC, &cfg, &mut fresh);
            let mut dirty = dirty_scratch();
            let mut rb = std::io::Cursor::new(bytes);
            let b = read_frame_into(&mut rb, SPEC, &cfg, &mut dirty);
            assert_eq!(outcome_tag(&a), outcome_tag(&b), "reused buffers changed the outcome");
            assert_eq!(ra.position(), rb.position(), "reused buffers changed bytes consumed");
            if matches!(a, Ok(Frame::Infer { .. })) {
                assert_eq!(fresh.route, dirty.route, "stale route bytes leaked across requests");
                assert_eq!(fresh.image, dirty.image, "stale image floats leaked across requests");
            }
        });
    }

    #[test]
    fn dirty_scratch_reuse_parses_smaller_frames_exactly() {
        // A long-routed frame followed by a short-routed one through the
        // same scratch: the shrink path must not keep tail bytes from the
        // previous (larger) request.
        let cfg = NetConfig::default();
        let mut stream = valid_frame("a-much-longer-route-name", &[7.0; 4]);
        stream.extend_from_slice(&valid_frame("m", &[1.0, 2.0, 3.0, 4.0]));
        let mut r = std::io::Cursor::new(stream);
        let mut scratch = dirty_scratch();
        match read_frame_into(&mut r, SPEC, &cfg, &mut scratch) {
            Ok(Frame::Infer { .. }) => {
                assert_eq!(scratch.route_str(), "a-much-longer-route-name");
                assert_eq!(scratch.image, vec![7.0; 4]);
            }
            _ => panic!("first frame must parse"),
        }
        match read_frame_into(&mut r, SPEC, &cfg, &mut scratch) {
            Ok(Frame::Infer { .. }) => {
                assert_eq!(scratch.route_str(), "m");
                assert_eq!(scratch.image, vec![1.0, 2.0, 3.0, 4.0]);
            }
            _ => panic!("second frame must parse"),
        }
    }

    #[test]
    fn reply_encoders_reuse_buffer_without_residue() {
        // A long reply followed by a short one into the same buffer: the
        // staged bytes must be exactly the short reply (gathered-write
        // correctness depends on buf.len() being exact).
        let mut buf = Vec::new();
        encode_ok(&mut buf, &[1.5, -2.0, 0.25, 9.0, 4.0], 3);
        assert_eq!(buf.len(), 9 + 5 * 4);
        assert_eq!(buf[0], WireStatus::Ok as u8);
        encode_msg(&mut buf, WireStatus::Shed, "q");
        assert_eq!(buf, vec![WireStatus::Shed as u8, 1, 0, 0, 0, b'q']);
        encode_ok(&mut buf, &[0.5], 0);
        let mut expect = vec![WireStatus::Ok as u8];
        expect.extend_from_slice(&1u32.to_le_bytes());
        expect.extend_from_slice(&0.5f32.to_le_bytes());
        expect.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(buf, expect);
    }

    #[test]
    fn accept_backoff_sleep_interrupts_on_stop() {
        let stop = Arc::new(AtomicBool::new(false));
        // Uninterrupted short wait completes and reports true.
        assert!(sleep_unless_stopped(&stop, Duration::from_millis(5)));
        // A wait far longer than the test budget returns early once stop
        // flips from another thread.
        let s2 = Arc::clone(&stop);
        let flipper = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.store(true, Ordering::Relaxed);
        });
        let t0 = Instant::now();
        let completed = sleep_unless_stopped(&stop, Duration::from_secs(30));
        flipper.join().unwrap();
        assert!(!completed, "stop must interrupt the backoff");
        assert!(t0.elapsed() < Duration::from_secs(5), "interrupt must be prompt");
    }

    #[test]
    fn resilient_client_round_trip_without_faults_spends_no_retries() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let mut client =
            ResilientClient::connect_lazy(server.addr.to_string(), RetryPolicy::default());
        let (logits, predicted) =
            client.classify("mock", &Tensor::filled(&[1, 1, 2, 2], 0.25)).unwrap();
        assert_eq!(logits[0], 1.0);
        assert_eq!(predicted, 0);
        let report = client.health().unwrap();
        assert!(report.contains("ready=true"), "{report}");
        // Healthy endpoint: the resilience layer must be pure overhead.
        let m = client.metrics();
        assert_eq!(m.client_retries.load(Ordering::Relaxed), 0);
        assert_eq!(m.reconnects.load(Ordering::Relaxed), 0);
        assert_eq!(m.circuit_opens.load(Ordering::Relaxed), 0);
        assert!(!client.circuit_open());
        server.shutdown();
    }

    #[test]
    fn resilient_client_terminal_rejection_is_not_retried() {
        let router = test_router();
        let server = NetServer::serve("127.0.0.1:0", router, SPEC).unwrap();
        let mut client =
            ResilientClient::connect_lazy(server.addr.to_string(), RetryPolicy::default());
        let err = client.classify("nope", &Tensor::filled(&[1, 1, 2, 2], 0.1)).unwrap_err();
        assert_eq!(err.wire_status(), Some(WireStatus::NoRoute));
        assert_eq!(client.metrics().client_retries.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn circuit_opens_on_dead_endpoint_and_fails_fast() {
        // Bind-then-drop reserves an address that now refuses connections.
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let policy = RetryPolicy {
            max_attempts: 1, // isolate circuit accounting from the retry loop
            failure_threshold: 2,
            circuit_cooldown: Duration::from_secs(3600),
            ..RetryPolicy::default()
        };
        let img = Tensor::filled(&[1, 1, 2, 2], 0.1);
        let mut client = ResilientClient::connect_lazy(dead_addr, policy);
        // Two connect failures reach the threshold and trip the breaker.
        for _ in 0..2 {
            let err = client.classify("mock", &img).unwrap_err();
            assert!(matches!(err, ClientError::Io(_)), "{err}");
        }
        assert!(client.circuit_open());
        let m = client.metrics();
        assert_eq!(m.circuit_opens.load(Ordering::Relaxed), 1);
        // Within the cooldown every call fails fast without touching the
        // network, with the typed non-retryable error.
        let t0 = Instant::now();
        let err = client.classify("mock", &img).unwrap_err();
        assert!(matches!(err, ClientError::CircuitOpen), "{err}");
        assert!(!err.retryable());
        assert_eq!(err.wire_status(), None);
        assert!(t0.elapsed() < Duration::from_secs(1), "fail-fast must not dial");
        assert_eq!(m.circuit_open_rejections.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn half_open_probe_closes_circuit_on_recovery() {
        // Start dead, trip the breaker, then bring a real server up on the
        // same address and watch the single probe close the circuit.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let policy = RetryPolicy {
            max_attempts: 1,
            failure_threshold: 1,
            circuit_cooldown: Duration::from_millis(10),
            ..RetryPolicy::default()
        };
        let img = Tensor::filled(&[1, 1, 2, 2], 0.5);
        let mut client = ResilientClient::connect_lazy(addr.to_string(), policy);
        client.classify("mock", &img).unwrap_err();
        assert!(client.circuit_open());
        // Rebinding the exact port can race another process; tolerate a
        // failure by skipping (the chaos suite covers this end-to-end).
        let router = test_router();
        let server = match NetServer::serve(addr, router, SPEC) {
            Ok(s) => s,
            Err(_) => return,
        };
        std::thread::sleep(Duration::from_millis(20)); // let the cooldown lapse
        let (logits, _) = client.classify("mock", &img).unwrap();
        assert_eq!(logits[0], 2.0);
        assert!(!client.circuit_open(), "successful probe must close the breaker");
        // Never-connected dials don't count as reconnects.
        assert_eq!(client.metrics().reconnects.load(Ordering::Relaxed), 0);
        server.shutdown();
    }
}
