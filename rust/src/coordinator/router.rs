//! Multi-model front door: route requests by model name to per-model
//! coordinators (each with its own queue, batching policy and workers).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::SubmitError;
use crate::coordinator::request::{InferReply, InferResponse};
use crate::coordinator::server::{Coordinator, CoordinatorConfig};
use crate::tensor::Tensor;

/// Routes inference traffic across models/variants.
pub struct Router {
    routes: BTreeMap<String, Coordinator>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Router {
        Router { routes: BTreeMap::new() }
    }

    /// Register a route (e.g. "minialexnet/f32").
    pub fn add_route(
        &mut self,
        name: &str,
        config: CoordinatorConfig,
        factory: BackendFactory,
    ) -> Result<()> {
        anyhow::ensure!(!self.routes.contains_key(name), "route {name} already exists");
        self.routes.insert(name.to_string(), Coordinator::start(config, factory)?);
        Ok(())
    }

    pub fn route_names(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Submit to a named route. The receiver yields exactly one typed
    /// [`InferReply`].
    pub fn submit(
        &self,
        route: &str,
        image: Tensor,
    ) -> Result<std::sync::mpsc::Receiver<InferReply>> {
        let c = self.routes.get(route).with_context(|| format!("no route {route}"))?;
        c.submit(image).map_err(|e| match e {
            SubmitError::QueueFull(cap) => anyhow::anyhow!("route {route}: queue full ({cap})"),
            SubmitError::ShutDown => anyhow::anyhow!("route {route}: shut down"),
            SubmitError::NoWorkers => anyhow::anyhow!("route {route}: no live workers"),
        })
    }

    /// Submit and wait.
    pub fn infer(&self, route: &str, image: Tensor) -> Result<InferResponse> {
        let c = self.routes.get(route).with_context(|| format!("no route {route}"))?;
        c.infer(image)
    }

    pub fn coordinator(&self, route: &str) -> Option<&Coordinator> {
        self.routes.get(route)
    }

    /// Shut every route down, returning per-route metric summaries.
    pub fn shutdown(self) -> Vec<(String, String)> {
        self.routes
            .into_iter()
            .map(|(name, c)| {
                let m = c.shutdown();
                (name, m.summary())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;
    use std::time::Duration;

    fn factory(classes: usize) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(MockBackend {
                classes,
                delay: Duration::ZERO,
                calls: Arc::new(AtomicU64::new(0)),
            }) as Box<dyn Backend>)
        })
    }

    #[test]
    fn routes_independently() {
        let mut r = Router::new();
        r.add_route("a", CoordinatorConfig::default(), factory(2)).unwrap();
        r.add_route("b", CoordinatorConfig::default(), factory(6)).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 1.0);
        assert_eq!(r.infer("a", img.clone()).unwrap().logits.len(), 2);
        assert_eq!(r.infer("b", img.clone()).unwrap().logits.len(), 6);
        assert!(r.infer("c", img).is_err());
        let summaries = r.shutdown();
        assert_eq!(summaries.len(), 2);
    }

    #[test]
    fn duplicate_route_rejected() {
        let mut r = Router::new();
        r.add_route("a", CoordinatorConfig::default(), factory(2)).unwrap();
        assert!(r.add_route("a", CoordinatorConfig::default(), factory(2)).is_err());
    }
}
