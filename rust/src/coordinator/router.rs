//! Multi-model front door: route requests by model name to per-model
//! coordinators (each with its own queue, batching policy and workers).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::SubmitError;
use crate::coordinator::request::{InferError, InferReply, InferResponse, Priority};
use crate::coordinator::server::{Coordinator, CoordinatorConfig};
use crate::tensor::Tensor;

/// Typed failure of a routed inference: the route lookup, the synchronous
/// admission, or the coordinator's typed reply. Carries the concrete
/// [`SubmitError`] / [`InferError`] so front doors (the TCP wire path) can
/// translate instead of flattening everything into one error string.
#[derive(Debug)]
pub enum RouteError {
    /// No route registered under this name.
    NoRoute(String),
    /// The submission was refused synchronously (queue full, shut down,
    /// dead pool); no request was admitted.
    Rejected(SubmitError),
    /// The request was admitted and resolved to a typed error reply.
    Infer(InferError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::NoRoute(name) => write!(f, "no route {name}"),
            RouteError::Rejected(e) => write!(f, "{e}"),
            RouteError::Infer(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Extra per-route readiness detail surfaced through the wire `health`
/// built-in (e.g. the shared engine's pre-warm state: `warmed panels=6`).
/// Called on every health probe; keep it cheap and lock-light.
pub type RouteStatusFn = Box<dyn Fn() -> String + Send + Sync>;

/// Routes inference traffic across models/variants.
pub struct Router {
    routes: BTreeMap<String, Coordinator>,
    status: BTreeMap<String, RouteStatusFn>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Router {
        Router { routes: BTreeMap::new(), status: BTreeMap::new() }
    }

    /// Register a route (e.g. "minialexnet/f32").
    pub fn add_route(
        &mut self,
        name: &str,
        config: CoordinatorConfig,
        factory: BackendFactory,
    ) -> Result<()> {
        anyhow::ensure!(!self.routes.contains_key(name), "route {name} already exists");
        self.routes.insert(name.to_string(), Coordinator::start(config, factory)?);
        Ok(())
    }

    /// [`Router::add_route`] plus a status callback reported by the wire
    /// health route (pre-warm / panel-cache state for shared-engine routes).
    pub fn add_route_with_status(
        &mut self,
        name: &str,
        config: CoordinatorConfig,
        factory: BackendFactory,
        status: RouteStatusFn,
    ) -> Result<()> {
        self.add_route(name, config, factory)?;
        self.status.insert(name.to_string(), status);
        Ok(())
    }

    /// The route's extra status line, when one was registered.
    pub fn route_status(&self, route: &str) -> Option<String> {
        self.status.get(route).map(|f| f())
    }

    pub fn route_names(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Submit to a named route. The receiver yields exactly one typed
    /// [`InferReply`].
    pub fn submit(
        &self,
        route: &str,
        image: Tensor,
    ) -> Result<std::sync::mpsc::Receiver<InferReply>> {
        let c = self.routes.get(route).with_context(|| format!("no route {route}"))?;
        c.submit(image).map_err(|e| match e {
            SubmitError::QueueFull(cap) => anyhow::anyhow!("route {route}: queue full ({cap})"),
            SubmitError::ShutDown => anyhow::anyhow!("route {route}: shut down"),
            SubmitError::NoWorkers => anyhow::anyhow!("route {route}: no live workers"),
        })
    }

    /// Submit and wait, with a typed outcome: callers can distinguish a
    /// missing route from admission refusal from a typed inference error.
    /// This is the wire path's entry point (`coordinator/net.rs` maps each
    /// variant onto a `WireStatus` code).
    pub fn infer_typed(&self, route: &str, image: Tensor) -> Result<InferResponse, RouteError> {
        self.infer_typed_with(route, image, Priority::default())
    }

    /// [`Router::infer_typed`] with an explicit scheduling lane (the wire
    /// path decodes the optional lane byte into this).
    pub fn infer_typed_with(
        &self,
        route: &str,
        image: Tensor,
        priority: Priority,
    ) -> Result<InferResponse, RouteError> {
        self.infer_typed_pooled(route, image, priority, None)
    }

    /// [`Router::infer_typed_with`] plus a buffer-recycle hook (see
    /// [`Coordinator::submit_pooled`]): the image's float storage returns
    /// through `recycle` at reply time, letting the wire handler reuse one
    /// buffer per connection on the steady-state path.
    pub fn infer_typed_pooled(
        &self,
        route: &str,
        image: Tensor,
        priority: Priority,
        recycle: Option<std::sync::mpsc::SyncSender<Vec<f32>>>,
    ) -> Result<InferResponse, RouteError> {
        let c = self
            .routes
            .get(route)
            .ok_or_else(|| RouteError::NoRoute(route.to_string()))?;
        let rx =
            c.submit_pooled(image, None, priority, recycle).map_err(RouteError::Rejected)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(RouteError::Infer(e)),
            // Unreachable by the reply protocol (every admitted request gets
            // exactly one typed reply); degrade to an error, never a lie.
            Err(_) => Err(RouteError::Infer(InferError::NoWorkers)),
        }
    }

    /// Submit and wait (anyhow convenience over [`Router::infer_typed`]).
    pub fn infer(&self, route: &str, image: Tensor) -> Result<InferResponse> {
        self.infer_typed(route, image).map_err(anyhow::Error::from)
    }

    pub fn coordinator(&self, route: &str) -> Option<&Coordinator> {
        self.routes.get(route)
    }

    /// Shut every route down, returning per-route metric summaries.
    pub fn shutdown(self) -> Vec<(String, String)> {
        self.routes
            .into_iter()
            .map(|(name, c)| {
                let m = c.shutdown();
                (name, m.summary())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;
    use std::time::Duration;

    fn factory(classes: usize) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(MockBackend {
                classes,
                delay: Duration::ZERO,
                calls: Arc::new(AtomicU64::new(0)),
            }) as Box<dyn Backend>)
        })
    }

    #[test]
    fn routes_independently() {
        let mut r = Router::new();
        r.add_route("a", CoordinatorConfig::default(), factory(2)).unwrap();
        r.add_route("b", CoordinatorConfig::default(), factory(6)).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 1.0);
        assert_eq!(r.infer("a", img.clone()).unwrap().logits.len(), 2);
        assert_eq!(r.infer("b", img.clone()).unwrap().logits.len(), 6);
        assert!(r.infer("c", img).is_err());
        let summaries = r.shutdown();
        assert_eq!(summaries.len(), 2);
    }

    #[test]
    fn infer_typed_distinguishes_outcomes() {
        let mut r = Router::new();
        r.add_route("a", CoordinatorConfig::default(), factory(2)).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 1.0);
        assert!(r.infer_typed("a", img.clone()).is_ok());
        match r.infer_typed("missing", img) {
            Err(RouteError::NoRoute(name)) => assert_eq!(name, "missing"),
            other => panic!("expected NoRoute, got {other:?}"),
        }
        assert_eq!(RouteError::NoRoute("x".into()).to_string(), "no route x");
        assert_eq!(
            RouteError::Infer(InferError::DeadlineExceeded).to_string(),
            InferError::DeadlineExceeded.to_string()
        );
    }

    #[test]
    fn lane_tag_reaches_route_metrics() {
        let mut r = Router::new();
        r.add_route("a", CoordinatorConfig::default(), factory(2)).unwrap();
        let img = Tensor::filled(&[1, 1, 2, 2], 1.0);
        r.infer_typed_with("a", img.clone(), Priority::Bulk).unwrap();
        r.infer_typed_with("a", img, Priority::Interactive).unwrap();
        let m = r.coordinator("a").unwrap().metrics();
        use std::sync::atomic::Ordering;
        assert_eq!(m.lane_submitted[0].load(Ordering::Relaxed), 1);
        assert_eq!(m.lane_submitted[1].load(Ordering::Relaxed), 1);
    }

    #[test]
    fn duplicate_route_rejected() {
        let mut r = Router::new();
        r.add_route("a", CoordinatorConfig::default(), factory(2)).unwrap();
        assert!(r.add_route("a", CoordinatorConfig::default(), factory(2)).is_err());
    }
}
