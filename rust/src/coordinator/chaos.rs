//! Deterministic TCP fault-injecting proxy for resilience tests.
//!
//! [`ChaosProxy`] sits between a client and a real [`NetServer`]
//! (`crate::coordinator::net::NetServer`), forwarding bytes in both
//! directions while injecting *scheduled* faults: each accepted connection
//! pops the next [`ConnFault`] from a FIFO schedule (falling back to a
//! configurable default), and each direction of that connection applies its
//! own [`FaultKind`]. Randomness (corruption bytes) comes from a
//! [`Rng`](crate::util::rng::Rng) seeded from the proxy seed plus the
//! connection index, so a failing chaos scenario replays byte-identically
//! from its seed — this is a *deterministic* chaos harness, not a fuzzer.
//!
//! The proxy is intentionally protocol-ignorant: it corrupts and truncates
//! byte streams without knowing where frame boundaries are. The properties
//! under test — the server never desyncs silently, the client's
//! `ResilientClient` reconnects and retries to success, conservation of
//! typed outcomes holds exactly — must hold for *arbitrary* byte damage.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::rng::Rng;

/// One direction's fault for a proxied connection. All sizes are counted in
/// raw stream bytes from the start of the connection (the proxy does not
/// parse frames).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Forward bytes untouched.
    Pass,
    /// Hold the first forwarded bytes back for the given duration, then
    /// behave like [`FaultKind::Pass`] (models a slow link, not a dead one).
    Delay(Duration),
    /// Forward exactly `n` bytes, then close both halves of the connection
    /// (models a peer dying mid-frame).
    TruncateAfter(usize),
    /// Forward the first `n` bytes untouched, then XOR every subsequent
    /// byte with a nonzero seeded value (models line corruption; the frame
    /// grammar must catch it, never the allocator).
    CorruptAfter(usize),
    /// Close the connection immediately, before forwarding anything.
    Reset,
    /// Read and discard everything for the given duration without
    /// forwarding, then close (models a black-holed route: the peer sees
    /// silence, then loss).
    BlackHole(Duration),
    /// Forward one byte at a time with a 1ms pause between bytes (models
    /// pathological partial writes; exercises `read_exact` reassembly).
    Trickle,
}

/// Per-connection fault plan: independent faults for the client→server
/// (`up`) and server→client (`down`) byte streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConnFault {
    /// Fault applied to client→server bytes.
    pub up: FaultKind,
    /// Fault applied to server→client bytes.
    pub down: FaultKind,
}

impl ConnFault {
    /// No fault in either direction.
    pub fn clean() -> ConnFault {
        ConnFault { up: FaultKind::Pass, down: FaultKind::Pass }
    }
}

impl Default for ConnFault {
    fn default() -> ConnFault {
        ConnFault::clean()
    }
}

/// Counters for assertions: how many connections the proxy accepted and how
/// many carried a non-clean fault plan.
#[derive(Default)]
pub struct ChaosMetrics {
    /// Connections accepted from clients.
    pub connections: AtomicU64,
    /// Accepted connections whose plan was not `ConnFault::clean()`.
    pub faulted: AtomicU64,
    /// Accepted connections dropped because the upstream dial failed.
    pub upstream_failures: AtomicU64,
}

struct Shared {
    upstream: SocketAddr,
    stop: AtomicBool,
    /// FIFO of per-connection plans; empty → `default` applies.
    schedule: Mutex<VecDeque<ConnFault>>,
    default: Mutex<ConnFault>,
    metrics: ChaosMetrics,
    seed: u64,
    pumps: Mutex<Vec<JoinHandle<()>>>,
}

/// A seeded TCP fault-injecting proxy. See the module docs for the model.
pub struct ChaosProxy {
    /// Address clients should connect to.
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind an ephemeral local port and start proxying to `upstream`.
    /// `seed` fixes the corruption byte stream for replayability.
    pub fn start(upstream: impl ToSocketAddrs, seed: u64) -> Result<ChaosProxy> {
        let upstream = upstream
            .to_socket_addrs()
            .context("resolve upstream")?
            .next()
            .context("upstream resolved to no address")?;
        let listener = TcpListener::bind("127.0.0.1:0").context("bind chaos proxy")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            upstream,
            stop: AtomicBool::new(false),
            schedule: Mutex::new(VecDeque::new()),
            default: Mutex::new(ConnFault::clean()),
            metrics: ChaosMetrics::default(),
            seed,
            pumps: Mutex::new(Vec::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::Builder::new()
            .name("lqr-chaos-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .context("spawn chaos accept thread")?;
        Ok(ChaosProxy { addr, shared, accept_thread: Some(accept_thread) })
    }

    /// Queue a fault plan for the *next* accepted connection (FIFO). Plans
    /// queued here take precedence over [`ChaosProxy::set_default`].
    pub fn push_fault(&self, fault: ConnFault) {
        self.shared.schedule.lock().unwrap().push_back(fault);
    }

    /// Plan applied to connections with no queued fault (initially clean).
    pub fn set_default(&self, fault: ConnFault) {
        *self.shared.default.lock().unwrap() = fault;
    }

    /// Accept/fault counters.
    pub fn metrics(&self) -> &ChaosMetrics {
        &self.shared.metrics
    }

    /// Stop accepting, sever all proxied connections, and join every pump
    /// thread. Idempotent.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // Pumps poll `stop` on a short read-timeout slice; joining here
        // bounds teardown at roughly one slice per pump.
        let pumps = std::mem::take(&mut *self.shared.pumps.lock().unwrap());
        for h in pumps {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Slice granularity for every blocking wait in the proxy, so `stop` is
/// honored promptly regardless of fault timings.
const SLICE: Duration = Duration::from_millis(20);

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut conn_idx: u64 = 0;
    while !shared.stop.load(Ordering::Relaxed) {
        let (client, _) = match listener.accept() {
            Ok(c) => c,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            Err(_) => {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
        };
        shared.metrics.connections.fetch_add(1, Ordering::Relaxed);
        let plan = shared
            .schedule
            .lock()
            .unwrap()
            .pop_front()
            .unwrap_or_else(|| *shared.default.lock().unwrap());
        if plan != ConnFault::clean() {
            shared.metrics.faulted.fetch_add(1, Ordering::Relaxed);
        }
        let server = match TcpStream::connect(shared.upstream) {
            Ok(s) => s,
            Err(_) => {
                // Dead upstream: dropping the client socket models the
                // refused/reset connection the client would have seen.
                shared.metrics.upstream_failures.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        };
        client.set_nonblocking(false).ok();
        spawn_pumps(&shared, client, server, plan, conn_idx);
        conn_idx += 1;
    }
}

/// Start the two per-direction pump threads for one proxied connection.
/// Each pump owns a clone of both streams so either side's fault can sever
/// the whole connection.
fn spawn_pumps(
    shared: &Arc<Shared>,
    client: TcpStream,
    server: TcpStream,
    plan: ConnFault,
    conn_idx: u64,
) {
    let pairs = [
        (client.try_clone(), server.try_clone(), plan.up, "up"),
        (server.try_clone(), client.try_clone(), plan.down, "down"),
    ];
    let mut handles = Vec::with_capacity(2);
    for (i, (from, to, fault, dir)) in pairs.into_iter().enumerate() {
        let (Ok(from), Ok(to)) = (from, to) else {
            // Clone failure: sever what we have; the peer sees a reset-like
            // close, which is within the chaos contract anyway.
            let _ = client.shutdown(Shutdown::Both);
            let _ = server.shutdown(Shutdown::Both);
            return;
        };
        let stop = Arc::clone(shared);
        // Distinct deterministic stream per connection and direction.
        let rng = Rng::new(
            shared.seed ^ conn_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((i as u64) << 63),
        );
        let h = std::thread::Builder::new()
            .name(format!("lqr-chaos-{dir}-{conn_idx}"))
            .spawn(move || pump(from, to, fault, &stop.stop, rng));
        match h {
            Ok(h) => handles.push(h),
            Err(_) => {
                let _ = client.shutdown(Shutdown::Both);
                let _ = server.shutdown(Shutdown::Both);
            }
        }
    }
    shared.pumps.lock().unwrap().extend(handles);
}

/// Sleep `total` in stop-aware slices; false if interrupted.
fn sleep_sliced(stop: &AtomicBool, total: Duration) -> bool {
    let deadline = Instant::now() + total;
    loop {
        if stop.load(Ordering::Relaxed) {
            return false;
        }
        let now = Instant::now();
        if now >= deadline {
            return true;
        }
        std::thread::sleep((deadline - now).min(SLICE));
    }
}

/// Copy bytes `from` → `to`, applying `fault`. A clean peer EOF propagates
/// as a half-close (the opposite direction keeps flowing, so an in-flight
/// reply still arrives); every fault-triggered exit severs both streams so
/// the peer never waits on a half-dead proxy.
fn pump(from: TcpStream, to: TcpStream, fault: FaultKind, stop: &AtomicBool, mut rng: Rng) {
    let mut from = from;
    let mut to = to;
    // Short read timeout so the pump notices `stop` within one slice even
    // when the peer is silent.
    let _ = from.set_read_timeout(Some(SLICE));
    let sever = run_pump(&mut from, &mut to, fault, stop, &mut rng);
    if sever {
        let _ = from.shutdown(Shutdown::Both);
        let _ = to.shutdown(Shutdown::Both);
    } else {
        let _ = to.shutdown(Shutdown::Write);
    }
}

/// Returns true when the exit is a fault (sever both streams), false on a
/// clean peer EOF (half-close only).
fn run_pump(
    from: &mut TcpStream,
    to: &mut TcpStream,
    fault: FaultKind,
    stop: &AtomicBool,
    rng: &mut Rng,
) -> bool {
    if fault == FaultKind::Reset {
        return true; // close before forwarding anything
    }
    if let FaultKind::Delay(d) = fault {
        if !sleep_sliced(stop, d) {
            return true;
        }
    }
    let blackhole_deadline = match fault {
        FaultKind::BlackHole(d) => Some(Instant::now() + d),
        _ => None,
    };
    let mut forwarded: usize = 0;
    let mut buf = [0u8; 4096];
    loop {
        if stop.load(Ordering::Relaxed) {
            return true;
        }
        if let Some(deadline) = blackhole_deadline {
            if Instant::now() >= deadline {
                return true; // silence, then loss
            }
        }
        let n = match from.read(&mut buf) {
            Ok(0) => return false,
            Ok(n) => n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                continue;
            }
            Err(_) => return true,
        };
        let chunk = &mut buf[..n];
        let ok = match fault {
            FaultKind::BlackHole(_) => true, // discard
            FaultKind::TruncateAfter(limit) => {
                let take = limit.saturating_sub(forwarded).min(n);
                let sent = take == 0 || to.write_all(&chunk[..take]).is_ok();
                forwarded += take;
                if !sent || forwarded >= limit {
                    return true; // budget spent (or peer gone): sever mid-frame
                }
                true
            }
            FaultKind::CorruptAfter(limit) => {
                for (i, b) in chunk.iter_mut().enumerate() {
                    if forwarded + i >= limit {
                        // `| 1` guarantees the XOR actually flips bits.
                        *b ^= (rng.next_u64() as u8) | 1;
                    }
                }
                forwarded += n;
                to.write_all(chunk).is_ok()
            }
            FaultKind::Trickle => {
                let mut ok = true;
                for b in chunk.iter() {
                    if stop.load(Ordering::Relaxed) || to.write_all(&[*b]).is_err() {
                        ok = false;
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                forwarded += n;
                ok
            }
            FaultKind::Pass | FaultKind::Delay(_) => {
                forwarded += n;
                to.write_all(chunk).is_ok()
            }
            FaultKind::Reset => unreachable!("handled before the loop"),
        };
        if !ok {
            return true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo server: accepts one connection, echoes bytes until EOF.
    fn echo_server() -> (SocketAddr, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            // Serve a handful of connections then exit; tests create few.
            for _ in 0..8 {
                let Ok((mut s, _)) = listener.accept() else { return };
                std::thread::spawn(move || {
                    let mut buf = [0u8; 1024];
                    loop {
                        match s.read(&mut buf) {
                            Ok(0) | Err(_) => return,
                            Ok(n) => {
                                if s.write_all(&buf[..n]).is_err() {
                                    return;
                                }
                            }
                        }
                    }
                });
            }
        });
        (addr, h)
    }

    fn send_recv(addr: SocketAddr, payload: &[u8]) -> std::io::Result<Vec<u8>> {
        let mut s = TcpStream::connect(addr)?;
        s.set_read_timeout(Some(Duration::from_secs(5)))?;
        s.write_all(payload)?;
        s.shutdown(Shutdown::Write)?;
        let mut out = Vec::new();
        s.read_to_end(&mut out)?;
        Ok(out)
    }

    #[test]
    fn clean_connection_passes_bytes_through_unchanged() {
        let (upstream, _h) = echo_server();
        let mut proxy = ChaosProxy::start(upstream, 1).unwrap();
        let echoed = send_recv(proxy.addr, b"hello through the proxy").unwrap();
        assert_eq!(echoed, b"hello through the proxy");
        assert_eq!(proxy.metrics().connections.load(Ordering::Relaxed), 1);
        assert_eq!(proxy.metrics().faulted.load(Ordering::Relaxed), 0);
        proxy.shutdown();
    }

    #[test]
    fn scheduled_fault_applies_once_then_falls_back_to_default() {
        let (upstream, _h) = echo_server();
        let mut proxy = ChaosProxy::start(upstream, 2).unwrap();
        proxy.push_fault(ConnFault { up: FaultKind::Reset, down: FaultKind::Pass });
        // First connection: reset upstream — nothing comes back.
        let echoed = send_recv(proxy.addr, b"doomed").unwrap_or_default();
        assert!(echoed.is_empty(), "reset connection must echo nothing");
        // Second connection: schedule empty, default (clean) applies.
        let echoed = send_recv(proxy.addr, b"survivor").unwrap();
        assert_eq!(echoed, b"survivor");
        assert_eq!(proxy.metrics().faulted.load(Ordering::Relaxed), 1);
        proxy.shutdown();
    }

    #[test]
    fn corrupt_after_flips_exactly_the_bytes_past_the_offset() {
        let (upstream, _h) = echo_server();
        let mut proxy = ChaosProxy::start(upstream, 3).unwrap();
        proxy.push_fault(ConnFault { up: FaultKind::CorruptAfter(4), down: FaultKind::Pass });
        let payload = b"AAAABBBB";
        let echoed = send_recv(proxy.addr, payload).unwrap();
        assert_eq!(echoed.len(), payload.len(), "corruption never changes length");
        assert_eq!(&echoed[..4], b"AAAA", "bytes before the offset untouched");
        assert_ne!(&echoed[4..], b"BBBB", "bytes past the offset corrupted");
        // Determinism: the same seed yields the same corrupted bytes.
        let mut proxy2 = ChaosProxy::start(upstream, 3).unwrap();
        proxy2.push_fault(ConnFault { up: FaultKind::CorruptAfter(4), down: FaultKind::Pass });
        let echoed2 = send_recv(proxy2.addr, payload).unwrap();
        assert_eq!(echoed, echoed2, "same seed, same damage");
        proxy.shutdown();
        proxy2.shutdown();
    }

    #[test]
    fn truncate_severs_after_budget_and_trickle_preserves_content() {
        let (upstream, _h) = echo_server();
        let mut proxy = ChaosProxy::start(upstream, 4).unwrap();
        proxy.push_fault(ConnFault { up: FaultKind::TruncateAfter(3), down: FaultKind::Pass });
        let echoed = send_recv(proxy.addr, b"123456").unwrap_or_default();
        assert!(echoed.len() <= 3, "at most the truncation budget arrives: {echoed:?}");
        proxy.push_fault(ConnFault { up: FaultKind::Trickle, down: FaultKind::Pass });
        let echoed = send_recv(proxy.addr, b"slowly").unwrap();
        assert_eq!(echoed, b"slowly", "trickle reorders timing, not content");
        proxy.shutdown();
    }

    #[test]
    fn shutdown_is_prompt_even_with_pending_blackhole() {
        let (upstream, _h) = echo_server();
        let mut proxy = ChaosProxy::start(upstream, 5).unwrap();
        proxy.push_fault(ConnFault {
            up: FaultKind::BlackHole(Duration::from_secs(3600)),
            down: FaultKind::BlackHole(Duration::from_secs(3600)),
        });
        let mut s = TcpStream::connect(proxy.addr).unwrap();
        s.write_all(b"into the void").unwrap();
        std::thread::sleep(Duration::from_millis(30)); // let pumps start
        let t0 = Instant::now();
        proxy.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5), "stop must interrupt the black hole");
    }
}
