//! Serving metrics: counters + latency histograms, shared via `Arc`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::request::InferError;
use crate::util::stats::LatencyHistogram;

/// Aggregated coordinator metrics. Cheap atomic counters on the hot path;
/// histograms behind short-lived mutexes.
///
/// Every request is accounted for exactly once in
/// `completed + failed + shed + expired + rejected` — failed work no longer
/// vanishes (see `docs/serving-robustness.md`).
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    /// Submissions refused synchronously (`SubmitError`): queue full under
    /// reject-newest, shut down, or no live workers.
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    /// Requests that got a typed error reply other than shed/expired:
    /// `BackendFailed`, `ShapeMismatch`, `ShuttingDown`, `NoWorkers`.
    pub failed: AtomicU64,
    /// Requests load-shed after admission (drop-oldest victims).
    pub shed: AtomicU64,
    /// Requests expired by their deadline before execution.
    pub expired: AtomicU64,
    /// Worker threads respawned by the supervisor after a crash or init
    /// failure.
    pub worker_restarts: AtomicU64,
    /// Wedged worker slots retired by the in-flight watchdog (a slot whose
    /// batch blew its deadline plus `watchdog_grace` mid-`run_batch`).
    pub watchdog_kills: AtomicU64,
    /// In-flight requests stranded on a wedged slot and replied
    /// `DeadlineExceeded` by the watchdog. Always `<= expired` (the
    /// watchdog records each stranded request in `expired` too).
    pub inflight_expired: AtomicU64,
    /// Backend invocations (bisection retries count individually).
    pub batches: AtomicU64,
    /// Sum of (unpadded) batch sizes — mean batch size = this / batches.
    pub batched_requests: AtomicU64,
    /// Batches released by deadline rather than size.
    pub deadline_flushes: AtomicU64,
    /// Batches a worker formed from a shard other than its home shard.
    pub steals: AtomicU64,
    /// Requests admitted per lane (index 0 = interactive, 1 = bulk).
    pub lane_submitted: [AtomicU64; 2],
    /// Drop-oldest victims shed per lane. Lane-aware shedding victimizes
    /// bulk first, so under mixed overload `lane_shed[1]` grows before
    /// `lane_shed[0]`. Reject-newest refusals land in `rejected`, not here
    /// (the request was never admitted).
    pub lane_shed: [AtomicU64; 2],
    /// Live `(shard, lane, shape)` formation buckets (gauge).
    pub open_buckets: AtomicU64,
    /// High-water mark of `open_buckets`.
    pub peak_buckets: AtomicU64,
    pub queue_hist: Mutex<LatencyHistogram>,
    pub execute_hist: Mutex<LatencyHistogram>,
    pub e2e_hist: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn record_batch(&self, size: usize, execute: Duration, deadline_flush: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        if deadline_flush {
            self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
        }
        self.execute_hist.lock().unwrap().record(execute);
    }

    pub fn record_completion(&self, queue: Duration, e2e: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.queue_hist.lock().unwrap().record(queue);
        self.e2e_hist.lock().unwrap().record(e2e);
    }

    /// Bucket a typed error reply into the matching counter.
    pub fn record_error(&self, err: &InferError) {
        let counter = match err {
            InferError::Shed { .. } => &self.shed,
            InferError::DeadlineExceeded => &self.expired,
            _ => &self.failed,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// A formation bucket came into existence; maintains the gauge and its
    /// high-water mark.
    pub fn bucket_opened(&self) {
        let now = self.open_buckets.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_buckets.fetch_max(now, Ordering::Relaxed);
    }

    /// A formation bucket emptied and was removed.
    pub fn bucket_closed(&self) {
        self.open_buckets.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line summary for logs / reports.
    pub fn summary(&self) -> String {
        let e2e = self.e2e_hist.lock().unwrap();
        let exe = self.execute_hist.lock().unwrap();
        let q = self.queue_hist.lock().unwrap();
        format!(
            "submitted={} completed={} failed={} shed={} expired={} rejected={} \
             restarts={} watchdog_kills={} inflight_expired={} batches={} \
             mean_batch={:.2} deadline_flushes={} \
             steals={} lane_submitted={}/{} lane_shed={}/{} peak_buckets={} | \
             e2e p50={:?} p99={:?} | exec mean={:?} | queue mean={:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.worker_restarts.load(Ordering::Relaxed),
            self.watchdog_kills.load(Ordering::Relaxed),
            self.inflight_expired.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.deadline_flushes.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.lane_submitted[0].load(Ordering::Relaxed),
            self.lane_submitted[1].load(Ordering::Relaxed),
            self.lane_shed[0].load(Ordering::Relaxed),
            self.lane_shed[1].load(Ordering::Relaxed),
            self.peak_buckets.load(Ordering::Relaxed),
            e2e.quantile(0.5),
            e2e.quantile(0.99),
            exe.mean(),
            q.mean(),
        )
    }
}

/// Wire-ingress metrics for the TCP front door (`coordinator/net.rs`),
/// shared between the accept loop, per-connection handlers and the server
/// handle via `Arc`. All counters are monotonic except `active_conns`,
/// which is a gauge mirroring the connection registry.
///
/// Accounting invariants:
/// - every accepted socket increments `total_conns` exactly once; it is
///   then either admitted (tracked in `active_conns` until its handler
///   exits) or refused with a busy reply (`rejected_conns`);
/// - `malformed` counts frames rejected by validation (bad lengths,
///   non-UTF-8 / empty routes, oversized frames) — never well-formed
///   requests that fail inference (those land in the per-route
///   [`Metrics`]);
/// - `timed_out` counts connections dropped by read/write/idle timeouts;
/// - `bytes_in` / `bytes_out` count wire payload actually parsed/written,
///   excluding bytes discarded from rejected frames.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Connections accepted over the server's lifetime.
    pub total_conns: AtomicU64,
    /// Currently admitted connections (gauge).
    pub active_conns: AtomicU64,
    /// Connections refused at accept time (pool full → busy reply + close).
    pub rejected_conns: AtomicU64,
    /// Connections dropped because a read or write hit the I/O timeout.
    pub timed_out: AtomicU64,
    /// Frames rejected by validation before reaching the router.
    pub malformed: AtomicU64,
    /// Transient accept-loop errors survived via backoff (EMFILE etc.).
    pub accept_errors: AtomicU64,
    /// Well-formed inference frames parsed.
    pub frames: AtomicU64,
    /// Request bytes parsed off the wire.
    pub bytes_in: AtomicU64,
    /// Reply bytes written to the wire.
    pub bytes_out: AtomicU64,
}

impl NetMetrics {
    /// One-line summary for logs / the `lqr serve` exit report.
    pub fn summary(&self) -> String {
        format!(
            "net: conns total={} active={} rejected={} timed_out={} | \
             frames={} malformed={} accept_errors={} | bytes in={} out={}",
            self.total_conns.load(Ordering::Relaxed),
            self.active_conns.load(Ordering::Relaxed),
            self.rejected_conns.load(Ordering::Relaxed),
            self.timed_out.load(Ordering::Relaxed),
            self.frames.load(Ordering::Relaxed),
            self.malformed.load(Ordering::Relaxed),
            self.accept_errors.load(Ordering::Relaxed),
            self.bytes_in.load(Ordering::Relaxed),
            self.bytes_out.load(Ordering::Relaxed),
        )
    }
}

/// Client-side resilience counters for [`crate::coordinator::net::ResilientClient`],
/// shared via `Arc` so several clients (or several threads of one test) can
/// aggregate into one ledger for exact reconciliation.
///
/// Accounting invariants:
/// - `client_retries` counts re-attempts only — a call that succeeds first
///   try contributes 0;
/// - `reconnects` counts TCP reconnections after an `Io` failure (the first
///   lazy connect of a call is not a reconnect);
/// - `circuit_opens` counts Closed/HalfOpen → Open transitions;
/// - `circuit_open_rejections` counts calls refused fail-fast with
///   [`crate::coordinator::net::ClientError::CircuitOpen`] (no wire traffic).
#[derive(Debug, Default)]
pub struct ClientMetrics {
    /// Attempts beyond the first, across all calls.
    pub client_retries: AtomicU64,
    /// Connections re-established after an `Io` error.
    pub reconnects: AtomicU64,
    /// Times the circuit breaker tripped open.
    pub circuit_opens: AtomicU64,
    /// Calls refused while the circuit was open (before its cooldown).
    pub circuit_open_rejections: AtomicU64,
}

impl ClientMetrics {
    /// One-line summary for logs / test reports.
    pub fn summary(&self) -> String {
        format!(
            "client: retries={} reconnects={} circuit_opens={} circuit_rejections={}",
            self.client_retries.load(Ordering::Relaxed),
            self.reconnects.load(Ordering::Relaxed),
            self.circuit_opens.load(Ordering::Relaxed),
            self.circuit_open_rejections.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ShedReason;

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.record_batch(4, Duration::from_millis(2), false);
        m.record_batch(8, Duration::from_millis(3), true);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
        assert!(m.summary().contains("mean_batch=6.00"));
    }

    #[test]
    fn error_buckets() {
        let m = Metrics::default();
        m.record_error(&InferError::BackendFailed { message: "x".into() });
        m.record_error(&InferError::ShapeMismatch { expected: vec![1], got: vec![2] });
        m.record_error(&InferError::NoWorkers);
        m.record_error(&InferError::ShuttingDown);
        m.record_error(&InferError::Shed { reason: ShedReason::DropOldest });
        m.record_error(&InferError::DeadlineExceeded);
        assert_eq!(m.failed.load(Ordering::Relaxed), 4);
        assert_eq!(m.shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
        let s = m.summary();
        assert!(s.contains("failed=4") && s.contains("shed=1") && s.contains("expired=1"));
    }

    #[test]
    fn bucket_gauge_tracks_high_water_mark() {
        let m = Metrics::default();
        m.bucket_opened();
        m.bucket_opened();
        m.bucket_opened();
        m.bucket_closed();
        m.bucket_closed();
        assert_eq!(m.open_buckets.load(Ordering::Relaxed), 1);
        assert_eq!(m.peak_buckets.load(Ordering::Relaxed), 3);
        m.steals.fetch_add(2, Ordering::Relaxed);
        m.lane_submitted[0].fetch_add(5, Ordering::Relaxed);
        m.lane_shed[1].fetch_add(4, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("steals=2"), "{s}");
        assert!(s.contains("lane_submitted=5/0"), "{s}");
        assert!(s.contains("lane_shed=0/4"), "{s}");
        assert!(s.contains("peak_buckets=3"), "{s}");
    }

    #[test]
    fn watchdog_counters_reported_in_summary() {
        let m = Metrics::default();
        m.watchdog_kills.fetch_add(2, Ordering::Relaxed);
        m.inflight_expired.fetch_add(7, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("watchdog_kills=2"), "{s}");
        assert!(s.contains("inflight_expired=7"), "{s}");
    }

    #[test]
    fn client_metrics_summary_reports_every_counter() {
        let c = ClientMetrics::default();
        c.client_retries.store(9, Ordering::Relaxed);
        c.reconnects.store(4, Ordering::Relaxed);
        c.circuit_opens.store(2, Ordering::Relaxed);
        c.circuit_open_rejections.store(6, Ordering::Relaxed);
        let s = c.summary();
        for needle in ["retries=9", "reconnects=4", "circuit_opens=2", "circuit_rejections=6"] {
            assert!(s.contains(needle), "summary missing {needle}: {s}");
        }
    }

    #[test]
    fn net_metrics_summary_reports_every_counter() {
        let n = NetMetrics::default();
        n.total_conns.store(7, Ordering::Relaxed);
        n.active_conns.store(2, Ordering::Relaxed);
        n.rejected_conns.store(3, Ordering::Relaxed);
        n.timed_out.store(1, Ordering::Relaxed);
        n.malformed.store(4, Ordering::Relaxed);
        n.accept_errors.store(5, Ordering::Relaxed);
        n.frames.store(11, Ordering::Relaxed);
        n.bytes_in.store(123, Ordering::Relaxed);
        n.bytes_out.store(456, Ordering::Relaxed);
        let s = n.summary();
        for needle in [
            "total=7",
            "active=2",
            "rejected=3",
            "timed_out=1",
            "frames=11",
            "malformed=4",
            "accept_errors=5",
            "in=123",
            "out=456",
        ] {
            assert!(s.contains(needle), "summary missing {needle}: {s}");
        }
    }
}
