//! Worker threads + supervisor: drain batches from the queue into a
//! backend, survive backend failures, and guarantee every request resolves.
//!
//! Three layers of fault tolerance (state machine in
//! `docs/serving-robustness.md`):
//!
//! - **Batch level** ([`run_batch`]): per-request shape validation (a real
//!   check, not a `debug_assert`), panic capture around
//!   `Backend::run_batch` so co-batched requests get typed replies instead
//!   of dropped senders, and poison isolation — a failed multi-request
//!   batch is bisected and retried per-half under a bounded invocation
//!   budget, so one bad request costs one `BackendFailed` reply while its
//!   neighbors complete.
//! - **Worker level**: a worker whose backend panicked exits (backend state
//!   is unknown) after failing its in-flight batch; init failures are
//!   reported, never silently swallowed.
//! - **Pool level** ([`supervise`]): a supervisor thread restarts crashed
//!   or init-failed workers with capped exponential backoff, and when every
//!   slot has exhausted its restart budget it fails the queue —
//!   submissions refuse with `NoWorkers` and queued requests get error
//!   replies instead of hanging forever.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::{BatchQueue, FlushReason};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferError, InferRequest, InferResponse, Priority};
use crate::tensor::Tensor;

/// Supervision parameters (plumbed from `CoordinatorConfig`).
#[derive(Debug, Clone, Copy)]
pub struct SupervisorConfig {
    /// Worker slots (each runs one backend).
    pub workers: usize,
    /// Consecutive failed respawns per slot before the slot is abandoned;
    /// a successful backend init resets the count. 0 = never restart.
    pub restart_limit: u32,
    /// Base backoff before the first restart; doubles per consecutive
    /// failure, capped at 1s.
    pub restart_backoff: Duration,
    /// Max backend invocations per popped batch (first attempt + bisection
    /// retries). Full bisection of a batch of n costs at most 2n-1.
    pub retry_budget: u32,
}

/// How a worker thread ended.
enum WorkerExit {
    /// The backend factory returned an error; no batches were taken.
    InitFailed(String),
    /// The backend panicked (its state is unknown) or the worker itself
    /// panicked; in-flight requests already got typed error replies.
    Crashed(String),
    /// The queue shut down (or failed) and drained; clean exit.
    Drained,
}

enum WorkerEvent {
    /// Backend built successfully; the worker is serving.
    Ready(usize),
    Exited(usize, WorkerExit),
}

/// Spawn `cfg.workers` supervised worker slots plus the supervisor thread.
///
/// Returns the supervisor's join handle and a one-shot readiness channel:
/// it yields `true` as soon as any worker's backend initializes, or `false`
/// once every slot died without a single successful init (the caller
/// should then treat construction as failed — the supervisor has already
/// failed the queue and is exiting).
pub fn supervise(
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    cfg: SupervisorConfig,
) -> (thread::JoinHandle<()>, mpsc::Receiver<bool>) {
    let (ready_tx, ready_rx) = mpsc::channel();
    let handle = thread::Builder::new()
        .name("lqr-supervisor".into())
        .spawn(move || supervisor_loop(queue, metrics, factory, cfg, ready_tx))
        .expect("spawn supervisor");
    (handle, ready_rx)
}

fn supervisor_loop(
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    cfg: SupervisorConfig,
    ready_tx: mpsc::Sender<bool>,
) {
    let n = cfg.workers;
    let (ev_tx, ev_rx) = mpsc::channel::<WorkerEvent>();
    let mut handles: Vec<Option<thread::JoinHandle<()>>> = Vec::with_capacity(n);
    for slot in 0..n {
        handles.push(Some(spawn_worker(
            slot,
            Arc::clone(&queue),
            Arc::clone(&metrics),
            Arc::clone(&factory),
            cfg.retry_budget,
            ev_tx.clone(),
        )));
    }
    // Per-slot state: consecutive respawn failures, and whether the slot is
    // permanently dead or exited cleanly.
    let mut failures = vec![0u32; n];
    let mut dead = vec![false; n];
    let mut drained = vec![false; n];
    let mut ever_ready = false;
    let mut init_reported = false;

    loop {
        if (0..n).all(|s| dead[s] || drained[s]) {
            break;
        }
        // The supervisor holds an ev_tx clone, so recv() only errors on a
        // logic bug; treat it as a signal to stop rather than panic.
        let Ok(ev) = ev_rx.recv() else { break };
        match ev {
            WorkerEvent::Ready(slot) => {
                failures[slot] = 0;
                if !ever_ready {
                    ever_ready = true;
                    if !init_reported {
                        init_reported = true;
                        let _ = ready_tx.send(true);
                    }
                }
            }
            WorkerEvent::Exited(slot, WorkerExit::Drained) => {
                drained[slot] = true;
                if let Some(h) = handles[slot].take() {
                    let _ = h.join();
                }
            }
            WorkerEvent::Exited(slot, exit) => {
                let why = match &exit {
                    WorkerExit::InitFailed(e) => format!("backend init failed: {e}"),
                    WorkerExit::Crashed(e) => format!("crashed: {e}"),
                    WorkerExit::Drained => unreachable!(),
                };
                if let Some(h) = handles[slot].take() {
                    let _ = h.join();
                }
                if queue.is_shutdown() || queue.is_failed() {
                    log::warn!("worker {slot} {why}; not restarting (tearing down)");
                    dead[slot] = true;
                } else {
                    failures[slot] += 1;
                    if failures[slot] > cfg.restart_limit {
                        log::error!(
                            "worker {slot} {why}; restart budget ({}) exhausted — slot abandoned",
                            cfg.restart_limit
                        );
                        dead[slot] = true;
                    } else {
                        let backoff = cfg
                            .restart_backoff
                            .saturating_mul(1u32 << (failures[slot] - 1).min(10))
                            .min(Duration::from_secs(1));
                        log::warn!(
                            "worker {slot} {why}; restart {}/{} in {backoff:?}",
                            failures[slot],
                            cfg.restart_limit
                        );
                        thread::sleep(backoff);
                        if queue.is_shutdown() || queue.is_failed() {
                            dead[slot] = true;
                        } else {
                            metrics
                                .worker_restarts
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            handles[slot] = Some(spawn_worker(
                                slot,
                                Arc::clone(&queue),
                                Arc::clone(&metrics),
                                Arc::clone(&factory),
                                cfg.retry_budget,
                                ev_tx.clone(),
                            ));
                        }
                    }
                }
            }
        }
        // All slots dead without a single successful init: report failed
        // construction to a waiting `Coordinator::start`.
        if !init_reported && (0..n).all(|s| dead[s]) {
            init_reported = true;
            let _ = ready_tx.send(false);
        }
    }
    // Pool died (no slot exited via a clean drain) outside of shutdown:
    // flip the fail-fast state so nothing ever hangs on this queue.
    if (0..n).all(|s| dead[s]) && !queue.is_shutdown() {
        log::error!("all {n} worker slots dead — failing the queue (NoWorkers)");
        queue.fail();
    }
    if !init_reported {
        let _ = ready_tx.send(ever_ready);
    }
    for h in handles.iter_mut().filter_map(|h| h.take()) {
        let _ = h.join();
    }
}

fn spawn_worker(
    slot: usize,
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    retry_budget: u32,
    events: mpsc::Sender<WorkerEvent>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("lqr-worker-{slot}"))
        .spawn(move || {
            let ev2 = events.clone();
            // Backstop: a panic anywhere in the worker loop (not just inside
            // the backend call) still reports Crashed instead of vanishing.
            let exit = catch_unwind(AssertUnwindSafe(|| {
                worker_main(slot, &queue, &metrics, &factory, retry_budget, &ev2)
            }))
            .unwrap_or_else(|p| WorkerExit::Crashed(panic_message(&p)));
            let _ = events.send(WorkerEvent::Exited(slot, exit));
        })
        .expect("spawn worker")
}

fn worker_main(
    slot: usize,
    queue: &BatchQueue,
    metrics: &Metrics,
    factory: &BackendFactory,
    retry_budget: u32,
    events: &mpsc::Sender<WorkerEvent>,
) -> WorkerExit {
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => return WorkerExit::InitFailed(format!("{e:#}")),
    };
    let _ = events.send(WorkerEvent::Ready(slot));
    log::info!("worker {slot}: {}", backend.describe());
    // The slot index doubles as the worker's home-shard identity: slot i
    // drains shard `i % shards` first and steals from siblings after.
    while let Some((batch, reason)) = queue.pop_batch_from(slot) {
        if let BatchOutcome::WorkerPoisoned(msg) =
            run_batch(&mut *backend, batch, reason, metrics, retry_budget)
        {
            return WorkerExit::Crashed(format!("backend panicked: {msg}"));
        }
    }
    log::debug!("worker {slot}: queue drained, exiting");
    WorkerExit::Drained
}

/// Result of [`run_batch`]: whether the worker may keep its backend.
#[derive(Debug)]
pub(crate) enum BatchOutcome {
    /// All requests replied; backend state is trustworthy.
    Completed,
    /// The backend panicked — every request got a typed reply, but the
    /// backend's internal state is unknown and the worker must be replaced.
    WorkerPoisoned(String),
}

/// Execute one popped batch, replying exactly once to every request.
///
/// Mismatched image shapes are rejected per-request with
/// [`InferError::ShapeMismatch`] (the batch's expected shape is the first
/// request's — one route serves one geometry). Backend errors trigger
/// bisection: the failing sub-batch is split and each half retried, bounded
/// by `retry_budget` total invocations, isolating a poison request to a
/// single `BackendFailed` reply. Backend panics are caught; the current
/// sub-batch and all not-yet-run splits get `BackendFailed` replies and the
/// caller is told to retire the worker.
pub(crate) fn run_batch(
    backend: &mut dyn crate::coordinator::backend::Backend,
    batch: Vec<InferRequest>,
    reason: FlushReason,
    metrics: &Metrics,
    retry_budget: u32,
) -> BatchOutcome {
    debug_assert!(!batch.is_empty());
    let formed_at = Instant::now();
    // Release-mode shape screen: one route = one input geometry. The first
    // request defines the batch shape; stragglers get typed errors instead
    // of silently corrupting the assembled tensor.
    let expected = batch[0].image.shape().to_vec();
    let mut good = Vec::with_capacity(batch.len());
    for r in batch {
        if r.image.shape() != &expected[..] {
            let got = r.image.shape().to_vec();
            log::warn!("request {}: shape {got:?} != batch shape {expected:?}", r.id);
            r.respond_err(
                InferError::ShapeMismatch { expected: expected.clone(), got },
                metrics,
            );
        } else {
            good.push(r);
        }
    }
    if good.is_empty() {
        return BatchOutcome::Completed;
    }

    // Bisection worklist (LIFO so the left half runs first, preserving
    // rough FIFO reply order).
    let mut budget = retry_budget.max(1);
    let mut first = true;
    let mut pending: Vec<Vec<InferRequest>> = vec![good];
    while let Some(mut reqs) = pending.pop() {
        if budget == 0 {
            for r in reqs {
                r.respond_err(
                    InferError::BackendFailed {
                        message: "retry budget exhausted during bisection".into(),
                    },
                    metrics,
                );
            }
            continue;
        }
        budget -= 1;
        let n = reqs.len();
        let input = assemble(&reqs, &expected);
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| backend.run_batch(&input)));
        let exec = t0.elapsed();
        metrics.record_batch(n, exec, first && reason == FlushReason::Deadline);
        first = false;
        match result {
            Ok(Ok(logits)) => {
                if logits.shape().len() != 2 || logits.dim(0) != n {
                    let message = format!(
                        "backend returned logits shape {:?} for a batch of {n}",
                        logits.shape()
                    );
                    log::error!("{message}");
                    for r in reqs {
                        r.respond_err(
                            InferError::BackendFailed { message: message.clone() },
                            metrics,
                        );
                    }
                    continue;
                }
                let classes = logits.dim(1);
                for (i, req) in reqs.into_iter().enumerate() {
                    let queue_time = formed_at.duration_since(req.submitted_at);
                    let resp = InferResponse::from_logits(
                        req.id,
                        logits.data()[i * classes..(i + 1) * classes].to_vec(),
                        queue_time,
                        exec,
                        n,
                    );
                    metrics.record_completion(queue_time, req.submitted_at.elapsed());
                    req.respond_ok(resp);
                }
            }
            Ok(Err(e)) if n > 1 => {
                // Poison isolation: split and retry each half independently.
                log::warn!("batch of {n} failed ({e:#}); bisecting");
                let right = reqs.split_off(n / 2);
                pending.push(right);
                pending.push(reqs);
            }
            Ok(Err(e)) => {
                log::error!("request {} failed: {e:#}", reqs[0].id);
                for r in reqs {
                    r.respond_err(
                        InferError::BackendFailed { message: format!("{e:#}") },
                        metrics,
                    );
                }
            }
            Err(p) => {
                let msg = panic_message(&p);
                log::error!("backend panicked on a batch of {n}: {msg}");
                let err = InferError::BackendFailed {
                    message: format!("backend panicked: {msg}"),
                };
                for r in reqs.into_iter().chain(pending.into_iter().flatten()) {
                    r.respond_err(err.clone(), metrics);
                }
                return BatchOutcome::WorkerPoisoned(msg);
            }
        }
    }
    BatchOutcome::Completed
}

/// Assemble `(n, C, H, W)` from per-request `(1, C, H, W)` images (all
/// pre-validated against `shape`).
fn assemble(reqs: &[InferRequest], shape: &[usize]) -> Tensor {
    let per: usize = shape.iter().product();
    let mut data = Vec::with_capacity(reqs.len() * per);
    for r in reqs {
        data.extend_from_slice(r.image.data());
    }
    let mut dims = vec![reqs.len()];
    dims.extend_from_slice(&shape[1..]);
    Tensor::new(&dims, data)
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

/// Convenience used by tests and single-shot tools: run one request through
/// a backend synchronously.
pub fn run_one(
    backend: &mut dyn crate::coordinator::backend::Backend,
    image: Tensor,
) -> anyhow::Result<InferResponse> {
    let (tx, rx) = mpsc::channel();
    let req = InferRequest {
        id: 0,
        image,
        submitted_at: Instant::now(),
        deadline: None,
        priority: Priority::default(),
        reply: tx,
        recycle: None,
    };
    let _ = run_batch(backend, vec![req], FlushReason::Full, &Metrics::default(), 1);
    match rx.recv() {
        Ok(Ok(resp)) => Ok(resp),
        Ok(Err(e)) => Err(e.into()),
        Err(_) => Err(anyhow::anyhow!("no reply (worker bug)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use std::sync::atomic::{AtomicU64, Ordering};

    fn mock() -> MockBackend {
        MockBackend {
            classes: 3,
            delay: Duration::ZERO,
            calls: Arc::new(AtomicU64::new(0)),
        }
    }

    fn req(id: u64, v: f32) -> (InferRequest, mpsc::Receiver<crate::coordinator::request::InferReply>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest {
                id,
                image: Tensor::filled(&[1, 1, 2, 2], v),
                submitted_at: Instant::now(),
                deadline: None,
                priority: Priority::default(),
                reply: tx,
                recycle: None,
            },
            rx,
        )
    }

    #[test]
    fn run_one_mock() {
        let mut b = mock();
        let img = Tensor::new(&[1, 1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let resp = run_one(&mut b, img).unwrap();
        assert_eq!(resp.logits, vec![4.0, 0.0, 0.0]);
        assert_eq!(resp.predicted, 0);
        assert_eq!(resp.batch_size, 1);
    }

    /// Backend that errors whenever the batch contains a poison row (sum
    /// over the magic value threshold).
    struct PoisonSensitive {
        inner: MockBackend,
    }

    impl Backend for PoisonSensitive {
        fn run_batch(&mut self, batch: &Tensor) -> anyhow::Result<Tensor> {
            let n = batch.dim(0);
            let per = batch.len() / n;
            for i in 0..n {
                let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
                if s >= 1000.0 {
                    anyhow::bail!("poison row {i}");
                }
            }
            self.inner.run_batch(batch)
        }

        fn describe(&self) -> String {
            "poison-sensitive".into()
        }
    }

    #[test]
    fn bisection_isolates_poison_request() {
        let mut b = PoisonSensitive { inner: mock() };
        let metrics = Metrics::default();
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            // Request 5 is poison: each of its 4 pixels is 500 (sum 2000).
            let v = if i == 5 { 500.0 } else { i as f32 };
            let (r, rx) = req(i, v);
            reqs.push(r);
            rxs.push(rx);
        }
        let out = run_batch(&mut b, reqs, FlushReason::Full, &metrics, 2 * 8);
        assert!(matches!(out, BatchOutcome::Completed));
        for (i, rx) in rxs.into_iter().enumerate() {
            let reply = rx.try_recv().expect("every request replied");
            if i == 5 {
                assert!(matches!(reply, Err(InferError::BackendFailed { .. })));
            } else {
                let resp = reply.expect("neighbor of poison must succeed");
                assert_eq!(resp.logits[0], 4.0 * i as f32);
            }
        }
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 7);
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retry_budget_bounds_bisection() {
        struct AlwaysFails;
        impl Backend for AlwaysFails {
            fn run_batch(&mut self, _b: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("nope")
            }
            fn describe(&self) -> String {
                "always-fails".into()
            }
        }
        let metrics = Metrics::default();
        let (reqs, rxs): (Vec<_>, Vec<_>) = (0..8u64).map(|i| req(i, 1.0)).unzip();
        let out = run_batch(&mut AlwaysFails, reqs, FlushReason::Full, &metrics, 3);
        assert!(matches!(out, BatchOutcome::Completed));
        // Only 3 invocations allowed; every request still resolves.
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 3);
        for rx in rxs {
            assert!(matches!(rx.try_recv().unwrap(), Err(InferError::BackendFailed { .. })));
        }
    }

    #[test]
    fn shape_mismatch_rejected_not_corrupted() {
        let mut b = mock();
        let metrics = Metrics::default();
        let (r0, rx0) = req(0, 1.0);
        let (tx, rx1) = mpsc::channel();
        let odd = InferRequest {
            id: 1,
            image: Tensor::filled(&[1, 1, 3, 3], 1.0),
            submitted_at: Instant::now(),
            deadline: None,
            priority: Priority::default(),
            reply: tx,
            recycle: None,
        };
        let out = run_batch(&mut b, vec![r0, odd], FlushReason::Full, &metrics, 4);
        assert!(matches!(out, BatchOutcome::Completed));
        assert!(rx0.try_recv().unwrap().is_ok());
        match rx1.try_recv().unwrap() {
            Err(InferError::ShapeMismatch { expected, got }) => {
                assert_eq!(expected, vec![1, 1, 2, 2]);
                assert_eq!(got, vec![1, 1, 3, 3]);
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn backend_panic_yields_typed_replies_and_poisons_worker() {
        struct Panics;
        impl Backend for Panics {
            fn run_batch(&mut self, _b: &Tensor) -> anyhow::Result<Tensor> {
                panic!("kaboom")
            }
            fn describe(&self) -> String {
                "panics".into()
            }
        }
        let metrics = Metrics::default();
        let (reqs, rxs): (Vec<_>, Vec<_>) = (0..4u64).map(|i| req(i, 1.0)).unzip();
        let out = run_batch(&mut Panics, reqs, FlushReason::Full, &metrics, 8);
        match out {
            BatchOutcome::WorkerPoisoned(msg) => assert!(msg.contains("kaboom")),
            other => panic!("expected WorkerPoisoned, got {other:?}"),
        }
        for rx in rxs {
            match rx.try_recv().unwrap() {
                Err(InferError::BackendFailed { message }) => {
                    assert!(message.contains("panicked"), "{message}");
                }
                other => panic!("expected BackendFailed, got {other:?}"),
            }
        }
    }
}
