//! Worker threads: drain batches from the queue into a backend.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::{BatchQueue, FlushReason};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, InferResponse};
use crate::tensor::Tensor;

/// Spawn `n` workers; each builds its own backend (PJRT sessions are not
/// Send) and loops `pop_batch -> run -> reply` until the queue shuts down
/// and drains. Returns the join handles.
pub fn spawn_workers(
    n: usize,
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
) -> Vec<thread::JoinHandle<()>> {
    (0..n)
        .map(|wid| {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let factory = Arc::clone(&factory);
            thread::Builder::new()
                .name(format!("lqr-worker-{wid}"))
                .spawn(move || {
                    let mut backend = match factory() {
                        Ok(b) => b,
                        Err(e) => {
                            log::error!("worker {wid}: backend init failed: {e:#}");
                            return;
                        }
                    };
                    log::info!("worker {wid}: {}", backend.describe());
                    while let Some((batch, reason)) = queue.pop_batch() {
                        run_batch(&mut *backend, batch, reason, &metrics);
                    }
                    log::debug!("worker {wid}: queue drained, exiting");
                })
                .expect("spawn worker")
        })
        .collect()
}

/// Assemble the image rows, execute, and reply to every request.
fn run_batch(
    backend: &mut dyn crate::coordinator::backend::Backend,
    batch: Vec<InferRequest>,
    reason: FlushReason,
    metrics: &Metrics,
) {
    let n = batch.len();
    debug_assert!(n > 0);
    let formed_at = Instant::now();
    // Assemble (n, C, H, W) from the per-request (1, C, H, W) images.
    let shape = batch[0].image.shape().to_vec();
    let per: usize = shape.iter().product();
    let mut data = Vec::with_capacity(n * per);
    for r in &batch {
        debug_assert_eq!(r.image.shape(), &shape[..], "mixed image shapes in batch");
        data.extend_from_slice(r.image.data());
    }
    let mut dims = vec![n];
    dims.extend_from_slice(&shape[1..]);
    let input = Tensor::new(&dims, data);

    let t0 = Instant::now();
    let result = backend.run_batch(&input);
    let exec = t0.elapsed();
    metrics.record_batch(n, exec, reason == FlushReason::Deadline);

    match result {
        Ok(logits) => {
            let classes = logits.dim(1);
            for (i, req) in batch.into_iter().enumerate() {
                let queue_time = formed_at.duration_since(req.submitted_at);
                let resp = InferResponse::from_logits(
                    req.id,
                    logits.data()[i * classes..(i + 1) * classes].to_vec(),
                    queue_time,
                    exec,
                    n,
                );
                metrics.record_completion(queue_time, req.submitted_at.elapsed());
                // Receiver may have given up; dropping the response is fine.
                let _ = req.reply.send(resp);
            }
        }
        Err(e) => {
            log::error!("batch of {n} failed: {e:#}");
            // Drop the reply senders: receivers observe a disconnect error.
            drop(batch);
        }
    }
}

/// Convenience used by tests and single-shot tools: run one request through
/// a backend synchronously.
pub fn run_one(
    backend: &mut dyn crate::coordinator::backend::Backend,
    image: Tensor,
) -> anyhow::Result<InferResponse> {
    let (tx, rx) = mpsc::channel();
    let req = InferRequest { id: 0, image, submitted_at: Instant::now(), reply: tx };
    run_batch(backend, vec![req], FlushReason::Full, &Metrics::default());
    rx.recv().map_err(|_| anyhow::anyhow!("backend failed"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_one_mock() {
        let mut b = MockBackend {
            classes: 3,
            delay: std::time::Duration::ZERO,
            calls: Arc::new(AtomicU64::new(0)),
        };
        let img = Tensor::new(&[1, 1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let resp = run_one(&mut b, img).unwrap();
        assert_eq!(resp.logits, vec![4.0, 0.0, 0.0]);
        assert_eq!(resp.predicted, 0);
        assert_eq!(resp.batch_size, 1);
    }
}
