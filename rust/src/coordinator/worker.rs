//! Worker threads + supervisor: drain batches from the queue into a
//! backend, survive backend failures, and guarantee every request resolves.
//!
//! Three layers of fault tolerance (state machine in
//! `docs/serving-robustness.md`):
//!
//! - **Batch level** ([`run_batch`]): per-request shape validation (a real
//!   check, not a `debug_assert`), panic capture around
//!   `Backend::run_batch` so co-batched requests get typed replies instead
//!   of dropped senders, and poison isolation — a failed multi-request
//!   batch is bisected and retried per-half under a bounded invocation
//!   budget, so one bad request costs one `BackendFailed` reply while its
//!   neighbors complete.
//! - **Worker level**: a worker whose backend panicked exits (backend state
//!   is unknown) after failing its in-flight batch; init failures are
//!   reported, never silently swallowed.
//! - **Pool level** ([`supervise`]): a supervisor thread restarts crashed
//!   or init-failed workers with capped exponential backoff, and when every
//!   slot has exhausted its restart budget it fails the queue —
//!   submissions refuse with `NoWorkers` and queued requests get error
//!   replies instead of hanging forever.
//! - **In-flight watchdog** (optional, `SupervisorConfig::watchdog_grace`):
//!   workers stamp a shared per-slot slab when they take a batch (busy
//!   since, batch deadline, per-request reply senders). A supervisor-side
//!   sweep detects a slot still busy past its batch deadline plus the
//!   grace, replies `DeadlineExceeded` to the stranded requests through
//!   the cloned senders, detaches the wedged thread (it can never be
//!   killed, only abandoned), and respawns the slot through the normal
//!   capped-backoff path. An epoch'd claim protocol makes double replies
//!   structurally impossible: the right to reply to a request transfers
//!   atomically between worker and watchdog.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::{BatchQueue, FlushReason};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferError, InferRequest, InferResponse, Priority};
use crate::tensor::Tensor;

/// Supervision parameters (plumbed from `CoordinatorConfig`).
#[derive(Debug, Clone, Copy)]
pub struct SupervisorConfig {
    /// Worker slots (each runs one backend).
    pub workers: usize,
    /// Consecutive failed respawns per slot before the slot is abandoned;
    /// a successful backend init resets the count. 0 = never restart.
    pub restart_limit: u32,
    /// Base backoff before the first restart; doubles per consecutive
    /// failure, capped at 1s.
    pub restart_backoff: Duration,
    /// Max backend invocations per popped batch (first attempt + bisection
    /// retries). Full bisection of a batch of n costs at most 2n-1.
    pub retry_budget: u32,
    /// In-flight watchdog: a slot still executing a batch past the batch's
    /// deadline plus this grace is declared wedged — its stranded requests
    /// get `DeadlineExceeded` replies and the slot is respawned. `None`
    /// disables the watchdog (batches may run unboundedly long). Batches
    /// whose requests carry no deadline are never watchdog-killed.
    pub watchdog_grace: Option<Duration>,
}

/// How a worker thread ended.
enum WorkerExit {
    /// The backend factory returned an error; no batches were taken.
    InitFailed(String),
    /// The backend panicked (its state is unknown) or the worker itself
    /// panicked; in-flight requests already got typed error replies.
    Crashed(String),
    /// The queue shut down (or failed) and drained; clean exit.
    Drained,
}

enum WorkerEvent {
    /// Backend built successfully; the worker is serving. Carries the
    /// slot's incarnation so events from a detached (wedged) predecessor
    /// are recognized as stale and ignored.
    Ready(usize, u64),
    Exited(usize, u64, WorkerExit),
}

/// Shared in-flight bookkeeping: one slot per worker, stamped when a batch
/// is taken and cleared when `run_batch` returns. The supervisor's watchdog
/// sweep reads it to find wedged slots.
pub(crate) struct InflightSlab {
    pub(crate) slots: Vec<InflightSlot>,
}

impl InflightSlab {
    fn new(n: usize) -> InflightSlab {
        InflightSlab { slots: (0..n).map(|_| InflightSlot::default()).collect() }
    }
}

/// Per-slot in-flight state behind one short-lived mutex.
#[derive(Default)]
pub(crate) struct InflightSlot {
    state: std::sync::Mutex<SlotState>,
}

#[derive(Default)]
struct SlotState {
    /// Bumped on every stamp *and* on every watchdog kill. A worker holding
    /// a stale epoch has lost the right to reply: its claims fail and it
    /// must abandon the batch.
    epoch: u64,
    busy_since: Option<Instant>,
    /// Earliest deadline across the stamped batch; `None` when no request
    /// carries one (such a batch is never watchdog-killed).
    deadline: Option<Instant>,
    /// `(request id, reply sender clone)` for every not-yet-replied request
    /// of the stamped batch. Claiming removes the entry; a watchdog kill
    /// drains whatever is left.
    pending: Vec<(u64, mpsc::Sender<crate::coordinator::request::InferReply>)>,
}

impl InflightSlot {
    /// Stamp a freshly popped batch; returns the epoch the worker must
    /// present with every claim.
    fn stamp(&self, batch: &[InferRequest]) -> u64 {
        let mut s = self.state.lock().unwrap();
        s.epoch += 1;
        s.busy_since = Some(Instant::now());
        s.deadline = batch.iter().filter_map(|r| r.deadline).min();
        s.pending = batch.iter().map(|r| (r.id, r.reply.clone())).collect();
        s.epoch
    }

    /// Acquire the right to reply to `id`. Fails when the watchdog has
    /// already killed this epoch (the watchdog replied; the worker must
    /// stay silent) — the reply right moves atomically, never duplicates.
    pub(crate) fn claim(&self, epoch: u64, id: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.epoch != epoch {
            return false;
        }
        match s.pending.iter().position(|(pid, _)| *pid == id) {
            Some(i) => {
                s.pending.swap_remove(i);
                true
            }
            // Unreachable if callers claim each id once; refusing to reply
            // is the safe failure mode (the other side must hold the right).
            None => false,
        }
    }

    /// Clear the stamp after `run_batch` returns; no-op if the watchdog
    /// already confiscated this epoch.
    fn finish(&self, epoch: u64) {
        let mut s = self.state.lock().unwrap();
        if s.epoch == epoch {
            s.busy_since = None;
            s.deadline = None;
            s.pending.clear();
        }
    }

    /// Watchdog check: if the slot is busy past its batch deadline plus
    /// `grace`, bump the epoch (confiscating the worker's reply rights) and
    /// return the stranded `(id, sender)` pairs. `None` = slot healthy.
    fn check_wedged(
        &self,
        now: Instant,
        grace: Duration,
    ) -> Option<Vec<(u64, mpsc::Sender<crate::coordinator::request::InferReply>)>> {
        let mut s = self.state.lock().unwrap();
        if s.busy_since.is_none() {
            return None;
        }
        let deadline = s.deadline?;
        if now < deadline + grace {
            return None;
        }
        s.epoch += 1;
        s.busy_since = None;
        s.deadline = None;
        Some(std::mem::take(&mut s.pending))
    }
}

/// Spawn `cfg.workers` supervised worker slots plus the supervisor thread.
///
/// Returns the supervisor's join handle and a one-shot readiness channel:
/// it yields `true` as soon as any worker's backend initializes, or `false`
/// once every slot died without a single successful init (the caller
/// should then treat construction as failed — the supervisor has already
/// failed the queue and is exiting).
pub fn supervise(
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    cfg: SupervisorConfig,
) -> (thread::JoinHandle<()>, mpsc::Receiver<bool>) {
    let (ready_tx, ready_rx) = mpsc::channel();
    let handle = thread::Builder::new()
        .name("lqr-supervisor".into())
        .spawn(move || supervisor_loop(queue, metrics, factory, cfg, ready_tx))
        .expect("spawn supervisor");
    (handle, ready_rx)
}

/// Sleep up to `total` in short slices, returning early (false) as soon as
/// the queue shuts down or fails — restart backoff must never delay
/// teardown by the full backoff.
fn wait_interruptible(queue: &BatchQueue, total: Duration) -> bool {
    const SLICE: Duration = Duration::from_millis(5);
    let until = Instant::now() + total;
    loop {
        if queue.is_shutdown() || queue.is_failed() {
            return false;
        }
        let now = Instant::now();
        if now >= until {
            return true;
        }
        thread::sleep((until - now).min(SLICE));
    }
}

/// Everything the supervisor mutates per slot, grouped so the crash path
/// and the watchdog kill path can share the failure/backoff/respawn logic.
struct SlotTable {
    handles: Vec<Option<thread::JoinHandle<()>>>,
    /// Consecutive failed respawns per slot (reset by a successful init).
    failures: Vec<u32>,
    dead: Vec<bool>,
    drained: Vec<bool>,
    /// Bumped on every (re)spawn; events carrying an older incarnation come
    /// from a detached predecessor and are ignored.
    incarnation: Vec<u64>,
}

fn supervisor_loop(
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    cfg: SupervisorConfig,
    ready_tx: mpsc::Sender<bool>,
) {
    let n = cfg.workers;
    let (ev_tx, ev_rx) = mpsc::channel::<WorkerEvent>();
    let slab = cfg.watchdog_grace.map(|_| Arc::new(InflightSlab::new(n)));
    let mut slots = SlotTable {
        handles: Vec::with_capacity(n),
        failures: vec![0u32; n],
        dead: vec![false; n],
        drained: vec![false; n],
        incarnation: vec![0u64; n],
    };
    for slot in 0..n {
        slots.handles.push(Some(spawn_worker(
            slot,
            0,
            Arc::clone(&queue),
            Arc::clone(&metrics),
            Arc::clone(&factory),
            cfg.retry_budget,
            slab.clone(),
            ev_tx.clone(),
        )));
    }
    let mut ever_ready = false;
    let mut init_reported = false;

    loop {
        if (0..n).all(|s| slots.dead[s] || slots.drained[s]) {
            break;
        }
        // The supervisor holds an ev_tx clone, so recv() only errors on a
        // logic bug; treat it as a signal to stop rather than panic. With
        // the watchdog on, wait with a timeout and sweep between events.
        let ev = match cfg.watchdog_grace {
            None => match ev_rx.recv() {
                Ok(ev) => ev,
                Err(_) => break,
            },
            Some(grace) => {
                let tick = (grace / 4)
                    .clamp(Duration::from_millis(1), Duration::from_millis(100));
                match ev_rx.recv_timeout(tick) {
                    Ok(ev) => ev,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        watchdog_sweep(
                            grace, &queue, &metrics, &factory, &cfg, &ev_tx,
                            slab.as_ref().unwrap(), &mut slots,
                        );
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        match ev {
            WorkerEvent::Ready(slot, inc) => {
                if inc != slots.incarnation[slot] {
                    continue; // stale: a detached predecessor came up late
                }
                slots.failures[slot] = 0;
                if !ever_ready {
                    ever_ready = true;
                    if !init_reported {
                        init_reported = true;
                        let _ = ready_tx.send(true);
                    }
                }
            }
            WorkerEvent::Exited(slot, inc, exit) => {
                if inc != slots.incarnation[slot] {
                    // A wedged worker we already replaced finally returned;
                    // its handle was detached and its requests were replied
                    // by the watchdog. Nothing to do.
                    log::debug!("worker {slot} (stale incarnation {inc}) exited late");
                    continue;
                }
                if matches!(exit, WorkerExit::Drained) {
                    slots.drained[slot] = true;
                    if let Some(h) = slots.handles[slot].take() {
                        let _ = h.join();
                    }
                } else {
                    let why = match &exit {
                        WorkerExit::InitFailed(e) => format!("backend init failed: {e}"),
                        WorkerExit::Crashed(e) => format!("crashed: {e}"),
                        WorkerExit::Drained => unreachable!(),
                    };
                    if let Some(h) = slots.handles[slot].take() {
                        let _ = h.join();
                    }
                    restart_slot(
                        slot, &why, &queue, &metrics, &factory, &cfg, &ev_tx,
                        slab.as_ref(), &mut slots,
                    );
                }
            }
        }
        // All slots dead without a single successful init: report failed
        // construction to a waiting `Coordinator::start`.
        if !init_reported && (0..n).all(|s| slots.dead[s]) {
            init_reported = true;
            let _ = ready_tx.send(false);
        }
    }
    // Pool died (no slot exited via a clean drain) outside of shutdown:
    // flip the fail-fast state so nothing ever hangs on this queue.
    if (0..n).all(|s| slots.dead[s]) && !queue.is_shutdown() {
        log::error!("all {n} worker slots dead — failing the queue (NoWorkers)");
        queue.fail();
    }
    if !init_reported {
        let _ = ready_tx.send(ever_ready);
    }
    for h in slots.handles.iter_mut().filter_map(|h| h.take()) {
        let _ = h.join();
    }
}

/// One watchdog pass over the live slots: reply `DeadlineExceeded` to every
/// request stranded on a wedged slot, detach the wedged thread (threads
/// cannot be killed — the zombie discovers its confiscated epoch on return
/// and exits silently), and respawn through the shared backoff path.
#[allow(clippy::too_many_arguments)]
fn watchdog_sweep(
    grace: Duration,
    queue: &Arc<BatchQueue>,
    metrics: &Arc<Metrics>,
    factory: &Arc<BackendFactory>,
    cfg: &SupervisorConfig,
    ev_tx: &mpsc::Sender<WorkerEvent>,
    slab: &Arc<InflightSlab>,
    slots: &mut SlotTable,
) {
    let now = Instant::now();
    for slot in 0..cfg.workers {
        if slots.dead[slot] || slots.drained[slot] {
            continue;
        }
        let Some(stranded) = slab.slots[slot].check_wedged(now, grace) else {
            continue;
        };
        let n = stranded.len();
        for (id, tx) in stranded {
            // No recycle: the wedged worker may still read the image buffer.
            log::warn!("request {id}: stranded on wedged worker {slot}; expiring");
            metrics.record_error(&InferError::DeadlineExceeded);
            metrics
                .inflight_expired
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let _ = tx.send(Err(InferError::DeadlineExceeded));
        }
        metrics.watchdog_kills.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Detach, never join: the thread is hung inside the backend.
        drop(slots.handles[slot].take());
        restart_slot(
            slot,
            &format!("wedged mid-batch ({n} in-flight requests expired)"),
            queue, metrics, factory, cfg, ev_tx, Some(slab), slots,
        );
    }
}

/// Shared tail of the crash and watchdog-kill paths: count the failure,
/// back off (interruptibly), and respawn the slot with a new incarnation —
/// or abandon it when the restart budget is spent.
#[allow(clippy::too_many_arguments)]
fn restart_slot(
    slot: usize,
    why: &str,
    queue: &Arc<BatchQueue>,
    metrics: &Arc<Metrics>,
    factory: &Arc<BackendFactory>,
    cfg: &SupervisorConfig,
    ev_tx: &mpsc::Sender<WorkerEvent>,
    slab: Option<&Arc<InflightSlab>>,
    slots: &mut SlotTable,
) {
    if queue.is_shutdown() || queue.is_failed() {
        log::warn!("worker {slot} {why}; not restarting (tearing down)");
        slots.dead[slot] = true;
        return;
    }
    slots.failures[slot] += 1;
    if slots.failures[slot] > cfg.restart_limit {
        log::error!(
            "worker {slot} {why}; restart budget ({}) exhausted — slot abandoned",
            cfg.restart_limit
        );
        slots.dead[slot] = true;
        return;
    }
    let backoff = cfg
        .restart_backoff
        .saturating_mul(1u32 << (slots.failures[slot] - 1).min(10))
        .min(Duration::from_secs(1));
    log::warn!(
        "worker {slot} {why}; restart {}/{} in {backoff:?}",
        slots.failures[slot],
        cfg.restart_limit
    );
    if !wait_interruptible(queue, backoff) {
        slots.dead[slot] = true;
        return;
    }
    metrics.worker_restarts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    slots.incarnation[slot] += 1;
    slots.handles[slot] = Some(spawn_worker(
        slot,
        slots.incarnation[slot],
        Arc::clone(queue),
        Arc::clone(metrics),
        Arc::clone(factory),
        cfg.retry_budget,
        slab.map(Arc::clone),
        ev_tx.clone(),
    ));
}

#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    slot: usize,
    incarnation: u64,
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    retry_budget: u32,
    slab: Option<Arc<InflightSlab>>,
    events: mpsc::Sender<WorkerEvent>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("lqr-worker-{slot}"))
        .spawn(move || {
            let ev2 = events.clone();
            // Backstop: a panic anywhere in the worker loop (not just inside
            // the backend call) still reports Crashed instead of vanishing.
            let exit = catch_unwind(AssertUnwindSafe(|| {
                worker_main(
                    slot,
                    incarnation,
                    &queue,
                    &metrics,
                    &factory,
                    retry_budget,
                    slab.as_deref(),
                    &ev2,
                )
            }))
            .unwrap_or_else(|p| WorkerExit::Crashed(panic_message(&p)));
            let _ = events.send(WorkerEvent::Exited(slot, incarnation, exit));
        })
        .expect("spawn worker")
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    slot: usize,
    incarnation: u64,
    queue: &BatchQueue,
    metrics: &Metrics,
    factory: &BackendFactory,
    retry_budget: u32,
    slab: Option<&InflightSlab>,
    events: &mpsc::Sender<WorkerEvent>,
) -> WorkerExit {
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => return WorkerExit::InitFailed(format!("{e:#}")),
    };
    let _ = events.send(WorkerEvent::Ready(slot, incarnation));
    log::info!("worker {slot}: {}", backend.describe());
    // The slot index doubles as the worker's home-shard identity: slot i
    // drains shard `i % shards` first and steals from siblings after.
    while let Some((batch, reason)) = queue.pop_batch_from(slot) {
        // Stamp before running so the watchdog can see this batch; clear
        // after (a no-op if the watchdog confiscated the epoch meanwhile).
        let watch = slab.map(|s| {
            let cell = &s.slots[slot];
            (cell, cell.stamp(&batch))
        });
        let outcome = run_batch(&mut *backend, batch, reason, metrics, retry_budget, watch);
        if let Some((cell, epoch)) = watch {
            cell.finish(epoch);
        }
        match outcome {
            BatchOutcome::Completed => {}
            BatchOutcome::WorkerPoisoned(msg) => {
                return WorkerExit::Crashed(format!("backend panicked: {msg}"));
            }
            BatchOutcome::Stranded => {
                // The watchdog declared this incarnation wedged and already
                // replied to the batch; this thread is a detached zombie and
                // must exit without touching anything else.
                return WorkerExit::Crashed("stranded by watchdog".into());
            }
        }
    }
    log::debug!("worker {slot}: queue drained, exiting");
    WorkerExit::Drained
}

/// Result of [`run_batch`]: whether the worker may keep its backend.
#[derive(Debug)]
pub(crate) enum BatchOutcome {
    /// All requests replied; backend state is trustworthy.
    Completed,
    /// The backend panicked — every request got a typed reply, but the
    /// backend's internal state is unknown and the worker must be replaced.
    WorkerPoisoned(String),
    /// The watchdog confiscated this batch's epoch mid-run: the stranded
    /// requests were already replied `DeadlineExceeded` by the supervisor
    /// and this worker has been detached and replaced. It must exit without
    /// replying to anything.
    Stranded,
}

/// Execute one popped batch, replying exactly once to every request.
///
/// Mismatched image shapes are rejected per-request with
/// [`InferError::ShapeMismatch`] (the batch's expected shape is the first
/// request's — one route serves one geometry). Backend errors trigger
/// bisection: the failing sub-batch is split and each half retried, bounded
/// by `retry_budget` total invocations, isolating a poison request to a
/// single `BackendFailed` reply. Backend panics are caught; the current
/// sub-batch and all not-yet-run splits get `BackendFailed` replies and the
/// caller is told to retire the worker.
///
/// `watch` is the in-flight watchdog handle (`None` when disabled): every
/// reply is preceded by an epoch'd claim, so if the supervisor declared
/// this batch wedged mid-run, the remaining requests are dropped silently
/// (the watchdog already replied) and [`BatchOutcome::Stranded`] is
/// returned.
pub(crate) fn run_batch(
    backend: &mut dyn crate::coordinator::backend::Backend,
    batch: Vec<InferRequest>,
    reason: FlushReason,
    metrics: &Metrics,
    retry_budget: u32,
    watch: Option<(&InflightSlot, u64)>,
) -> BatchOutcome {
    debug_assert!(!batch.is_empty());
    let claimed = |id: u64| watch.map_or(true, |(cell, epoch)| cell.claim(epoch, id));
    let mut stranded = false;
    let formed_at = Instant::now();
    // Release-mode shape screen: one route = one input geometry. The first
    // request defines the batch shape; stragglers get typed errors instead
    // of silently corrupting the assembled tensor.
    let expected = batch[0].image.shape().to_vec();
    let mut good = Vec::with_capacity(batch.len());
    for r in batch {
        if r.image.shape() != &expected[..] {
            let got = r.image.shape().to_vec();
            log::warn!("request {}: shape {got:?} != batch shape {expected:?}", r.id);
            if claimed(r.id) {
                r.respond_err(
                    InferError::ShapeMismatch { expected: expected.clone(), got },
                    metrics,
                );
            } else {
                stranded = true;
            }
        } else {
            good.push(r);
        }
    }
    if good.is_empty() {
        return if stranded { BatchOutcome::Stranded } else { BatchOutcome::Completed };
    }

    // Bisection worklist (LIFO so the left half runs first, preserving
    // rough FIFO reply order).
    let mut budget = retry_budget.max(1);
    let mut first = true;
    let mut pending: Vec<Vec<InferRequest>> = vec![good];
    while let Some(mut reqs) = pending.pop() {
        if stranded {
            // Epoch confiscated: reply rights belong to the watchdog now.
            // Dropping the remaining requests is correct — their receivers
            // already have the watchdog's DeadlineExceeded reply.
            break;
        }
        if budget == 0 {
            for r in reqs {
                if claimed(r.id) {
                    r.respond_err(
                        InferError::BackendFailed {
                            message: "retry budget exhausted during bisection".into(),
                        },
                        metrics,
                    );
                } else {
                    stranded = true;
                }
            }
            continue;
        }
        budget -= 1;
        let n = reqs.len();
        let input = assemble(&reqs, &expected);
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| backend.run_batch(&input)));
        let exec = t0.elapsed();
        metrics.record_batch(n, exec, first && reason == FlushReason::Deadline);
        first = false;
        match result {
            Ok(Ok(logits)) => {
                if logits.shape().len() != 2 || logits.dim(0) != n {
                    let message = format!(
                        "backend returned logits shape {:?} for a batch of {n}",
                        logits.shape()
                    );
                    log::error!("{message}");
                    for r in reqs {
                        if claimed(r.id) {
                            r.respond_err(
                                InferError::BackendFailed { message: message.clone() },
                                metrics,
                            );
                        } else {
                            stranded = true;
                        }
                    }
                    continue;
                }
                let classes = logits.dim(1);
                for (i, req) in reqs.into_iter().enumerate() {
                    if !claimed(req.id) {
                        stranded = true;
                        continue;
                    }
                    let queue_time = formed_at.duration_since(req.submitted_at);
                    let resp = InferResponse::from_logits(
                        req.id,
                        logits.data()[i * classes..(i + 1) * classes].to_vec(),
                        queue_time,
                        exec,
                        n,
                    );
                    metrics.record_completion(queue_time, req.submitted_at.elapsed());
                    req.respond_ok(resp);
                }
            }
            Ok(Err(e)) if n > 1 => {
                // Poison isolation: split and retry each half independently.
                log::warn!("batch of {n} failed ({e:#}); bisecting");
                let right = reqs.split_off(n / 2);
                pending.push(right);
                pending.push(reqs);
            }
            Ok(Err(e)) => {
                log::error!("request {} failed: {e:#}", reqs[0].id);
                for r in reqs {
                    if claimed(r.id) {
                        r.respond_err(
                            InferError::BackendFailed { message: format!("{e:#}") },
                            metrics,
                        );
                    } else {
                        stranded = true;
                    }
                }
            }
            Err(p) => {
                let msg = panic_message(&p);
                log::error!("backend panicked on a batch of {n}: {msg}");
                let err = InferError::BackendFailed {
                    message: format!("backend panicked: {msg}"),
                };
                for r in reqs.into_iter().chain(pending.into_iter().flatten()) {
                    if claimed(r.id) {
                        r.respond_err(err.clone(), metrics);
                    } else {
                        stranded = true;
                    }
                }
                return BatchOutcome::WorkerPoisoned(msg);
            }
        }
    }
    if stranded { BatchOutcome::Stranded } else { BatchOutcome::Completed }
}

/// Assemble `(n, C, H, W)` from per-request `(1, C, H, W)` images (all
/// pre-validated against `shape`).
fn assemble(reqs: &[InferRequest], shape: &[usize]) -> Tensor {
    let per: usize = shape.iter().product();
    let mut data = Vec::with_capacity(reqs.len() * per);
    for r in reqs {
        data.extend_from_slice(r.image.data());
    }
    let mut dims = vec![reqs.len()];
    dims.extend_from_slice(&shape[1..]);
    Tensor::new(&dims, data)
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

/// Convenience used by tests and single-shot tools: run one request through
/// a backend synchronously.
pub fn run_one(
    backend: &mut dyn crate::coordinator::backend::Backend,
    image: Tensor,
) -> anyhow::Result<InferResponse> {
    let (tx, rx) = mpsc::channel();
    let req = InferRequest {
        id: 0,
        image,
        submitted_at: Instant::now(),
        deadline: None,
        priority: Priority::default(),
        reply: tx,
        recycle: None,
    };
    let _ = run_batch(backend, vec![req], FlushReason::Full, &Metrics::default(), 1, None);
    match rx.recv() {
        Ok(Ok(resp)) => Ok(resp),
        Ok(Err(e)) => Err(e.into()),
        Err(_) => Err(anyhow::anyhow!("no reply (worker bug)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use std::sync::atomic::{AtomicU64, Ordering};

    fn mock() -> MockBackend {
        MockBackend {
            classes: 3,
            delay: Duration::ZERO,
            calls: Arc::new(AtomicU64::new(0)),
        }
    }

    fn req(id: u64, v: f32) -> (InferRequest, mpsc::Receiver<crate::coordinator::request::InferReply>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest {
                id,
                image: Tensor::filled(&[1, 1, 2, 2], v),
                submitted_at: Instant::now(),
                deadline: None,
                priority: Priority::default(),
                reply: tx,
                recycle: None,
            },
            rx,
        )
    }

    #[test]
    fn run_one_mock() {
        let mut b = mock();
        let img = Tensor::new(&[1, 1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let resp = run_one(&mut b, img).unwrap();
        assert_eq!(resp.logits, vec![4.0, 0.0, 0.0]);
        assert_eq!(resp.predicted, 0);
        assert_eq!(resp.batch_size, 1);
    }

    /// Backend that errors whenever the batch contains a poison row (sum
    /// over the magic value threshold).
    struct PoisonSensitive {
        inner: MockBackend,
    }

    impl Backend for PoisonSensitive {
        fn run_batch(&mut self, batch: &Tensor) -> anyhow::Result<Tensor> {
            let n = batch.dim(0);
            let per = batch.len() / n;
            for i in 0..n {
                let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
                if s >= 1000.0 {
                    anyhow::bail!("poison row {i}");
                }
            }
            self.inner.run_batch(batch)
        }

        fn describe(&self) -> String {
            "poison-sensitive".into()
        }
    }

    #[test]
    fn bisection_isolates_poison_request() {
        let mut b = PoisonSensitive { inner: mock() };
        let metrics = Metrics::default();
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            // Request 5 is poison: each of its 4 pixels is 500 (sum 2000).
            let v = if i == 5 { 500.0 } else { i as f32 };
            let (r, rx) = req(i, v);
            reqs.push(r);
            rxs.push(rx);
        }
        let out = run_batch(&mut b, reqs, FlushReason::Full, &metrics, 2 * 8, None);
        assert!(matches!(out, BatchOutcome::Completed));
        for (i, rx) in rxs.into_iter().enumerate() {
            let reply = rx.try_recv().expect("every request replied");
            if i == 5 {
                assert!(matches!(reply, Err(InferError::BackendFailed { .. })));
            } else {
                let resp = reply.expect("neighbor of poison must succeed");
                assert_eq!(resp.logits[0], 4.0 * i as f32);
            }
        }
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 7);
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retry_budget_bounds_bisection() {
        struct AlwaysFails;
        impl Backend for AlwaysFails {
            fn run_batch(&mut self, _b: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("nope")
            }
            fn describe(&self) -> String {
                "always-fails".into()
            }
        }
        let metrics = Metrics::default();
        let (reqs, rxs): (Vec<_>, Vec<_>) = (0..8u64).map(|i| req(i, 1.0)).unzip();
        let out = run_batch(&mut AlwaysFails, reqs, FlushReason::Full, &metrics, 3, None);
        assert!(matches!(out, BatchOutcome::Completed));
        // Only 3 invocations allowed; every request still resolves.
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 3);
        for rx in rxs {
            assert!(matches!(rx.try_recv().unwrap(), Err(InferError::BackendFailed { .. })));
        }
    }

    #[test]
    fn shape_mismatch_rejected_not_corrupted() {
        let mut b = mock();
        let metrics = Metrics::default();
        let (r0, rx0) = req(0, 1.0);
        let (tx, rx1) = mpsc::channel();
        let odd = InferRequest {
            id: 1,
            image: Tensor::filled(&[1, 1, 3, 3], 1.0),
            submitted_at: Instant::now(),
            deadline: None,
            priority: Priority::default(),
            reply: tx,
            recycle: None,
        };
        let out = run_batch(&mut b, vec![r0, odd], FlushReason::Full, &metrics, 4, None);
        assert!(matches!(out, BatchOutcome::Completed));
        assert!(rx0.try_recv().unwrap().is_ok());
        match rx1.try_recv().unwrap() {
            Err(InferError::ShapeMismatch { expected, got }) => {
                assert_eq!(expected, vec![1, 1, 2, 2]);
                assert_eq!(got, vec![1, 1, 3, 3]);
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 1);
    }

    fn req_with_deadline(
        id: u64,
        v: f32,
        deadline: Instant,
    ) -> (InferRequest, mpsc::Receiver<crate::coordinator::request::InferReply>) {
        let (mut r, rx) = req(id, v);
        r.deadline = Some(deadline);
        (r, rx)
    }

    #[test]
    fn restart_backoff_wait_is_interruptible_by_shutdown() {
        use crate::coordinator::batcher::{BatchPolicy, BatchQueue, ShedPolicy};
        let queue = Arc::new(BatchQueue::new(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                capacity: 8,
                shed: ShedPolicy::RejectNewest,
                shards: 1,
                steal: true,
                priority_lanes: true,
            },
            Arc::new(Metrics::default()),
        ));
        let q2 = Arc::clone(&queue);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            q2.shutdown();
        });
        let t0 = Instant::now();
        let completed = wait_interruptible(&queue, Duration::from_secs(30));
        assert!(!completed, "wait must be cut short by shutdown");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "a 30s backoff must not delay shutdown: waited {:?}",
            t0.elapsed()
        );
        t.join().unwrap();
    }

    #[test]
    fn slab_claim_transfers_reply_right_exactly_once() {
        let slot = InflightSlot::default();
        let now = Instant::now();
        let (reqs, _rxs): (Vec<_>, Vec<_>) =
            (0..3u64).map(|i| req_with_deadline(i, 1.0, now)).unzip();
        let epoch = slot.stamp(&reqs);
        // Worker claims one request, then the watchdog fires.
        assert!(slot.claim(epoch, 0));
        assert!(!slot.claim(epoch, 0), "double claim must fail");
        let stranded = slot
            .check_wedged(now + Duration::from_millis(1), Duration::ZERO)
            .expect("slot blew its deadline");
        let mut ids: Vec<u64> = stranded.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2], "already-claimed request must not be drained");
        // The zombie's stale epoch can neither claim nor clear the stamp.
        assert!(!slot.claim(epoch, 1));
        slot.finish(epoch);
        assert!(
            slot.check_wedged(now + Duration::from_secs(1), Duration::ZERO).is_none(),
            "confiscated slot is idle until the replacement stamps it"
        );
    }

    #[test]
    fn no_deadline_batches_are_never_wedge_killed() {
        let slot = InflightSlot::default();
        let (reqs, _rxs): (Vec<_>, Vec<_>) = (0..2u64).map(|i| req(i, 1.0)).unzip();
        let _epoch = slot.stamp(&reqs);
        assert!(slot
            .check_wedged(Instant::now() + Duration::from_secs(3600), Duration::ZERO)
            .is_none());
    }

    #[test]
    fn stranded_batch_drops_silently_after_watchdog_reply() {
        let slot = InflightSlot::default();
        let metrics = Metrics::default();
        let now = Instant::now();
        let (reqs, rxs): (Vec<_>, Vec<_>) =
            (0..4u64).map(|i| req_with_deadline(i, i as f32, now)).unzip();
        let epoch = slot.stamp(&reqs);
        // Watchdog fires before the worker replies and sends the typed
        // expiry through the confiscated senders.
        let stranded = slot.check_wedged(now, Duration::ZERO).expect("wedged");
        assert_eq!(stranded.len(), 4);
        for (_, tx) in &stranded {
            let _ = tx.send(Err(InferError::DeadlineExceeded));
        }
        // The zombie worker now finishes the batch — it must not reply.
        let out =
            run_batch(&mut mock(), reqs, FlushReason::Full, &metrics, 8, Some((&slot, epoch)));
        assert!(matches!(out, BatchOutcome::Stranded), "{out:?}");
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
        for rx in rxs {
            assert!(matches!(rx.try_recv().unwrap(), Err(InferError::DeadlineExceeded)));
            assert!(
                rx.try_recv().is_err(),
                "exactly one reply per request (no zombie double-reply)"
            );
        }
    }

    #[test]
    fn backend_panic_yields_typed_replies_and_poisons_worker() {
        struct Panics;
        impl Backend for Panics {
            fn run_batch(&mut self, _b: &Tensor) -> anyhow::Result<Tensor> {
                panic!("kaboom")
            }
            fn describe(&self) -> String {
                "panics".into()
            }
        }
        let metrics = Metrics::default();
        let (reqs, rxs): (Vec<_>, Vec<_>) = (0..4u64).map(|i| req(i, 1.0)).unzip();
        let out = run_batch(&mut Panics, reqs, FlushReason::Full, &metrics, 8, None);
        match out {
            BatchOutcome::WorkerPoisoned(msg) => assert!(msg.contains("kaboom")),
            other => panic!("expected WorkerPoisoned, got {other:?}"),
        }
        for rx in rxs {
            match rx.try_recv().unwrap() {
                Err(InferError::BackendFailed { message }) => {
                    assert!(message.contains("panicked"), "{message}");
                }
                other => panic!("expected BackendFailed, got {other:?}"),
            }
        }
    }
}
