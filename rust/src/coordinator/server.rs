//! The coordinator facade: configuration, lifecycle, submission API.
//!
//! Failure semantics (full contract in `docs/serving-robustness.md`):
//!
//! - [`Coordinator::start`] fails fast if no worker backend initializes.
//! - Every submitted request resolves to exactly one typed
//!   [`InferReply`](crate::coordinator::request::InferReply) — success or a
//!   typed [`InferError`]; clients never infer failure from `RecvError`.
//! - A dead worker pool flips the coordinator into a fail-fast state:
//!   `submit` returns [`SubmitError::NoWorkers`] and queued requests get
//!   error replies instead of hanging.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::{BatchPolicy, BatchQueue, ShedPolicy, SubmitError};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferError, InferReply, InferRequest, InferResponse, Priority};
use crate::coordinator::worker::{supervise, SupervisorConfig};
use crate::tensor::Tensor;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    /// Overload behaviour at capacity: reject the newest submission or shed
    /// the oldest queued request (see [`ShedPolicy`]).
    pub shed: ShedPolicy,
    /// TTL applied to every request that doesn't carry an explicit one
    /// (`None` = requests never expire).
    pub default_deadline: Option<Duration>,
    /// Backend invocations allowed per popped batch (first attempt +
    /// poison-bisection retries).
    pub retry_budget: u32,
    /// Consecutive failed worker respawns per slot before the slot is
    /// abandoned (0 = never restart; a successful init resets the count).
    pub restart_limit: u32,
    /// Base supervisor backoff before a restart; doubles per consecutive
    /// failure, capped at 1s.
    pub restart_backoff: Duration,
    /// Submission shards (0 = auto: one per worker). More shards cut
    /// submit-lock contention; work stealing keeps them all drained.
    pub shards: usize,
    /// Let an idle worker steal the stalest releasable bucket from sibling
    /// shards. With stealing off, `shards` is clamped to `workers` so every
    /// shard has a home worker.
    pub steal: bool,
    /// Schedule the interactive lane ahead of bulk and shed bulk first
    /// (see [`Priority`]).
    pub priority_lanes: bool,
    /// In-flight watchdog grace: a worker still executing a batch past the
    /// batch's deadline plus this grace is declared wedged — the stranded
    /// requests get typed [`InferError::DeadlineExceeded`] replies and the
    /// slot is respawned through the capped-backoff restart path. `None`
    /// (the default) disables the watchdog; requests without a deadline are
    /// never watchdog-killed either way.
    pub watchdog_grace: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: 1024,
            shed: ShedPolicy::RejectNewest,
            default_deadline: None,
            retry_budget: 16,
            restart_limit: 5,
            restart_backoff: Duration::from_millis(10),
            shards: 0,
            steal: true,
            priority_lanes: true,
            watchdog_grace: None,
        }
    }
}

/// A running inference service over one model variant.
pub struct Coordinator {
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    supervisor: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start supervised workers over a backend factory (each worker builds
    /// its own backend — PJRT sessions are thread-bound). Blocks until at
    /// least one backend initializes; errors if every worker slot dies
    /// without a single successful init, so a fully-dead pool is a
    /// construction failure, not a hang at first `infer`.
    pub fn start(config: CoordinatorConfig, factory: BackendFactory) -> Result<Coordinator> {
        anyhow::ensure!(config.workers >= 1, "need at least one worker");
        let metrics = Arc::new(Metrics::default());
        // Shard resolution: 0 = one shard per worker. Without stealing a
        // shard with no home worker would never drain, so clamp.
        let mut shards = if config.shards == 0 { config.workers } else { config.shards };
        if !config.steal {
            shards = shards.min(config.workers);
        }
        let queue = Arc::new(BatchQueue::new(
            BatchPolicy {
                max_batch: config.max_batch,
                max_wait: config.max_wait,
                capacity: config.queue_capacity,
                shed: config.shed,
                shards: shards.max(1),
                steal: config.steal,
                priority_lanes: config.priority_lanes,
            },
            Arc::clone(&metrics),
        ));
        let (supervisor, ready_rx) = supervise(
            Arc::clone(&queue),
            Arc::clone(&metrics),
            Arc::new(factory),
            SupervisorConfig {
                workers: config.workers,
                restart_limit: config.restart_limit,
                restart_backoff: config.restart_backoff,
                retry_budget: config.retry_budget,
                watchdog_grace: config.watchdog_grace,
            },
        );
        if !ready_rx.recv().unwrap_or(false) {
            queue.shutdown();
            let _ = supervisor.join();
            anyhow::bail!(
                "coordinator start failed: no worker backend initialized ({} slot(s))",
                config.workers
            );
        }
        Ok(Coordinator {
            queue,
            metrics,
            next_id: AtomicU64::new(0),
            default_deadline: config.default_deadline,
            supervisor: Some(supervisor),
        })
    }

    /// Submit one image; returns a receiver that yields exactly one typed
    /// [`InferReply`]. Applies backpressure via [`SubmitError`].
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        self.submit_with_deadline(image, None)
    }

    /// Submit with an explicit TTL (overrides the config's
    /// `default_deadline`). Requests still queued past their deadline are
    /// expired with [`InferError::DeadlineExceeded`] instead of executing.
    pub fn submit_with_deadline(
        &self,
        image: Tensor,
        ttl: Option<Duration>,
    ) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        self.submit_with_options(image, ttl, Priority::default())
    }

    /// Full-control submission: explicit TTL and scheduling lane. The lane
    /// is advisory when the queue runs with priority lanes disabled.
    pub fn submit_with_options(
        &self,
        image: Tensor,
        ttl: Option<Duration>,
        priority: Priority,
    ) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        self.submit_pooled(image, ttl, priority, None)
    }

    /// [`Coordinator::submit_with_options`] plus a buffer-recycle hook: at
    /// reply time the image's float storage is handed back through
    /// `recycle` (see [`InferRequest::recycle`]) so a steady-state
    /// submitter — the TCP ingress — can reuse one buffer per connection
    /// instead of allocating per request. A synchronous reject (queue full)
    /// drops the buffer to the allocator; that is the overload path, not
    /// steady state.
    pub fn submit_pooled(
        &self,
        image: Tensor,
        ttl: Option<Duration>,
        priority: Priority,
        recycle: Option<mpsc::SyncSender<Vec<f32>>>,
    ) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let req = InferRequest {
            id,
            image,
            submitted_at: now,
            deadline: ttl.or(self.default_deadline).map(|d| now + d),
            priority,
            reply: tx,
            recycle,
        };
        match self.queue.submit(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(e) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                if matches!(e, SubmitError::QueueFull(_)) {
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// Submit and wait (convenience for examples / tests). Maps the typed
    /// reply protocol into `anyhow`: the error chain carries the concrete
    /// [`InferError`] / [`SubmitError`], never a bare channel disconnect.
    pub fn infer(&self, image: Tensor) -> Result<InferResponse> {
        self.infer_with_deadline(image, None)
    }

    /// [`Coordinator::infer`] with an explicit TTL.
    pub fn infer_with_deadline(
        &self,
        image: Tensor,
        ttl: Option<Duration>,
    ) -> Result<InferResponse> {
        self.infer_with_options(image, ttl, Priority::default())
    }

    /// [`Coordinator::infer`] with an explicit TTL and scheduling lane.
    pub fn infer_with_options(
        &self,
        image: Tensor,
        ttl: Option<Duration>,
        priority: Priority,
    ) -> Result<InferResponse> {
        let rx = self.submit_with_options(image, ttl, priority).map_err(anyhow::Error::from)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(anyhow::Error::from(e)),
            // Unreachable by protocol (every request gets exactly one typed
            // reply); kept so a future bug degrades to an error, not a lie.
            Err(_) => Err(anyhow::anyhow!(InferError::NoWorkers)),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Queued requests per submission shard (diagnostics / tests).
    pub fn shard_depths(&self) -> Vec<usize> {
        self.queue.shard_depths()
    }

    /// Queued requests per lane: `[interactive, bulk]`.
    pub fn lane_depths(&self) -> [usize; 2] {
        self.queue.lane_depths()
    }

    /// Configured queue capacity (the `queue_capacity` knob), for health /
    /// readiness reporting alongside [`Coordinator::queue_depth`].
    pub fn queue_capacity(&self) -> usize {
        self.queue.policy().capacity
    }

    /// True once the pool is irrecoverably dead (fail-fast state).
    pub fn is_failed(&self) -> bool {
        self.queue.is_failed()
    }

    /// Stop accepting work, drain the queue, join the supervisor (which
    /// joins the workers), then resolve any stragglers with
    /// [`InferError::ShuttingDown`] — every outstanding receiver resolves.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.teardown();
        Arc::clone(&self.metrics)
    }

    fn teardown(&mut self) {
        self.queue.shutdown();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        // Normally empty (workers drain on shutdown); non-empty only if the
        // pool died mid-drain.
        self.queue.flush_pending(InferError::ShuttingDown);
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.teardown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use std::sync::atomic::AtomicU64 as AU64;

    fn mock_factory(delay_ms: u64, calls: Arc<AU64>) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(MockBackend {
                classes: 4,
                delay: Duration::from_millis(delay_ms),
                calls: Arc::clone(&calls),
            }) as Box<dyn Backend>)
        })
    }

    fn img(v: f32) -> Tensor {
        Tensor::filled(&[1, 1, 2, 2], v)
    }

    #[test]
    fn end_to_end_single() {
        let calls = Arc::new(AU64::new(0));
        let c = Coordinator::start(CoordinatorConfig::default(), mock_factory(0, calls)).unwrap();
        assert_eq!(c.queue_capacity(), CoordinatorConfig::default().queue_capacity);
        let resp = c.infer(img(0.5)).unwrap();
        assert_eq!(resp.logits[0], 2.0); // 4 pixels * 0.5
        let m = c.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batching_aggregates_under_load() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            queue_capacity: 256,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(2, Arc::clone(&calls))).unwrap();
        let rxs: Vec<_> = (0..32).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.logits[0], 4.0 * i as f32, "response routed to wrong request");
        }
        let m = c.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 32);
        assert!(
            m.mean_batch_size() > 1.5,
            "expected batching under load, mean={}",
            m.mean_batch_size()
        );
    }

    #[test]
    fn responses_match_requests_across_workers() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 3,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 256,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(1, calls)).unwrap();
        let rxs: Vec<_> = (0..64).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap().logits[0], 4.0 * i as f32);
        }
    }

    #[test]
    fn rejects_when_queue_full() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 2,
            max_wait: Duration::from_millis(200),
            queue_capacity: 4,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(100, calls)).unwrap();
        let mut rejected = false;
        let mut rxs = Vec::new();
        for i in 0..64 {
            match c.submit(img(i as f32)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::QueueFull(_)) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected, "backpressure never engaged");
        assert!(c.metrics().rejected.load(Ordering::Relaxed) >= 1);
        assert!(c.metrics().shed.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn shutdown_drains_pending() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(500),
            queue_capacity: 256,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(1, calls)).unwrap();
        let rxs: Vec<_> = (0..6).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        let m = c.shutdown(); // must flush the partial batch immediately
        assert_eq!(m.completed.load(Ordering::Relaxed), 6);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn sharded_config_completes_all_requests() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 4,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 512,
            shards: 4,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(1, calls)).unwrap();
        assert_eq!(c.shard_depths().len(), 4);
        let rxs: Vec<_> = (0..128).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            assert_eq!(r.logits[0], 4.0 * i as f32, "response routed to wrong request");
        }
        let m = c.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 128);
    }

    #[test]
    fn no_steal_clamps_shards_to_workers() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 256,
            shards: 8,
            steal: false,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(0, calls)).unwrap();
        // 8 requested shards, but without stealing only a worker's home
        // shard ever drains — must clamp to the worker count.
        assert_eq!(c.shard_depths().len(), 2);
        let rxs: Vec<_> = (0..32).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(10)).unwrap().is_ok());
        }
    }

    #[test]
    fn priority_submissions_complete_on_both_lanes() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 256,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, mock_factory(1, calls)).unwrap();
        let rxs: Vec<_> = (0..32)
            .map(|i| {
                let pri = if i % 2 == 0 { Priority::Interactive } else { Priority::Bulk };
                c.submit_with_options(img(i as f32), None, pri).unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let m = c.shutdown();
        assert_eq!(m.lane_submitted[0].load(Ordering::Relaxed), 16);
        assert_eq!(m.lane_submitted[1].load(Ordering::Relaxed), 16);
    }

    #[test]
    fn watchdog_recovers_wedged_worker_and_expires_in_flight() {
        use std::sync::atomic::AtomicBool;
        // First run_batch call across the pool hangs until `release`; the
        // supervisor watchdog must expire the stranded request and respawn
        // the slot without waiting for the hung call to return.
        struct WedgeOnce {
            wedge: Arc<AtomicBool>,
            release: Arc<AtomicBool>,
            inner: MockBackend,
        }
        impl Backend for WedgeOnce {
            fn run_batch(&mut self, b: &Tensor) -> anyhow::Result<Tensor> {
                if self.wedge.swap(false, Ordering::SeqCst) {
                    while !self.release.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    anyhow::bail!("unwedged late");
                }
                self.inner.run_batch(b)
            }
            fn describe(&self) -> String {
                "wedge-once".into()
            }
        }
        let wedge = Arc::new(AtomicBool::new(true));
        let release = Arc::new(AtomicBool::new(false));
        let calls = Arc::new(AU64::new(0));
        let (w2, r2) = (Arc::clone(&wedge), Arc::clone(&release));
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(WedgeOnce {
                wedge: Arc::clone(&w2),
                release: Arc::clone(&r2),
                inner: MockBackend {
                    classes: 4,
                    delay: Duration::ZERO,
                    calls: Arc::clone(&calls),
                },
            }) as Box<dyn Backend>)
        });
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_capacity: 64,
            default_deadline: Some(Duration::from_millis(100)),
            watchdog_grace: Some(Duration::from_millis(50)),
            restart_backoff: Duration::from_millis(5),
            ..Default::default()
        };
        let c = Coordinator::start(cfg, factory).unwrap();
        let t0 = Instant::now();
        let rx = c.submit(img(1.0)).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            Err(InferError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded from the watchdog, got {other:?}"),
        }
        // Bounded recovery: deadline + grace + backoff, plus sweep tick and
        // scheduling slack — far below the 10s receiver bound either way.
        assert!(t0.elapsed() < Duration::from_secs(5));
        // The replacement worker serves traffic while the zombie still hangs.
        let resp = c.infer(img(0.5)).unwrap();
        assert_eq!(resp.logits[0], 2.0);
        let m = c.metrics();
        assert_eq!(m.watchdog_kills.load(Ordering::Relaxed), 1);
        assert_eq!(m.inflight_expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
        assert!(m.worker_restarts.load(Ordering::Relaxed) >= 1);
        // Unwedge the zombie before teardown so the detached thread exits.
        release.store(true, Ordering::SeqCst);
        let m = c.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn default_deadline_applies_to_submissions() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_capacity: 256,
            default_deadline: Some(Duration::from_millis(30)),
            ..Default::default()
        };
        // 80ms backend: the first request executes, the second expires
        // while the first occupies the only worker.
        let c = Coordinator::start(cfg, mock_factory(80, calls)).unwrap();
        let rx1 = c.submit(img(1.0)).unwrap();
        let rx2 = c.submit(img(2.0)).unwrap();
        assert!(rx1.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        match rx2.recv_timeout(Duration::from_secs(5)).unwrap() {
            Err(InferError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let m = c.shutdown();
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
    }
}
