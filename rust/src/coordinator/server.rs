//! The coordinator facade: configuration, lifecycle, submission API.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::backend::BackendFactory;
use crate::coordinator::batcher::{BatchPolicy, BatchQueue, SubmitError};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, InferResponse};
use crate::coordinator::worker::spawn_workers;
use crate::tensor::Tensor;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: 1024,
        }
    }
}

/// A running inference service over one model variant.
pub struct Coordinator {
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start workers over a backend factory (each worker builds its own
    /// backend — PJRT sessions are thread-bound).
    pub fn start(config: CoordinatorConfig, factory: BackendFactory) -> Result<Coordinator> {
        anyhow::ensure!(config.workers >= 1, "need at least one worker");
        let queue = Arc::new(BatchQueue::new(BatchPolicy {
            max_batch: config.max_batch,
            max_wait: config.max_wait,
            capacity: config.queue_capacity,
        }));
        let metrics = Arc::new(Metrics::default());
        let workers = spawn_workers(
            config.workers,
            Arc::clone(&queue),
            Arc::clone(&metrics),
            Arc::new(factory),
        );
        Ok(Coordinator { queue, metrics, next_id: AtomicU64::new(0), workers })
    }

    /// Submit one image; returns a receiver for the response. Applies
    /// backpressure via [`SubmitError::QueueFull`].
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<InferResponse>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferRequest { id, image, submitted_at: Instant::now(), reply: tx };
        match self.queue.submit(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(e) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit and wait (convenience for examples / tests).
    pub fn infer(&self, image: Tensor) -> Result<InferResponse> {
        let rx = self.submit(image).map_err(anyhow::Error::from)?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped request (backend failure)"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Stop accepting work, drain the queue, join the workers.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.queue.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        Arc::clone(&self.metrics)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, MockBackend};
    use std::sync::atomic::AtomicU64 as AU64;

    fn mock_factory(delay_ms: u64, calls: Arc<AU64>) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(MockBackend {
                classes: 4,
                delay: Duration::from_millis(delay_ms),
                calls: Arc::clone(&calls),
            }) as Box<dyn Backend>)
        })
    }

    fn img(v: f32) -> Tensor {
        Tensor::filled(&[1, 1, 2, 2], v)
    }

    #[test]
    fn end_to_end_single() {
        let calls = Arc::new(AU64::new(0));
        let c = Coordinator::start(CoordinatorConfig::default(), mock_factory(0, calls)).unwrap();
        let resp = c.infer(img(0.5)).unwrap();
        assert_eq!(resp.logits[0], 2.0); // 4 pixels * 0.5
        let m = c.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batching_aggregates_under_load() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            queue_capacity: 256,
        };
        let c = Coordinator::start(cfg, mock_factory(2, Arc::clone(&calls))).unwrap();
        let rxs: Vec<_> = (0..32).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.logits[0], 4.0 * i as f32, "response routed to wrong request");
        }
        let m = c.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 32);
        assert!(
            m.mean_batch_size() > 1.5,
            "expected batching under load, mean={}",
            m.mean_batch_size()
        );
    }

    #[test]
    fn responses_match_requests_across_workers() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 3,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 256,
        };
        let c = Coordinator::start(cfg, mock_factory(1, calls)).unwrap();
        let rxs: Vec<_> = (0..64).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().logits[0], 4.0 * i as f32);
        }
    }

    #[test]
    fn rejects_when_queue_full() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 2,
            max_wait: Duration::from_millis(200),
            queue_capacity: 4,
        };
        let c = Coordinator::start(cfg, mock_factory(100, calls)).unwrap();
        let mut rejected = false;
        let mut rxs = Vec::new();
        for i in 0..64 {
            match c.submit(img(i as f32)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::QueueFull(_)) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected, "backpressure never engaged");
        assert!(c.metrics().rejected.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn shutdown_drains_pending() {
        let calls = Arc::new(AU64::new(0));
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(500),
            queue_capacity: 256,
        };
        let c = Coordinator::start(cfg, mock_factory(1, calls)).unwrap();
        let rxs: Vec<_> = (0..6).map(|i| c.submit(img(i as f32)).unwrap()).collect();
        let m = c.shutdown(); // must flush the partial batch immediately
        assert_eq!(m.completed.load(Ordering::Relaxed), 6);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }
}
