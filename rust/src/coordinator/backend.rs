//! Execution backends for the worker threads.
//!
//! A backend turns a `(B, C, H, W)` batch into `(B, classes)` logits. Three
//! implementations:
//!
//! - [`PjrtBackend`]  — the AOT path: compiled HLO artifacts (f32 or the
//!   Pallas-LQ variants), per-thread PJRT session. Picks the best artifact
//!   batch size for each incoming batch and pads the remainder.
//! - [`NativeBackend`] — the rust-native engine at any [`Precision`]
//!   (used for quantization configurations not baked into artifacts).
//! - [`MockBackend`]  — deterministic stub for coordinator tests.

use std::sync::Arc;

use anyhow::Result;

use crate::nn::{Engine, Precision};
use crate::runtime::{ModelRunner, Session};
use crate::tensor::Tensor;

/// A batch executor. Implementations need not be Send — each worker thread
/// builds its own backend via [`BackendFactory`].
///
/// Fault contract (what the supervised worker does with misbehaviour):
/// an `Err` from [`Backend::run_batch`] fails only that batch — the worker
/// bisects and retries to isolate poison requests, and the backend is
/// assumed reusable afterwards. A *panic* retires the whole worker (state
/// unknown); the supervisor replaces it with a fresh backend. Returning a
/// logits tensor whose row count differs from the input batch is treated
/// as a batch failure, never silently mis-routed.
pub trait Backend {
    /// Execute a `(B, C, H, W)` batch -> `(B, classes)` logits.
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor>;
    /// Human-readable description for logs.
    fn describe(&self) -> String;
}

/// Thread-safe constructor for per-worker backends. May be invoked many
/// times over a coordinator's life: once per worker slot at start, and
/// again whenever the supervisor replaces a crashed worker — it should be
/// idempotent and safe to call concurrently.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn Backend>> + Send + Sync>;

// ------------------------------------------------------------------ PJRT --

/// Runs batches through AOT artifacts, choosing the smallest artifact batch
/// size >= the incoming batch (padding with zero rows) — or falling back to
/// looping the largest artifact when the batch exceeds it.
pub struct PjrtBackend {
    session: Session,
    /// (batch_size, runner), ascending by batch size.
    runners: Vec<(usize, ModelRunner)>,
    input_chw: (usize, usize, usize),
    name: String,
}

impl PjrtBackend {
    /// Load every `(model, variant)` artifact from `artifacts_dir`.
    pub fn open(artifacts_dir: &str, model: &str, variant: &str) -> Result<PjrtBackend> {
        let mut session = Session::open(artifacts_dir)?;
        let metas: Vec<_> = session
            .manifest()
            .variants(model, variant)
            .into_iter()
            .map(|a| a.name.clone())
            .collect();
        anyhow::ensure!(
            !metas.is_empty(),
            "no artifacts for model={model} variant={variant} in {artifacts_dir}"
        );
        let input_chw = session.manifest().models[model].input_shape;
        let mut runners = Vec::new();
        for name in metas {
            let r = session.load(&name)?;
            runners.push((r.meta.batch, r));
        }
        runners.sort_by_key(|(b, _)| *b);
        Ok(PjrtBackend {
            session,
            runners,
            input_chw,
            name: format!("pjrt:{model}:{variant}"),
        })
    }

    fn pick(&self, n: usize) -> &ModelRunner {
        for (b, r) in &self.runners {
            if *b >= n {
                return r;
            }
        }
        &self.runners.last().unwrap().1
    }

    /// Run exactly one artifact invocation on `rows` rows (rows <= artifact
    /// batch), padding the tail with zeros.
    fn run_padded(&self, runner: &ModelRunner, batch: &Tensor, start: usize, rows: usize) -> Result<Tensor> {
        let (c, h, w) = self.input_chw;
        let per = c * h * w;
        let ab = runner.meta.batch;
        let mut data = vec![0.0f32; ab * per];
        data[..rows * per]
            .copy_from_slice(&batch.data()[start * per..(start + rows) * per]);
        let padded = Tensor::new(&[ab, c, h, w], data);
        let logits = self.session.run(runner, &padded)?;
        Ok(logits.take_rows(rows))
    }
}

impl Backend for PjrtBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        let largest = self.runners.last().unwrap().0;
        if n <= largest {
            let runner = self.pick(n);
            return self.run_padded(runner, batch, 0, n);
        }
        // Oversized batch: tile the largest artifact.
        let runner = &self.runners.last().unwrap().1;
        let mut out = Vec::with_capacity(n * runner.num_classes);
        let mut start = 0;
        while start < n {
            let rows = largest.min(n - start);
            let part = self.run_padded(runner, batch, start, rows)?;
            out.extend_from_slice(part.data());
            start += rows;
        }
        Ok(Tensor::new(&[n, runner.num_classes], out))
    }

    fn describe(&self) -> String {
        format!(
            "{} batches={:?}",
            self.name,
            self.runners.iter().map(|(b, _)| *b).collect::<Vec<_>>()
        )
    }
}

// ---------------------------------------------------------------- native --

/// Rust-native engine backend: any precision, no artifact needed.
///
/// Holds the [`Engine`] behind an `Arc`: `Engine::forward` takes `&self`
/// and the prepared-panel cache is internally locked, so every worker in a
/// pool (and every supervisor-restarted replacement) can share ONE engine —
/// one weight copy, one `WeightPanel` per (layer, bits_w, region) — instead
/// of paying N× memory and N× quantize+pack cold-start. Build pools via
/// [`shared_native_factory`].
pub struct NativeBackend {
    engine: Arc<Engine>,
    precision: Precision,
}

impl NativeBackend {
    /// Wrap an owned engine (single-backend uses: tools, tests). Worker
    /// pools should share one engine via [`NativeBackend::shared`] /
    /// [`shared_native_factory`] instead.
    pub fn new(engine: Engine, precision: Precision) -> NativeBackend {
        NativeBackend::shared(Arc::new(engine), precision)
    }

    /// Attach to a shared engine (panel cache and weights are shared with
    /// every other holder of the `Arc`).
    pub fn shared(engine: Arc<Engine>, precision: Precision) -> NativeBackend {
        NativeBackend { engine, precision }
    }
}

impl Backend for NativeBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        Ok(self.engine.forward(batch, self.precision))
    }

    fn describe(&self) -> String {
        let stats = self.engine.panel_stats();
        format!(
            "native:{}:{:?} panels={} panel_bytes={} (shared x{})",
            self.engine.arch.name,
            self.precision,
            stats.panels,
            stats.bytes,
            Arc::strong_count(&self.engine),
        )
    }
}

/// A [`BackendFactory`] whose every product — initial worker slots *and*
/// supervisor-restarted replacements — attaches to the same shared engine.
///
/// Pre-warms the panel cache before returning: every layer's
/// `WeightPanel` for `precision` is built once, here, so no worker ever
/// pays quantize+pack latency on its first batch and the health route can
/// report the route warmed from the moment it serves. Returns the factory
/// plus the number of panels prepared.
pub fn shared_native_factory(
    engine: Arc<Engine>,
    precision: Precision,
) -> (BackendFactory, usize) {
    let warmed = engine.prewarm(precision);
    let factory: BackendFactory = Box::new(move || {
        Ok(Box::new(NativeBackend::shared(Arc::clone(&engine), precision)) as Box<dyn Backend>)
    });
    (factory, warmed)
}

// ------------------------------------------------------------------ mock --

/// Test backend: logits = [row_sum, id, 0, ...]; optional artificial delay.
pub struct MockBackend {
    pub classes: usize,
    pub delay: std::time::Duration,
    pub calls: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl Backend for MockBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let n = batch.dim(0);
        let per = batch.len() / n;
        let mut out = vec![0.0f32; n * self.classes];
        for i in 0..n {
            let s: f32 = batch.data()[i * per..(i + 1) * per].iter().sum();
            out[i * self.classes] = s;
        }
        Ok(Tensor::new(&[n, self.classes], out))
    }

    fn describe(&self) -> String {
        "mock".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn mock_backend_row_sums() {
        let mut b = MockBackend {
            classes: 4,
            delay: std::time::Duration::ZERO,
            calls: Arc::new(AtomicU64::new(0)),
        };
        let x = Tensor::new(&[2, 1, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let y = b.run_batch(&x).unwrap();
        assert_eq!(y.at2(0, 0), 3.0);
        assert_eq!(y.at2(1, 0), 7.0);
        assert_eq!(b.calls.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
