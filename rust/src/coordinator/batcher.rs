//! Bounded FIFO queue + dynamic batching policy.
//!
//! The policy is the classic serving trade-off: a batch is released when
//! either `max_batch` requests are queued (throughput) or the oldest queued
//! request has waited `max_wait` (latency). The queue is bounded at
//! `capacity`; when full, `submit` applies backpressure by returning
//! [`SubmitError::QueueFull`] so the caller can shed or retry.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::request::InferRequest;

/// Why a batch was released (recorded in metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Shutdown,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull(usize),
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(cap) => write!(f, "queue full (capacity {cap})"),
            SubmitError::ShutDown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), capacity: 1024 }
    }
}

struct Inner {
    queue: VecDeque<InferRequest>,
    shutdown: bool,
}

/// Thread-safe batching queue shared between submitters and workers.
pub struct BatchQueue {
    policy: BatchPolicy,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl BatchQueue {
    pub fn new(policy: BatchPolicy) -> BatchQueue {
        assert!(policy.max_batch >= 1);
        BatchQueue {
            policy,
            inner: Mutex::new(Inner { queue: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (FIFO). Fails when full or shut down.
    pub fn submit(&self, req: InferRequest) -> Result<(), SubmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.shutdown {
            return Err(SubmitError::ShutDown);
        }
        if inner.queue.len() >= self.policy.capacity {
            return Err(SubmitError::QueueFull(self.policy.capacity));
        }
        inner.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Current depth (approximate).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready, the deadline of the oldest request
    /// expires, or shutdown. Returns `None` only when shut down *and* empty;
    /// FIFO order is preserved within and across batches.
    pub fn pop_batch(&self) -> Option<(Vec<InferRequest>, FlushReason)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.queue.len() >= self.policy.max_batch {
                let batch = drain(&mut inner.queue, self.policy.max_batch);
                self.cv.notify_all(); // submitters may be watching depth
                return Some((batch, FlushReason::Full));
            }
            if !inner.queue.is_empty() {
                let oldest = inner.queue.front().unwrap().submitted_at;
                let elapsed = oldest.elapsed();
                if elapsed >= self.policy.max_wait {
                    let n = inner.queue.len().min(self.policy.max_batch);
                    let batch = drain(&mut inner.queue, n);
                    return Some((batch, FlushReason::Deadline));
                }
                if inner.shutdown {
                    let n = inner.queue.len().min(self.policy.max_batch);
                    return Some((drain(&mut inner.queue, n), FlushReason::Shutdown));
                }
                // Wait out the remaining deadline (or a new arrival).
                let (guard, _) = self
                    .cv
                    .wait_timeout(inner, self.policy.max_wait - elapsed)
                    .unwrap();
                inner = guard;
            } else {
                if inner.shutdown {
                    return None;
                }
                inner = self.cv.wait(inner).unwrap();
            }
        }
    }

    /// Stop accepting new work; wake workers to drain the remainder.
    pub fn shutdown(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.shutdown = true;
        self.cv.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.inner.lock().unwrap().shutdown
    }
}

fn drain(q: &mut VecDeque<InferRequest>, n: usize) -> Vec<InferRequest> {
    q.drain(..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::thread;
    use std::time::Instant;

    fn req(id: u64) -> (InferRequest, mpsc::Receiver<crate::coordinator::InferResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest {
                id,
                image: Tensor::zeros(&[1, 1, 2, 2]),
                submitted_at: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            capacity: 100,
        });
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
        }
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(reason, FlushReason::Full);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_flush_partial_batch() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
            capacity: 100,
        });
        let (r, _rx) = req(7);
        q.submit(r).unwrap();
        let t0 = Instant::now();
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(reason, FlushReason::Deadline);
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(8), "flushed too early");
    }

    #[test]
    fn backpressure_when_full() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(1),
            capacity: 2,
        });
        let (a, _ra) = req(1);
        let (b, _rb) = req(2);
        let (c, _rc) = req(3);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        assert_eq!(q.submit(c), Err(SubmitError::QueueFull(2)));
    }

    #[test]
    fn shutdown_drains_then_none() {
        let q = Arc::new(BatchQueue::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            capacity: 100,
        }));
        let (r, _rx) = req(1);
        q.submit(r).unwrap();
        q.shutdown();
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Shutdown);
        assert!(q.pop_batch().is_none());
        let (r2, _rx2) = req(2);
        assert_eq!(q.submit(r2), Err(SubmitError::ShutDown));
    }

    #[test]
    fn fifo_across_batches_with_concurrent_worker() {
        let q = Arc::new(BatchQueue::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(5),
            capacity: 1000,
        }));
        let qq = Arc::clone(&q);
        let collector = thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some((batch, _)) = qq.pop_batch() {
                seen.extend(batch.iter().map(|r| r.id));
            }
            seen
        });
        let mut rxs = Vec::new();
        for i in 0..50 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
            if i % 7 == 0 {
                thread::sleep(Duration::from_millis(1));
            }
        }
        q.shutdown();
        let seen = collector.join().unwrap();
        assert_eq!(seen, (0..50).collect::<Vec<_>>(), "FIFO order violated");
    }
}
