//! Bounded FIFO queue + dynamic batching policy + admission control.
//!
//! The policy is the classic serving trade-off: a batch is released when
//! either `max_batch` requests are queued (throughput) or the oldest queued
//! request has waited `max_wait` (latency). The queue is bounded at
//! `capacity`; when full, the [`ShedPolicy`] decides whether the *newest*
//! request is rejected ([`SubmitError::QueueFull`]) or the *oldest* queued
//! request is shed with a typed [`InferError::Shed`] reply to admit the new
//! one — overload degrades latency-predictably instead of queue-deep.
//!
//! Requests carry an optional deadline; [`BatchQueue::pop_batch`] expires
//! stale requests with [`InferError::DeadlineExceeded`] *before* forming
//! batches, so workers never burn cycles computing answers nobody is
//! waiting for.
//!
//! The queue also owns the coordinator's fail-fast state: when the
//! supervisor declares the worker pool irrecoverably dead it calls
//! [`BatchQueue::fail`], which flushes every queued request with
//! [`InferError::NoWorkers`] and makes later submits return
//! [`SubmitError::NoWorkers`] — no request ever hangs on a dead pool.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferError, InferRequest, ShedReason};

/// Why a batch was released (recorded in metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Shutdown,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull(usize),
    ShutDown,
    /// The worker pool is irrecoverably dead (every worker exhausted its
    /// restart budget); the coordinator is in its fail-fast state.
    NoWorkers,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(cap) => write!(f, "queue full (capacity {cap})"),
            SubmitError::ShutDown => write!(f, "coordinator shut down"),
            SubmitError::NoWorkers => write!(f, "no live workers (pool is dead)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What to do with a submission when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the incoming request: `submit` returns
    /// [`SubmitError::QueueFull`] and the caller never gets a receiver.
    RejectNewest,
    /// Admit the incoming request by shedding the oldest queued one; the
    /// victim's receiver gets [`InferError::Shed`]. Favors fresh traffic —
    /// the requests most likely to still have a waiting client.
    DropOldest,
}

impl ShedPolicy {
    /// Parse a CLI-style name (`reject-newest` | `drop-oldest`).
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "reject-newest" => Some(ShedPolicy::RejectNewest),
            "drop-oldest" => Some(ShedPolicy::DropOldest),
            _ => None,
        }
    }
}

/// Batch formation + admission policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
    pub shed: ShedPolicy,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            capacity: 1024,
            shed: ShedPolicy::RejectNewest,
        }
    }
}

struct Inner {
    queue: VecDeque<InferRequest>,
    shutdown: bool,
    /// Fail-fast: pool irrecoverably dead. Submits refuse, workers exit.
    failed: bool,
}

/// Thread-safe batching queue shared between submitters and workers.
pub struct BatchQueue {
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl BatchQueue {
    pub fn new(policy: BatchPolicy, metrics: Arc<Metrics>) -> BatchQueue {
        assert!(policy.max_batch >= 1);
        BatchQueue {
            policy,
            metrics,
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                shutdown: false,
                failed: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (FIFO). At capacity the [`ShedPolicy`] applies;
    /// fails when shut down or the pool is dead.
    pub fn submit(&self, req: InferRequest) -> Result<(), SubmitError> {
        let victim = {
            let mut inner = self.inner.lock().unwrap();
            if inner.failed {
                return Err(SubmitError::NoWorkers);
            }
            if inner.shutdown {
                return Err(SubmitError::ShutDown);
            }
            let victim = if inner.queue.len() >= self.policy.capacity {
                match self.policy.shed {
                    ShedPolicy::RejectNewest => {
                        return Err(SubmitError::QueueFull(self.policy.capacity))
                    }
                    ShedPolicy::DropOldest => inner.queue.pop_front(),
                }
            } else {
                None
            };
            inner.queue.push_back(req);
            self.cv.notify_one();
            victim
        };
        // Reply to the shed victim outside the lock.
        if let Some(v) = victim {
            v.respond_err(InferError::Shed { reason: ShedReason::DropOldest }, &self.metrics);
        }
        Ok(())
    }

    /// Current depth (approximate).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready, the wait deadline of the oldest request
    /// expires, or shutdown. Expired requests are replied
    /// [`InferError::DeadlineExceeded`] and never occupy batch slots.
    /// Returns `None` when shut down *and* empty, or when the pool has been
    /// failed; FIFO order is preserved within and across batches.
    pub fn pop_batch(&self) -> Option<(Vec<InferRequest>, FlushReason)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // Expire stale requests first (reply while holding the lock is
            // fine: mpsc send never blocks and takes no lock of ours).
            let now = Instant::now();
            let mut i = 0;
            while i < inner.queue.len() {
                if inner.queue[i].expired(now) {
                    if let Some(r) = inner.queue.remove(i) {
                        r.respond_err(InferError::DeadlineExceeded, &self.metrics);
                    }
                } else {
                    i += 1;
                }
            }
            if inner.failed {
                return None;
            }
            if inner.queue.len() >= self.policy.max_batch {
                let batch = drain(&mut inner.queue, self.policy.max_batch);
                self.cv.notify_all(); // submitters may be watching depth
                return Some((batch, FlushReason::Full));
            }
            if !inner.queue.is_empty() {
                let oldest = inner.queue.front().unwrap().submitted_at;
                let elapsed = oldest.elapsed();
                if elapsed >= self.policy.max_wait {
                    let n = inner.queue.len().min(self.policy.max_batch);
                    let batch = drain(&mut inner.queue, n);
                    return Some((batch, FlushReason::Deadline));
                }
                if inner.shutdown {
                    let n = inner.queue.len().min(self.policy.max_batch);
                    return Some((drain(&mut inner.queue, n), FlushReason::Shutdown));
                }
                // Wait out the remaining flush window — or the nearest
                // request deadline, whichever comes first, so expiry replies
                // are prompt even under a long max_wait.
                let mut wait = self.policy.max_wait - elapsed;
                if let Some(dl) = inner.queue.iter().filter_map(|r| r.deadline).min() {
                    wait = wait.min(dl.saturating_duration_since(now));
                }
                let (guard, _) = self
                    .cv
                    .wait_timeout(inner, wait.max(Duration::from_micros(50)))
                    .unwrap();
                inner = guard;
            } else {
                if inner.shutdown {
                    return None;
                }
                inner = self.cv.wait(inner).unwrap();
            }
        }
    }

    /// Stop accepting new work; wake workers to drain the remainder.
    pub fn shutdown(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.shutdown = true;
        self.cv.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.inner.lock().unwrap().shutdown
    }

    /// Flip into the fail-fast state: every queued request is replied
    /// [`InferError::NoWorkers`], later submits refuse with
    /// [`SubmitError::NoWorkers`], and workers blocked in `pop_batch` wake
    /// and exit. Called by the supervisor when the pool is irrecoverably
    /// dead.
    pub fn fail(&self) {
        let drained: Vec<InferRequest> = {
            let mut inner = self.inner.lock().unwrap();
            inner.failed = true;
            self.cv.notify_all();
            inner.queue.drain(..).collect()
        };
        for r in drained {
            r.respond_err(InferError::NoWorkers, &self.metrics);
        }
    }

    pub fn is_failed(&self) -> bool {
        self.inner.lock().unwrap().failed
    }

    /// Teardown sweep: reply `err` to anything still queued. Used by
    /// `Coordinator::shutdown` after the workers have exited, so a pool
    /// that died mid-drain still resolves every outstanding receiver.
    pub fn flush_pending(&self, err: InferError) {
        let drained: Vec<InferRequest> = {
            let mut inner = self.inner.lock().unwrap();
            inner.queue.drain(..).collect()
        };
        for r in drained {
            r.respond_err(err.clone(), &self.metrics);
        }
    }
}

fn drain(q: &mut VecDeque<InferRequest>, n: usize) -> Vec<InferRequest> {
    q.drain(..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferReply;
    use crate::tensor::Tensor;
    use std::sync::mpsc;
    use std::thread;

    fn req(id: u64) -> (InferRequest, mpsc::Receiver<InferReply>) {
        req_ttl(id, None)
    }

    fn req_ttl(id: u64, ttl: Option<Duration>) -> (InferRequest, mpsc::Receiver<InferReply>) {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        (
            InferRequest {
                id,
                image: Tensor::zeros(&[1, 1, 2, 2]),
                submitted_at: now,
                deadline: ttl.map(|d| now + d),
                reply: tx,
            },
            rx,
        )
    }

    fn queue(max_batch: usize, max_wait: Duration, capacity: usize, shed: ShedPolicy) -> BatchQueue {
        BatchQueue::new(
            BatchPolicy { max_batch, max_wait, capacity, shed },
            Arc::new(Metrics::default()),
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let q = queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest);
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
        }
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(reason, FlushReason::Full);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_flush_partial_batch() {
        let q = queue(64, Duration::from_millis(10), 100, ShedPolicy::RejectNewest);
        let (r, _rx) = req(7);
        q.submit(r).unwrap();
        let t0 = Instant::now();
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(reason, FlushReason::Deadline);
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(8), "flushed too early");
    }

    #[test]
    fn backpressure_when_full_reject_newest() {
        let q = queue(8, Duration::from_secs(1), 2, ShedPolicy::RejectNewest);
        let (a, _ra) = req(1);
        let (b, _rb) = req(2);
        let (c, _rc) = req(3);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        assert_eq!(q.submit(c), Err(SubmitError::QueueFull(2)));
    }

    #[test]
    fn drop_oldest_sheds_victim_with_typed_reply() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(1),
                capacity: 2,
                shed: ShedPolicy::DropOldest,
            },
            Arc::clone(&metrics),
        );
        let (a, ra) = req(1);
        let (b, _rb) = req(2);
        let (c, _rc) = req(3);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        q.submit(c).unwrap(); // admitted; request 1 shed
        assert_eq!(q.depth(), 2);
        match ra.try_recv().unwrap() {
            Err(InferError::Shed { reason: ShedReason::DropOldest }) => {}
            other => panic!("expected Shed reply, got {other:?}"),
        }
        assert_eq!(metrics.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
        let (batch, _) = q.pop_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn expired_requests_replied_not_batched() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_secs(1),
                capacity: 100,
                shed: ShedPolicy::RejectNewest,
            },
            Arc::clone(&metrics),
        );
        let (stale, stale_rx) = req_ttl(1, Some(Duration::ZERO));
        let (live, _live_rx) = req(2);
        q.submit(stale).unwrap();
        q.submit(live).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let (batch, _) = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 2, "expired request must not occupy a batch slot");
        assert!(matches!(stale_rx.try_recv().unwrap(), Err(InferError::DeadlineExceeded)));
        assert_eq!(metrics.expired.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn expiry_wakes_before_long_max_wait() {
        // max_wait is 10s but the only request's TTL is 30ms: the worker
        // must wake and reply DeadlineExceeded promptly, not sleep out the
        // flush window.
        let q = Arc::new(queue(64, Duration::from_secs(10), 100, ShedPolicy::RejectNewest));
        let (r, rx) = req_ttl(1, Some(Duration::from_millis(30)));
        q.submit(r).unwrap();
        let qq = Arc::clone(&q);
        let worker = thread::spawn(move || qq.pop_batch());
        let reply = rx.recv_timeout(Duration::from_secs(2)).expect("prompt expiry reply");
        assert!(matches!(reply, Err(InferError::DeadlineExceeded)));
        q.shutdown();
        assert!(worker.join().unwrap().is_none());
    }

    #[test]
    fn fail_flushes_and_refuses() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(BatchPolicy::default(), Arc::clone(&metrics));
        let (a, ra) = req(1);
        let (b, rb) = req(2);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        q.fail();
        assert!(matches!(ra.try_recv().unwrap(), Err(InferError::NoWorkers)));
        assert!(matches!(rb.try_recv().unwrap(), Err(InferError::NoWorkers)));
        let (c, _rc) = req(3);
        assert_eq!(q.submit(c), Err(SubmitError::NoWorkers));
        assert!(q.pop_batch().is_none(), "workers must exit a failed queue");
        assert_eq!(metrics.failed.load(std::sync::atomic::Ordering::Relaxed), 2);
    }

    #[test]
    fn shutdown_drains_then_none() {
        let q = Arc::new(queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest));
        let (r, _rx) = req(1);
        q.submit(r).unwrap();
        q.shutdown();
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Shutdown);
        assert!(q.pop_batch().is_none());
        let (r2, _rx2) = req(2);
        assert_eq!(q.submit(r2), Err(SubmitError::ShutDown));
    }

    #[test]
    fn flush_pending_resolves_stragglers() {
        let q = queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest);
        let (r, rx) = req(1);
        q.submit(r).unwrap();
        q.flush_pending(InferError::ShuttingDown);
        assert!(matches!(rx.try_recv().unwrap(), Err(InferError::ShuttingDown)));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn fifo_across_batches_with_concurrent_worker() {
        let q = Arc::new(queue(3, Duration::from_millis(5), 1000, ShedPolicy::RejectNewest));
        let qq = Arc::clone(&q);
        let collector = thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some((batch, _)) = qq.pop_batch() {
                seen.extend(batch.iter().map(|r| r.id));
            }
            seen
        });
        let mut rxs = Vec::new();
        for i in 0..50 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
            if i % 7 == 0 {
                thread::sleep(Duration::from_millis(1));
            }
        }
        q.shutdown();
        let seen = collector.join().unwrap();
        assert_eq!(seen, (0..50).collect::<Vec<_>>(), "FIFO order violated");
    }
}
