//! Sharded, shape-bucketed, lane-aware batching queue + admission control.
//!
//! The seed design was one `Mutex+Condvar` FIFO; under many submitter
//! threads the submit lock — not GEMM throughput — became the ceiling.
//! This queue is rebuilt for saturation:
//!
//! - **N shards**, each its own `Mutex+Condvar`. A submitting thread is
//!   pinned to one shard (submitter-local pick), so submit contention drops
//!   ~N×. `BatchPolicy::shards` sizes the array.
//! - **Shape buckets**: within a shard, requests group by image shape, and
//!   a formed batch always comes from exactly one bucket — mixed-shape
//!   traffic no longer fragments batches or triggers `ShapeMismatch`
//!   screening in the worker. (One route owns one queue, so the effective
//!   bucket key is `(route, shape)`.)
//! - **Priority lanes**: each shard holds an interactive and a bulk lane
//!   ([`Priority`]). When both lanes have releasable work, interactive
//!   forms first; lane-aware shedding victimizes bulk first, and a bulk
//!   arrival may never evict interactive work.
//! - **Work stealing**: a worker drains its home shard
//!   (`worker % shards`), then steals the *stalest* releasable bucket from
//!   siblings (`BatchPolicy::steal`); an idle stealer re-scans every
//!   [`IDLE_POLL`] so no shard strands behind a busy home worker. With
//!   `steal` off every shard must be some worker's home
//!   (`Coordinator::start` clamps `shards <= workers` in that mode).
//!
//! Release rules per bucket are the classic trade-off, unchanged: a batch
//! is released when the bucket holds `max_batch` requests (throughput) or
//! its oldest request has waited `max_wait` (latency). The queue stays
//! bounded at `capacity` **globally** across shards; at capacity the
//! [`ShedPolicy`] either refuses the newcomer ([`SubmitError::QueueFull`])
//! or evicts the *globally* stalest victim (per-shard heads are compared)
//! with a typed [`InferError::Shed`] reply.
//!
//! All PR-5 semantics survive: deadlines expire inside
//! [`BatchQueue::pop_batch_from`] with [`InferError::DeadlineExceeded`]
//! before batch formation, and [`BatchQueue::fail`] flushes every shard
//! with [`InferError::NoWorkers`] and makes later submits refuse — no
//! request ever hangs on a dead pool. `tests/batch_scale.rs` pins the
//! conservation invariant (every admitted request resolves exactly once)
//! under concurrent submitters × workers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferError, InferRequest, Priority, ShedReason};

/// Why a batch was released (recorded in metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Shutdown,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull(usize),
    ShutDown,
    /// The worker pool is irrecoverably dead (every worker exhausted its
    /// restart budget); the coordinator is in its fail-fast state.
    NoWorkers,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(cap) => write!(f, "queue full (capacity {cap})"),
            SubmitError::ShutDown => write!(f, "coordinator shut down"),
            SubmitError::NoWorkers => write!(f, "no live workers (pool is dead)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What to do with a submission when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the incoming request: `submit` returns
    /// [`SubmitError::QueueFull`] and the caller never gets a receiver.
    RejectNewest,
    /// Admit the incoming request by shedding the globally stalest queued
    /// one; the victim's receiver gets [`InferError::Shed`]. Favors fresh
    /// traffic — the requests most likely to still have a waiting client.
    /// With priority lanes on, victims come from the bulk lane first, and
    /// a bulk arrival may not victimize interactive work (it is refused
    /// with [`SubmitError::QueueFull`] instead).
    DropOldest,
}

impl ShedPolicy {
    /// Parse a CLI-style name (`reject-newest` | `drop-oldest`).
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "reject-newest" => Some(ShedPolicy::RejectNewest),
            "drop-oldest" => Some(ShedPolicy::DropOldest),
            _ => None,
        }
    }
}

/// Batch formation + admission policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Global queue bound, across all shards and lanes.
    pub capacity: usize,
    pub shed: ShedPolicy,
    /// Number of submission shards (>= 1).
    pub shards: usize,
    /// Workers steal releasable buckets from sibling shards when their
    /// home shard has nothing to form. Off: each worker serves only its
    /// home shard (callers must ensure `shards <= workers`).
    pub steal: bool,
    /// Schedule interactive ahead of bulk and shed bulk first. Off: every
    /// request runs in one lane and [`Priority`] is ignored.
    pub priority_lanes: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            capacity: 1024,
            shed: ShedPolicy::RejectNewest,
            shards: 1,
            steal: true,
            priority_lanes: true,
        }
    }
}

/// Floor on condvar waits so a near-zero remainder still yields the lock.
const MIN_WAIT: Duration = Duration::from_micros(50);
/// Re-scan period for an idle worker in multi-shard steal mode: sibling
/// submits notify their own shard only, so a parked stealer polls. Bounded
/// extra latency for stolen work; ~500 empty scans/s/worker when idle.
const IDLE_POLL: Duration = Duration::from_millis(2);
/// Park bound when nothing is queued anywhere in scope. Purely a
/// belt-and-braces backstop — shutdown/fail/submit all notify the condvar.
const PARK: Duration = Duration::from_millis(50);

/// One `(lane, shape)` formation bucket: FIFO within the bucket.
struct Bucket {
    shape: Vec<usize>,
    queue: VecDeque<InferRequest>,
}

struct ShardInner {
    /// `lanes[0]` interactive, `lanes[1]` bulk. Buckets are unordered;
    /// formation picks by head age, not insertion order.
    lanes: [Vec<Bucket>; 2],
    lane_len: [usize; 2],
    len: usize,
}

struct Shard {
    inner: Mutex<ShardInner>,
    cv: Condvar,
}

/// A releasable bucket found during a scan.
struct Candidate {
    lane: usize,
    bucket: usize,
    head: Instant,
    reason: FlushReason,
}

/// Earliest future instant at which something in scope becomes actionable
/// (a bucket crossing `max_wait`, or a request deadline expiring).
#[derive(Default, Clone, Copy)]
struct WaitHint {
    next_event: Option<Instant>,
}

impl WaitHint {
    fn note(&mut self, t: Instant) {
        self.next_event = Some(match self.next_event {
            Some(e) if e <= t => e,
            _ => t,
        });
    }

    fn wait_from(&self, now: Instant) -> Option<Duration> {
        self.next_event.map(|e| e.saturating_duration_since(now).max(MIN_WAIT))
    }
}

static NEXT_SUBMITTER: AtomicUsize = AtomicUsize::new(0);
thread_local! {
    /// Process-wide submitter slot: each submitting thread gets a stable
    /// id on first submit, pinning it to one shard (`slot % shards`).
    static SUBMITTER_SLOT: std::cell::Cell<Option<usize>> = std::cell::Cell::new(None);
}

fn submitter_slot() -> usize {
    SUBMITTER_SLOT.with(|c| match c.get() {
        Some(s) => s,
        None => {
            let s = NEXT_SUBMITTER.fetch_add(1, Ordering::Relaxed);
            c.set(Some(s));
            s
        }
    })
}

/// Thread-safe sharded batching queue shared between submitters and
/// workers.
pub struct BatchQueue {
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    shards: Vec<Shard>,
    /// Global depth; admission control compares it against `capacity`.
    queued: AtomicUsize,
    shutdown: AtomicBool,
    /// Fail-fast: pool irrecoverably dead. Submits refuse, workers exit.
    failed: AtomicBool,
}

impl BatchQueue {
    pub fn new(policy: BatchPolicy, metrics: Arc<Metrics>) -> BatchQueue {
        assert!(policy.max_batch >= 1);
        assert!(policy.shards >= 1, "need at least one shard");
        let shards = (0..policy.shards)
            .map(|_| Shard {
                inner: Mutex::new(ShardInner {
                    lanes: [Vec::new(), Vec::new()],
                    lane_len: [0, 0],
                    len: 0,
                }),
                cv: Condvar::new(),
            })
            .collect();
        BatchQueue {
            policy,
            metrics,
            shards,
            queued: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            failed: AtomicBool::new(false),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Effective lane index for a request under this queue's policy.
    fn lane_of(&self, p: Priority) -> usize {
        if self.policy.priority_lanes {
            p.lane()
        } else {
            0
        }
    }

    /// Enqueue on the submitter-local shard. At capacity the [`ShedPolicy`]
    /// applies; fails when shut down or the pool is dead.
    pub fn submit(&self, req: InferRequest) -> Result<(), SubmitError> {
        self.submit_to(submitter_slot() % self.shards.len(), req)
    }

    /// Targeted submit for tests and benchmarks that need deterministic
    /// placement; production callers want [`BatchQueue::submit`].
    pub fn submit_to(&self, shard: usize, req: InferRequest) -> Result<(), SubmitError> {
        if self.failed.load(Ordering::Acquire) {
            return Err(SubmitError::NoWorkers);
        }
        if self.shutdown.load(Ordering::Acquire) {
            return Err(SubmitError::ShutDown);
        }
        let lane = self.lane_of(req.priority);
        // Admission control against the global bound. The load is racy
        // across shards (exact when submission is single-threaded); the
        // bound can transiently overshoot by at most the number of
        // concurrent submitters.
        let victim = if self.queued.load(Ordering::Acquire) >= self.policy.capacity {
            match self.policy.shed {
                ShedPolicy::RejectNewest => {
                    return Err(SubmitError::QueueFull(self.policy.capacity))
                }
                ShedPolicy::DropOldest => {
                    let v = self.evict_stalest(lane);
                    if v.is_none() {
                        // Nothing this lane may victimize (e.g. a bulk
                        // arrival with only interactive queued): refuse.
                        return Err(SubmitError::QueueFull(self.policy.capacity));
                    }
                    v
                }
            }
        } else {
            None
        };
        let res = {
            let mut g = self.shards[shard].inner.lock().unwrap();
            // Re-check lifecycle under the shard lock: fail()/shutdown()
            // raise the flag before sweeping the shards, so a submit that
            // lost the race must refuse rather than strand a request in an
            // already-swept shard.
            if self.failed.load(Ordering::Acquire) {
                Err(SubmitError::NoWorkers)
            } else if self.shutdown.load(Ordering::Acquire) {
                Err(SubmitError::ShutDown)
            } else {
                let inner = &mut *g;
                let shape = req.image.shape().to_vec();
                match inner.lanes[lane].iter_mut().find(|b| b.shape == shape) {
                    Some(b) => b.queue.push_back(req),
                    None => {
                        let mut queue = VecDeque::new();
                        queue.push_back(req);
                        inner.lanes[lane].push(Bucket { shape, queue });
                        self.metrics.bucket_opened();
                    }
                }
                inner.lane_len[lane] += 1;
                inner.len += 1;
                self.queued.fetch_add(1, Ordering::AcqRel);
                self.shards[shard].cv.notify_one();
                Ok(())
            }
        };
        if res.is_ok() {
            self.metrics.lane_submitted[lane].fetch_add(1, Ordering::Relaxed);
        }
        // Reply to the shed victim outside the lock. Even if the push
        // itself was refused, the victim was already evicted and owes its
        // receiver a reply.
        if let Some(v) = victim {
            self.metrics.lane_shed[self.lane_of(v.priority)].fetch_add(1, Ordering::Relaxed);
            v.respond_err(InferError::Shed { reason: ShedReason::DropOldest }, &self.metrics);
        }
        res
    }

    /// Evict the globally stalest queued request for a newcomer in
    /// `incoming_lane`. Victim lanes: bulk first, then interactive — but a
    /// bulk arrival may only victimize bulk. Compares per-shard heads,
    /// locking one shard at a time.
    fn evict_stalest(&self, incoming_lane: usize) -> Option<InferRequest> {
        let order: &[usize] = if !self.policy.priority_lanes {
            &[0]
        } else if incoming_lane == 0 {
            &[1, 0]
        } else {
            &[1]
        };
        for &lane in order {
            loop {
                let mut best: Option<(usize, Instant)> = None;
                for (sid, shard) in self.shards.iter().enumerate() {
                    let g = shard.inner.lock().unwrap();
                    if let Some(h) = stalest_head(&g, lane) {
                        if best.map_or(true, |(_, bh)| h < bh) {
                            best = Some((sid, h));
                        }
                    }
                }
                let Some((sid, _)) = best else { break };
                let mut g = self.shards[sid].inner.lock().unwrap();
                match self.pop_stalest_locked(&mut g, lane) {
                    Some(v) => return Some(v),
                    // Raced with a pop on that shard; re-scan the lane.
                    None => continue,
                }
            }
        }
        None
    }

    /// Pop the stalest request in `lane` from a locked shard, maintaining
    /// counters and bucket lifecycle.
    fn pop_stalest_locked(&self, inner: &mut ShardInner, lane: usize) -> Option<InferRequest> {
        let bi = inner.lanes[lane]
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.queue.front().map(|r| (i, r.submitted_at)))
            .min_by_key(|&(_, t)| t)
            .map(|(i, _)| i)?;
        let victim = inner.lanes[lane][bi].queue.pop_front()?;
        if inner.lanes[lane][bi].queue.is_empty() {
            inner.lanes[lane].swap_remove(bi);
            self.metrics.bucket_closed();
        }
        inner.lane_len[lane] -= 1;
        inner.len -= 1;
        self.queued.fetch_sub(1, Ordering::AcqRel);
        Some(victim)
    }

    /// Current global depth (approximate under concurrency).
    pub fn depth(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Per-shard depths (each shard locked briefly in turn).
    pub fn shard_depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.inner.lock().unwrap().len).collect()
    }

    /// Queued requests per lane `[interactive, bulk]` across all shards.
    pub fn lane_depths(&self) -> [usize; 2] {
        let mut out = [0usize; 2];
        for s in &self.shards {
            let g = s.inner.lock().unwrap();
            out[0] += g.lane_len[0];
            out[1] += g.lane_len[1];
        }
        out
    }

    /// Compatibility wrapper: pop as the worker homed on shard 0.
    pub fn pop_batch(&self) -> Option<(Vec<InferRequest>, FlushReason)> {
        self.pop_batch_from(0)
    }

    /// Block until a batch can be formed for worker `worker` (home shard
    /// `worker % shards`, then — with stealing on — the stalest releasable
    /// bucket among siblings), the wait window of the oldest relevant
    /// request expires, or shutdown. Expired requests are replied
    /// [`InferError::DeadlineExceeded`] during every scan and never occupy
    /// batch slots. Returns `None` when shut down *and* the worker's scope
    /// is drained, or when the pool has been failed. FIFO order holds
    /// within a bucket.
    pub fn pop_batch_from(&self, worker: usize) -> Option<(Vec<InferRequest>, FlushReason)> {
        let nshards = self.shards.len();
        let home = worker % nshards;
        let stealing = self.policy.steal && nshards > 1;
        loop {
            if self.failed.load(Ordering::Acquire) {
                return None;
            }
            let shutdown = self.shutdown.load(Ordering::Acquire);
            let now = Instant::now();
            let mut hint = WaitHint::default();
            // Home shard first.
            {
                let mut g = self.shards[home].inner.lock().unwrap();
                let inner = &mut *g;
                self.expire_locked(inner, now);
                if let Some(c) = self.best_candidate(inner, now, shutdown, &mut hint) {
                    let batch = self.take_candidate(inner, &c);
                    return Some((batch, c.reason));
                }
            }
            // Steal pass 1: peek every sibling for its best releasable
            // bucket; remember the stalest (interactive outranks bulk).
            if stealing {
                let mut best: Option<(usize, usize, Instant)> = None;
                for off in 1..nshards {
                    let sid = (home + off) % nshards;
                    let mut g = self.shards[sid].inner.lock().unwrap();
                    let inner = &mut *g;
                    self.expire_locked(inner, now);
                    if let Some(c) = self.best_candidate(inner, now, shutdown, &mut hint) {
                        if best.map_or(true, |(_, l, h)| (c.lane, c.head) < (l, h)) {
                            best = Some((sid, c.lane, c.head));
                        }
                    }
                }
                // Pass 2: re-derive under the winner's lock (the bucket may
                // have been taken meanwhile — then rescan from the top).
                if let Some((sid, _, _)) = best {
                    let mut g = self.shards[sid].inner.lock().unwrap();
                    let inner = &mut *g;
                    let now2 = Instant::now();
                    self.expire_locked(inner, now2);
                    let mut scratch = WaitHint::default();
                    if let Some(c) = self.best_candidate(inner, now2, shutdown, &mut scratch) {
                        let batch = self.take_candidate(inner, &c);
                        self.metrics.steals.fetch_add(1, Ordering::Relaxed);
                        return Some((batch, c.reason));
                    }
                    continue;
                }
            }
            // Nothing releasable in scope.
            if shutdown {
                if self.queued.load(Ordering::Acquire) == 0 {
                    return None;
                }
                if !stealing && self.shards[home].inner.lock().unwrap().len == 0 {
                    // Leftovers belong to other workers' home shards (or to
                    // the final flush_pending sweep).
                    return None;
                }
                // Releasable work exists in scope (shutdown makes every
                // non-empty bucket releasable); rescan.
                continue;
            }
            // Park on the home condvar. The candidate check re-runs under
            // the lock so a submit racing the scan can't be slept through;
            // sibling-shard arrivals are covered by the IDLE_POLL bound.
            let mut g = self.shards[home].inner.lock().unwrap();
            if self.shutdown.load(Ordering::Acquire) || self.failed.load(Ordering::Acquire) {
                continue;
            }
            let inner = &mut *g;
            let now2 = Instant::now();
            if self.best_candidate(inner, now2, false, &mut hint).is_none() {
                let mut wait = hint.wait_from(now2).unwrap_or(PARK);
                if stealing {
                    wait = wait.min(IDLE_POLL);
                }
                let _ = self.shards[home].cv.wait_timeout(g, wait).unwrap();
            }
        }
    }

    /// Reply `DeadlineExceeded` to every expired request in a locked shard
    /// (mpsc send never blocks and takes no lock of ours).
    fn expire_locked(&self, inner: &mut ShardInner, now: Instant) {
        for lane in 0..2 {
            let mut bi = 0;
            while bi < inner.lanes[lane].len() {
                let mut removed = 0;
                {
                    let q = &mut inner.lanes[lane][bi].queue;
                    let mut i = 0;
                    while i < q.len() {
                        if q[i].expired(now) {
                            if let Some(r) = q.remove(i) {
                                r.respond_err(InferError::DeadlineExceeded, &self.metrics);
                                removed += 1;
                            }
                        } else {
                            i += 1;
                        }
                    }
                }
                inner.lane_len[lane] -= removed;
                inner.len -= removed;
                if removed > 0 {
                    self.queued.fetch_sub(removed, Ordering::AcqRel);
                }
                if inner.lanes[lane][bi].queue.is_empty() {
                    inner.lanes[lane].swap_remove(bi);
                    self.metrics.bucket_closed();
                } else {
                    bi += 1;
                }
            }
        }
    }

    /// Find the bucket to form next in a locked shard: interactive lane
    /// outranks bulk; within a lane, the stalest releasable bucket wins.
    /// Non-releasable buckets contribute their release/deadline instants
    /// to `hint` so the caller knows how long it may park.
    fn best_candidate(
        &self,
        inner: &ShardInner,
        now: Instant,
        shutdown: bool,
        hint: &mut WaitHint,
    ) -> Option<Candidate> {
        for lane in 0..2 {
            let mut best: Option<Candidate> = None;
            for (bi, b) in inner.lanes[lane].iter().enumerate() {
                let Some(head) = b.queue.front() else { continue };
                let head_t = head.submitted_at;
                let reason = if b.queue.len() >= self.policy.max_batch {
                    FlushReason::Full
                } else if now.saturating_duration_since(head_t) >= self.policy.max_wait {
                    FlushReason::Deadline
                } else if shutdown {
                    FlushReason::Shutdown
                } else {
                    hint.note(head_t + self.policy.max_wait);
                    for r in &b.queue {
                        if let Some(d) = r.deadline {
                            hint.note(d);
                        }
                    }
                    continue;
                };
                if best.as_ref().map_or(true, |c| head_t < c.head) {
                    best = Some(Candidate { lane, bucket: bi, head: head_t, reason });
                }
            }
            if best.is_some() {
                return best;
            }
        }
        None
    }

    /// Drain up to `max_batch` from the candidate bucket, maintaining
    /// counters and removing the bucket if emptied.
    fn take_candidate(&self, inner: &mut ShardInner, c: &Candidate) -> Vec<InferRequest> {
        let (batch, emptied) = {
            let bucket = &mut inner.lanes[c.lane][c.bucket];
            let n = bucket.queue.len().min(self.policy.max_batch);
            let batch: Vec<InferRequest> = bucket.queue.drain(..n).collect();
            (batch, bucket.queue.is_empty())
        };
        if emptied {
            inner.lanes[c.lane].swap_remove(c.bucket);
            self.metrics.bucket_closed();
        }
        inner.lane_len[c.lane] -= batch.len();
        inner.len -= batch.len();
        self.queued.fetch_sub(batch.len(), Ordering::AcqRel);
        batch
    }

    /// Stop accepting new work; wake workers everywhere to drain the
    /// remainder.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            let _g = shard.inner.lock().unwrap();
            shard.cv.notify_all();
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Flip into the fail-fast state: every queued request in every shard
    /// is replied [`InferError::NoWorkers`], later submits refuse with
    /// [`SubmitError::NoWorkers`], and workers blocked in
    /// [`BatchQueue::pop_batch_from`] wake and exit. Called by the
    /// supervisor when the pool is irrecoverably dead.
    pub fn fail(&self) {
        self.failed.store(true, Ordering::SeqCst);
        for r in self.drain_all(true) {
            r.respond_err(InferError::NoWorkers, &self.metrics);
        }
    }

    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// Teardown sweep: reply `err` to anything still queued in any shard.
    /// Used by `Coordinator::shutdown` after the workers have exited, so a
    /// pool that died mid-drain still resolves every outstanding receiver.
    pub fn flush_pending(&self, err: InferError) {
        for r in self.drain_all(false) {
            r.respond_err(err.clone(), &self.metrics);
        }
    }

    fn drain_all(&self, notify: bool) -> Vec<InferRequest> {
        let mut drained = Vec::new();
        for shard in &self.shards {
            let mut g = shard.inner.lock().unwrap();
            let inner = &mut *g;
            for lane in 0..2 {
                for b in inner.lanes[lane].iter_mut() {
                    drained.extend(b.queue.drain(..));
                    self.metrics.bucket_closed();
                }
                inner.lanes[lane].clear();
                inner.lane_len[lane] = 0;
            }
            self.queued.fetch_sub(inner.len, Ordering::AcqRel);
            inner.len = 0;
            if notify {
                shard.cv.notify_all();
            }
        }
        drained
    }
}

fn stalest_head(inner: &ShardInner, lane: usize) -> Option<Instant> {
    inner.lanes[lane]
        .iter()
        .filter_map(|b| b.queue.front().map(|r| r.submitted_at))
        .min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferReply;
    use crate::tensor::Tensor;
    use std::sync::mpsc;
    use std::thread;

    fn req(id: u64) -> (InferRequest, mpsc::Receiver<InferReply>) {
        req_full(id, None, Priority::Interactive, &[1, 1, 2, 2])
    }

    fn req_ttl(id: u64, ttl: Option<Duration>) -> (InferRequest, mpsc::Receiver<InferReply>) {
        req_full(id, ttl, Priority::Interactive, &[1, 1, 2, 2])
    }

    fn req_pri(id: u64, p: Priority) -> (InferRequest, mpsc::Receiver<InferReply>) {
        req_full(id, None, p, &[1, 1, 2, 2])
    }

    fn req_shape(id: u64, shape: &[usize]) -> (InferRequest, mpsc::Receiver<InferReply>) {
        req_full(id, None, Priority::Interactive, shape)
    }

    fn req_full(
        id: u64,
        ttl: Option<Duration>,
        priority: Priority,
        shape: &[usize],
    ) -> (InferRequest, mpsc::Receiver<InferReply>) {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        (
            InferRequest {
                id,
                image: Tensor::zeros(shape),
                submitted_at: now,
                deadline: ttl.map(|d| now + d),
                priority,
                reply: tx,
                recycle: None,
            },
            rx,
        )
    }

    fn queue(max_batch: usize, max_wait: Duration, capacity: usize, shed: ShedPolicy) -> BatchQueue {
        BatchQueue::new(
            BatchPolicy { max_batch, max_wait, capacity, shed, ..BatchPolicy::default() },
            Arc::new(Metrics::default()),
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let q = queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest);
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
        }
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(reason, FlushReason::Full);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_flush_partial_batch() {
        let q = queue(64, Duration::from_millis(10), 100, ShedPolicy::RejectNewest);
        let (r, _rx) = req(7);
        q.submit(r).unwrap();
        let t0 = Instant::now();
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(reason, FlushReason::Deadline);
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(8), "flushed too early");
    }

    #[test]
    fn backpressure_when_full_reject_newest() {
        let q = queue(8, Duration::from_secs(1), 2, ShedPolicy::RejectNewest);
        let (a, _ra) = req(1);
        let (b, _rb) = req(2);
        let (c, _rc) = req(3);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        assert_eq!(q.submit(c), Err(SubmitError::QueueFull(2)));
    }

    #[test]
    fn drop_oldest_sheds_victim_with_typed_reply() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(1),
                capacity: 2,
                shed: ShedPolicy::DropOldest,
                ..BatchPolicy::default()
            },
            Arc::clone(&metrics),
        );
        let (a, ra) = req(1);
        let (b, _rb) = req(2);
        let (c, _rc) = req(3);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        q.submit(c).unwrap(); // admitted; request 1 shed
        assert_eq!(q.depth(), 2);
        match ra.try_recv().unwrap() {
            Err(InferError::Shed { reason: ShedReason::DropOldest }) => {}
            other => panic!("expected Shed reply, got {other:?}"),
        }
        assert_eq!(metrics.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.lane_shed[0].load(std::sync::atomic::Ordering::Relaxed), 1);
        let (batch, _) = q.pop_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn expired_requests_replied_not_batched() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_secs(1),
                capacity: 100,
                shed: ShedPolicy::RejectNewest,
                ..BatchPolicy::default()
            },
            Arc::clone(&metrics),
        );
        let (stale, stale_rx) = req_ttl(1, Some(Duration::ZERO));
        let (live, _live_rx) = req(2);
        q.submit(stale).unwrap();
        q.submit(live).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let (batch, _) = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 2, "expired request must not occupy a batch slot");
        assert!(matches!(stale_rx.try_recv().unwrap(), Err(InferError::DeadlineExceeded)));
        assert_eq!(metrics.expired.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn expiry_wakes_before_long_max_wait() {
        // max_wait is 10s but the only request's TTL is 30ms: the worker
        // must wake and reply DeadlineExceeded promptly, not sleep out the
        // flush window.
        let q = Arc::new(queue(64, Duration::from_secs(10), 100, ShedPolicy::RejectNewest));
        let (r, rx) = req_ttl(1, Some(Duration::from_millis(30)));
        q.submit(r).unwrap();
        let qq = Arc::clone(&q);
        let worker = thread::spawn(move || qq.pop_batch());
        let reply = rx.recv_timeout(Duration::from_secs(2)).expect("prompt expiry reply");
        assert!(matches!(reply, Err(InferError::DeadlineExceeded)));
        q.shutdown();
        assert!(worker.join().unwrap().is_none());
    }

    #[test]
    fn fail_flushes_and_refuses() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(BatchPolicy::default(), Arc::clone(&metrics));
        let (a, ra) = req(1);
        let (b, rb) = req(2);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        q.fail();
        assert!(matches!(ra.try_recv().unwrap(), Err(InferError::NoWorkers)));
        assert!(matches!(rb.try_recv().unwrap(), Err(InferError::NoWorkers)));
        let (c, _rc) = req(3);
        assert_eq!(q.submit(c), Err(SubmitError::NoWorkers));
        assert!(q.pop_batch().is_none(), "workers must exit a failed queue");
        assert_eq!(metrics.failed.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn shutdown_drains_then_none() {
        let q = Arc::new(queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest));
        let (r, _rx) = req(1);
        q.submit(r).unwrap();
        q.shutdown();
        let (batch, reason) = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Shutdown);
        assert!(q.pop_batch().is_none());
        let (r2, _rx2) = req(2);
        assert_eq!(q.submit(r2), Err(SubmitError::ShutDown));
    }

    #[test]
    fn flush_pending_resolves_stragglers() {
        let q = queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest);
        let (r, rx) = req(1);
        q.submit(r).unwrap();
        q.flush_pending(InferError::ShuttingDown);
        assert!(matches!(rx.try_recv().unwrap(), Err(InferError::ShuttingDown)));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn fifo_across_batches_with_concurrent_worker() {
        // One shard + one shape = one bucket: FIFO must hold across batch
        // boundaries exactly as in the single-queue design.
        let q = Arc::new(queue(3, Duration::from_millis(5), 1000, ShedPolicy::RejectNewest));
        let qq = Arc::clone(&q);
        let collector = thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some((batch, _)) = qq.pop_batch() {
                seen.extend(batch.iter().map(|r| r.id));
            }
            seen
        });
        let mut rxs = Vec::new();
        for i in 0..50 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
            if i % 7 == 0 {
                thread::sleep(Duration::from_millis(1));
            }
        }
        q.shutdown();
        let seen = collector.join().unwrap();
        assert_eq!(seen, (0..50).collect::<Vec<_>>(), "FIFO order violated");
    }

    #[test]
    fn buckets_keep_batches_shape_homogeneous() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(10),
                ..BatchPolicy::default()
            },
            Arc::clone(&metrics),
        );
        // Interleave two shapes; each pop must come from one bucket.
        for i in 0..4 {
            let (a, _ra) = req_shape(2 * i, &[1, 1, 2, 2]);
            q.submit(a).unwrap();
            let (b, _rb) = req_shape(2 * i + 1, &[1, 1, 3, 3]);
            q.submit(b).unwrap();
        }
        assert_eq!(metrics.open_buckets.load(std::sync::atomic::Ordering::Relaxed), 2);
        let (first, r1) = q.pop_batch().unwrap();
        assert_eq!(r1, FlushReason::Full);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4, 6]);
        assert_eq!(first[0].image.shape(), &[1, 1, 2, 2]);
        let (second, _) = q.pop_batch().unwrap();
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 5, 7]);
        assert_eq!(second[0].image.shape(), &[1, 1, 3, 3]);
        assert_eq!(metrics.open_buckets.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(metrics.peak_buckets.load(std::sync::atomic::Ordering::Relaxed), 2);
    }

    #[test]
    fn interactive_forms_before_older_bulk() {
        let q = queue(4, Duration::from_secs(10), 100, ShedPolicy::RejectNewest);
        for i in 0..4 {
            let (b, _rb) = req_pri(i, Priority::Bulk);
            q.submit(b).unwrap();
        }
        for i in 4..8 {
            let (r, _rr) = req_pri(i, Priority::Interactive);
            q.submit(r).unwrap();
        }
        // Both lanes hold a full bucket; the bulk one is older, but the
        // interactive lane must form first.
        let (first, _) = q.pop_batch().unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        let (second, _) = q.pop_batch().unwrap();
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(q.lane_depths(), [0, 0]);
    }

    #[test]
    fn priority_lanes_off_ignores_priority() {
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_secs(10),
                priority_lanes: false,
                ..BatchPolicy::default()
            },
            Arc::new(Metrics::default()),
        );
        let (b, _rb) = req_pri(0, Priority::Bulk);
        let (i, _ri) = req_pri(1, Priority::Interactive);
        q.submit(b).unwrap();
        q.submit(i).unwrap();
        // One lane: strict arrival order, bulk first.
        let (batch, _) = q.pop_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn steal_drains_sibling_shard() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_secs(10),
                shards: 2,
                ..BatchPolicy::default()
            },
            Arc::clone(&metrics),
        );
        let (r, _rx) = req(1);
        q.submit_to(0, r).unwrap();
        assert_eq!(q.shard_depths(), vec![1, 0]);
        // Worker 1's home is shard 1 (empty): it must steal from shard 0.
        let (batch, reason) = q.pop_batch_from(1).unwrap();
        assert_eq!(batch[0].id, 1);
        assert_eq!(reason, FlushReason::Full);
        assert_eq!(metrics.steals.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn steal_prefers_stalest_sibling_bucket() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_secs(10),
                shards: 3,
                ..BatchPolicy::default()
            },
            Arc::clone(&metrics),
        );
        let (a, _ra) = req(1); // older
        std::thread::sleep(Duration::from_millis(2));
        let (b, _rb) = req(2); // newer
        q.submit_to(2, b).unwrap();
        q.submit_to(1, a).unwrap();
        // Worker 0's home (shard 0) is empty; between shards 1 and 2 it
        // must steal the stalest head: request 1 in shard 1.
        let (batch, _) = q.pop_batch_from(0).unwrap();
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn drop_oldest_evicts_globally_stalest_across_shards() {
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(1),
                capacity: 2,
                shed: ShedPolicy::DropOldest,
                shards: 2,
                ..BatchPolicy::default()
            },
            Arc::new(Metrics::default()),
        );
        let (a, ra) = req(1); // oldest, lands in shard 0
        std::thread::sleep(Duration::from_millis(2));
        let (b, _rb) = req(2);
        let (c, _rc) = req(3);
        q.submit_to(0, a).unwrap();
        q.submit_to(1, b).unwrap();
        q.submit_to(1, c).unwrap(); // at capacity: must evict request 1 from shard 0
        assert!(matches!(
            ra.try_recv().unwrap(),
            Err(InferError::Shed { reason: ShedReason::DropOldest })
        ));
        assert_eq!(q.depth(), 2);
        assert_eq!(q.shard_depths(), vec![0, 2]);
    }

    #[test]
    fn lane_aware_shed_victimizes_bulk_first() {
        let metrics = Arc::new(Metrics::default());
        let q = BatchQueue::new(
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(1),
                capacity: 2,
                shed: ShedPolicy::DropOldest,
                ..BatchPolicy::default()
            },
            Arc::clone(&metrics),
        );
        let (i1, _ri1) = req_pri(1, Priority::Interactive);
        let (b1, rb1) = req_pri(2, Priority::Bulk);
        q.submit(i1).unwrap();
        q.submit(b1).unwrap();
        // Interactive arrival at capacity: the bulk request is the victim
        // even though the interactive one is older.
        let (i2, _ri2) = req_pri(3, Priority::Interactive);
        q.submit(i2).unwrap();
        assert!(matches!(
            rb1.try_recv().unwrap(),
            Err(InferError::Shed { reason: ShedReason::DropOldest })
        ));
        assert_eq!(metrics.lane_shed[1].load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(q.lane_depths(), [2, 0]);
        // Bulk arrival with only interactive queued: refused, never evicts
        // the interactive lane — even under drop-oldest.
        let (b2, _rb2) = req_pri(4, Priority::Bulk);
        assert_eq!(q.submit(b2), Err(SubmitError::QueueFull(2)));
        assert_eq!(q.lane_depths(), [2, 0]);
        assert_eq!(metrics.lane_shed[0].load(std::sync::atomic::Ordering::Relaxed), 0);
    }
}
