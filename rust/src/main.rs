//! `lqr` — CLI for the Local Quantization Region inference stack.
//!
//! Subcommands (one per workflow; see `lqr help`):
//!   serve      run the serving coordinator over a model variant
//!   classify   classify validation images through a PJRT artifact
//!   accuracy   accuracy sweeps (Tables 1-2 / Figs. 9-10)
//!   opcount    analytic op counts (Table 3)
//!   fpga       FPGA resource/perf/power model (Tables 4-5)
//!   speedup    f32 vs fixed-point runtime (Fig. 8)
//!   info       artifact manifest + architecture summary

use std::time::Duration;

use anyhow::Result;

use lqr::coordinator::backend::{Backend, PjrtBackend};
use lqr::coordinator::{Coordinator, CoordinatorConfig, ShedPolicy};
use lqr::dataset::Dataset;
use lqr::eval::sweep;
use lqr::nn::Arch;
use lqr::runtime::Manifest;
use lqr::util::cli::Args;
use lqr::util::rng::Rng;

fn main() {
    lqr::util::logging::init();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&argv),
        "serve-tcp" => cmd_serve_tcp(&argv),
        "quantize" => cmd_quantize(&argv),
        "classify" => cmd_classify(&argv),
        "accuracy" => cmd_accuracy(&argv),
        "opcount" => cmd_opcount(),
        "fpga" => cmd_fpga(),
        "speedup" => cmd_speedup(&argv),
        "info" => cmd_info(&argv),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
lqr — Local Quantization Region inference stack (Yang et al. 2018 reproduction)

USAGE: lqr <command> [flags]

COMMANDS:
  serve      run the serving coordinator (dynamic batching over PJRT artifacts)
  serve-tcp  expose the coordinator over the TCP wire protocol
  quantize   quantize a trained model offline into a .lqz deploy artifact
  classify   classify validation images through one artifact
  accuracy   accuracy sweeps: DQ vs LQ, bit widths, region sizes
  opcount    Table 3 analytic op counts (full AlexNet / VGG-16)
  fpga       Tables 4-5 FPGA matrix-multiplier model
  speedup    Fig. 8 f32 vs 8-bit per-image runtime
  info       list artifacts and architectures

Run `lqr <command> --help` for flags.
";

fn cmd_serve(argv: &[String]) -> Result<()> {
    let p = Args::new("lqr serve", "serve a model variant with dynamic batching")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("model", "minialexnet", "model name")
        .flag("variant", "f32", "artifact variant: f32 | lq")
        .flag("workers", "1", "worker threads (each owns a PJRT session)")
        .flag("max-batch", "8", "dynamic batch size cap")
        .flag("max-wait-ms", "5", "batch deadline in milliseconds")
        .flag("deadline-ms", "0", "per-request TTL in milliseconds (0 = no deadline)")
        .flag("watchdog-grace-ms", "0", "kill a worker wedged past deadline+grace (0 = off)")
        .flag("shed", "reject-newest", "overload policy: reject-newest | drop-oldest")
        .flag("shards", "0", "submission queue shards (0 = one per worker)")
        .flag("steal", "true", "idle workers steal stale buckets from sibling shards")
        .flag("priority-lanes", "true", "interactive lane forms first, bulk sheds first")
        .flag("rate", "200", "request arrival rate (Poisson, req/s)")
        .flag("requests", "500", "total requests to send")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;

    let artifacts = p.get("artifacts").to_string();
    let model = p.get("model").to_string();
    let variant = p.get("variant").to_string();
    let shed = ShedPolicy::parse(p.get("shed"))
        .ok_or_else(|| anyhow::anyhow!("--shed must be reject-newest or drop-oldest"))?;
    let deadline_ms = p.get_u64("deadline-ms");
    let watchdog_ms = p.get_u64("watchdog-grace-ms");
    let cfg = CoordinatorConfig {
        workers: p.get_usize("workers"),
        max_batch: p.get_usize("max-batch"),
        max_wait: Duration::from_millis(p.get_u64("max-wait-ms")),
        queue_capacity: 4096,
        shed,
        default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        watchdog_grace: (watchdog_ms > 0).then(|| Duration::from_millis(watchdog_ms)),
        shards: p.get_usize("shards"),
        steal: p.get_bool("steal"),
        priority_lanes: p.get_bool("priority-lanes"),
        ..Default::default()
    };
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?;
    let (a2, m2, v2) = (artifacts.clone(), model.clone(), variant.clone());
    let coord = Coordinator::start(
        cfg,
        Box::new(move || Ok(Box::new(PjrtBackend::open(&a2, &m2, &v2)?) as Box<dyn Backend>)),
    )?;

    let rate = p.get_f64("rate");
    let total = p.get_usize("requests");
    println!("serving {model}/{variant}: {total} requests @ {rate} req/s (Poisson)");
    let mut rng = Rng::new(7);
    let mut rxs = Vec::with_capacity(total);
    let mut labels = Vec::with_capacity(total);
    let t0 = std::time::Instant::now();
    for _ in 0..total {
        let i = ds.sample(&mut rng);
        labels.push(ds.labels[i]);
        loop {
            match coord.submit(ds.image(i)) {
                Ok(rx) => {
                    rxs.push(rx);
                    break;
                }
                // Backpressure: wait for the queue to drain a little.
                Err(lqr::coordinator::SubmitError::QueueFull(_)) => {
                    std::thread::sleep(Duration::from_micros(200))
                }
                // Shut down / dead pool: retrying can never succeed.
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut hits = 0usize;
    let mut errors = 0usize;
    for (rx, label) in rxs.into_iter().zip(labels) {
        match rx.recv()? {
            Ok(resp) => {
                if resp.predicted as i32 == label {
                    hits += 1;
                }
            }
            // Typed failure (shed / expired / backend): counted, not fatal.
            Err(_) => errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.shutdown();
    println!(
        "done in {wall:.2}s  throughput={:.1} req/s  accuracy={:.1}%  errors={errors}",
        total as f64 / wall,
        100.0 * hits as f64 / total as f64
    );
    println!("{}", m.summary());
    Ok(())
}

fn cmd_serve_tcp(argv: &[String]) -> Result<()> {
    use lqr::coordinator::backend::shared_native_factory;
    use lqr::coordinator::net::{ImageSpec, NetConfig, NetServer};
    use lqr::coordinator::router::Router;
    use lqr::nn::{Engine, Precision};
    use std::sync::Arc;
    use std::time::Instant;

    let p = Args::new("lqr serve-tcp", "serve models over the TCP wire protocol")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("listen", "127.0.0.1:7423", "bind address")
        .flag("models", "minialexnet,minivgg", "models to route (comma list)")
        .flag("variants", "f32,lq", "artifact variants per model (comma list)")
        .flag("backend", "pjrt", "pjrt (AOT artifacts) | native (one shared in-process engine)")
        .flag("native-bits", "2", "activation bits for --backend native (weights stay 8-bit)")
        .flag("workers", "1", "workers per route")
        .flag("max-batch", "8", "dynamic batch cap")
        .flag("max-wait-ms", "5", "batch deadline (ms)")
        .flag("deadline-ms", "0", "per-request TTL in milliseconds (0 = no deadline)")
        .flag("watchdog-grace-ms", "0", "kill a worker wedged past deadline+grace (0 = off)")
        .flag("shards", "0", "submission queue shards per route (0 = one per worker)")
        .flag("steal", "true", "idle workers steal stale buckets from sibling shards")
        .flag("priority-lanes", "true", "interactive lane forms first, bulk sheds first")
        .flag("max-conns", "64", "handler pool size; excess connections get a Busy reply")
        .flag("io-timeout-ms", "10000", "per-connection read/write timeout (0 = no timeout)")
        .flag("max-frame-bytes", "16777216", "hard cap on one request frame's total bytes")
        .flag("drain-ms", "5000", "shutdown drain deadline for in-flight requests")
        .flag("duration", "30", "seconds to serve before shutdown (0 = forever)")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;

    let artifacts = p.get("artifacts").to_string();
    let manifest = Manifest::load(&artifacts)?;
    let mut router = Router::new();
    let deadline_ms = p.get_u64("deadline-ms");
    let watchdog_ms = p.get_u64("watchdog-grace-ms");
    let coord_cfg = || CoordinatorConfig {
        workers: p.get_usize("workers"),
        max_batch: p.get_usize("max-batch"),
        max_wait: Duration::from_millis(p.get_u64("max-wait-ms")),
        queue_capacity: 4096,
        default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        watchdog_grace: (watchdog_ms > 0).then(|| Duration::from_millis(watchdog_ms)),
        shards: p.get_usize("shards"),
        steal: p.get_bool("steal"),
        priority_lanes: p.get_bool("priority-lanes"),
        ..Default::default()
    };
    let backend = p.get("backend").to_string();
    for model in p.get("models").split(',') {
        let meta = manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        let _ = meta;
        if backend == "native" {
            // One engine per model, loaded once (copy-free npz path) and
            // shared across every worker; the factory pre-warms the panel
            // cache so no request ever pays quantize+pack latency.
            let bits = p.get_usize("native-bits") as u8;
            let arch = Arch::by_name(model)
                .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
            let t0 = Instant::now();
            let engine =
                Arc::new(Engine::from_npz(arch, format!("{artifacts}/weights_{model}.npz"))?);
            let load_ms = t0.elapsed().as_secs_f64() * 1e3;
            let (factory, warmed) = shared_native_factory(Arc::clone(&engine), Precision::lq(bits));
            let route = format!("{model}/lq{bits}");
            let eng_status = Arc::clone(&engine);
            router.add_route_with_status(
                &route,
                coord_cfg(),
                factory,
                Box::new(move || {
                    let s = eng_status.panel_stats();
                    format!("warmed panels={} panel_bytes={}", s.panels, s.bytes)
                }),
            )?;
            println!(
                "route {route} (shared engine: load {load_ms:.1}ms, warmed {warmed} panels, {} panel bytes)",
                engine.panel_stats().bytes
            );
            continue;
        }
        anyhow::ensure!(backend == "pjrt", "unknown --backend {backend} (want pjrt | native)");
        for variant in p.get("variants").split(',') {
            let route = format!("{model}/{variant}");
            let (a, m, v) = (artifacts.clone(), model.to_string(), variant.to_string());
            router.add_route(
                &route,
                coord_cfg(),
                Box::new(move || {
                    Ok(Box::new(PjrtBackend::open(&a, &m, &v)?) as Box<dyn Backend>)
                }),
            )?;
            println!("route {route}");
        }
    }
    let (c, h, w) = manifest.models.values().next().unwrap().input_shape;
    let router = Arc::new(router);
    let net_cfg = NetConfig {
        max_conns: p.get_usize("max-conns"),
        io_timeout: Duration::from_millis(p.get_u64("io-timeout-ms")),
        max_frame_bytes: p.get_usize("max-frame-bytes"),
        drain_timeout: Duration::from_millis(p.get_u64("drain-ms")),
        ..Default::default()
    };
    let server =
        NetServer::serve_with(p.get("listen"), Arc::clone(&router), ImageSpec { c, h, w }, net_cfg)?;
    println!("listening on {}", server.addr);
    let secs = p.get_u64("duration");
    if secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    let net_metrics = server.shutdown();
    println!("shut down after {secs}s");
    println!("{}", net_metrics.summary());
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    use lqr::nn::Engine;
    use lqr::quant::serialize::write_lqz;
    use lqr::quant::RegionSpec;

    let p = Args::new("lqr quantize", "offline-quantize a model into .lqz")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("model", "minialexnet", "model name")
        .flag("bits", "8", "weight bits (1-8)")
        .flag("region", "kernel", "region: kernel | dq | <size>")
        .required("out", "output .lqz path")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let artifacts = p.get("artifacts");
    let model = p.get("model");
    let region = RegionSpec::parse(p.get("region"))
        .ok_or_else(|| anyhow::anyhow!("bad --region {}", p.get("region")))?;
    let engine = Engine::from_npz(
        Arch::by_name(model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?,
        format!("{artifacts}/weights_{model}.npz"),
    )?;
    let entries = engine.to_lqz_entries(p.get_usize("bits") as u8, region);
    write_lqz(p.get("out"), &entries)?;
    let bytes = std::fs::metadata(p.get("out"))?.len();
    println!(
        "wrote {} ({} entries, {:.0} KB, {} bits, region={region})",
        p.get("out"),
        entries.len(),
        bytes as f64 / 1e3,
        p.get("bits"),
    );
    Ok(())
}

fn cmd_classify(argv: &[String]) -> Result<()> {
    let p = Args::new("lqr classify", "classify val images through one artifact")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("artifact", "minialexnet_f32_b8", "artifact name (see `lqr info`)")
        .flag("count", "32", "number of images")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let artifacts = p.get("artifacts");
    let mut session = lqr::runtime::Session::open(artifacts)?;
    let runner = session.load(p.get("artifact"))?;
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?;
    let batch = runner.meta.batch;
    let n = p.get_usize("count").min(ds.len());
    let mut hits = 0;
    let mut done = 0;
    while done + batch <= n {
        let x = ds.batch(done, batch);
        let logits = session.run(&runner, &x)?;
        for r in 0..batch {
            let row = logits.row(r);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 == ds.labels[done + r] {
                hits += 1;
            }
        }
        done += batch;
    }
    println!("{}: {hits}/{done} top-1 over val subset", p.get("artifact"));
    Ok(())
}

fn cmd_accuracy(argv: &[String]) -> Result<()> {
    let p = Args::new("lqr accuracy", "accuracy sweeps (Tables 1-2, Figs. 9-10)")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("table", "2", "which experiment: 1 | 2 | fig10")
        .flag("bits", "8,6,4,2", "activation bit widths for table 2")
        .flag("regions", "27,9,3", "region sizes for fig10")
        .flag("limit", "512", "validation images to evaluate")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let artifacts = p.get("artifacts");
    let limit = p.get_usize("limit");
    match p.get("table") {
        "1" => sweep::table1(artifacts, limit)?.print(),
        "2" => sweep::table2(artifacts, &p.get_usize_list("bits"), limit)?.print(),
        "fig10" => sweep::fig10(artifacts, &p.get_usize_list("regions"), limit)?.print(),
        other => anyhow::bail!("unknown --table {other} (want 1 | 2 | fig10)"),
    }
    Ok(())
}

fn cmd_opcount() -> Result<()> {
    sweep::table3().print();
    Ok(())
}

fn cmd_fpga() -> Result<()> {
    sweep::table45().print();
    Ok(())
}

fn cmd_speedup(argv: &[String]) -> Result<()> {
    let p = Args::new("lqr speedup", "Fig. 8 f32 vs 8-bit per-image runtime")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("images", "20", "images to measure per configuration")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    sweep::fig8(p.get("artifacts"), p.get_usize("images"))?.print();
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let p = Args::new("lqr info", "artifact + architecture summary")
        .flag("artifacts", "artifacts", "artifacts directory")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let m = Manifest::load(p.get("artifacts"))?;
    println!("artifacts in {}:", m.dir.display());
    for a in &m.artifacts {
        println!(
            "  {:<24} model={:<12} variant={:<4} bits={} batch={}",
            a.name, a.model, a.variant, a.bits, a.batch
        );
    }
    println!("\narchitectures:");
    for name in ["minialexnet", "minivgg", "alexnet", "vgg16"] {
        let a = Arch::by_name(name).unwrap();
        println!(
            "  {:<12} input={:?} layers={} params={:.1}M",
            name,
            a.input,
            a.layers.len(),
            a.param_count() as f64 / 1e6
        );
    }
    Ok(())
}
