//! Shared packed weight-panel GEMM core.
//!
//! Every quantized GEMM in the ladder (`gemm_quantized`, `gemm_lut`,
//! `gemm_packed`) reduces to the same computation: an integer dot product
//! over u8 codes per quantization region, followed by the eq. 7 affine
//! correction. This module factors that computation into one cache-friendly
//! core so the three entry points share a single hot loop:
//!
//! - [`WeightPanel`] widens / bit-unpacks the weight codes **once** into
//!   N-tiles of [`NR`] output channels stored K-major (`[tile][p][jj]`), so
//!   the microkernel reads one contiguous `NR`-wide line per reduction step.
//!   K is blocked on quantization-region boundaries — the panel layout
//!   matches the LQ granularity, which is what lets the per-region affine
//!   correction vectorize. Scales / mins / code-sums are stored transposed
//!   (`[tile][region][jj]`) for the same reason. For <= 4-bit codes the
//!   panel additionally keeps a region-aligned **bit-plane** layout
//!   ([`WeightPanel::bit_planes`]) beside the u8 tiles — the operand of the
//!   bit-serial popcount GEMM ([`super::bitserial`]).
//! - [`gemm_panel`] / [`gemm_panel_packed`] run a register-tiled
//!   [`MR`]x[`NR`] microkernel selected at runtime by the SIMD dispatcher
//!   ([`super::simd`]): explicit AVX2 / AVX-512-VNNI widening integer MACs
//!   on x86-64, NEON `umlal` / `udot` tiles on aarch64, the portable scalar
//!   tile otherwise (contract in `docs/kernel-dispatch.md`). Arbitrary
//!   regions-per-row and odd K tails are handled by the region loop itself
//!   (the tail region is just shorter).
//! - [`gemm_lut_panel`] replaces the inner multiply with §V code bucketing,
//!   bucketing a whole `NR`-wide tile per activation row per region instead
//!   of re-widening the weight row for every `(i, j)` pair; the bucketing
//!   pass dispatches through the same kernel table.
//!
//! The outer loops run an **M-block x N-tile schedule**: activation rows are
//! grouped into L2-sized blocks (`m_block_rows`), each weight tile streams
//! through a whole block of rows before the next tile loads, and
//! `scope_chunks` parallelizes over the M-blocks. For batch-sized M this
//! keeps every weight tile's codes resident across dozens of row visits
//! instead of re-streaming the full panel per `MR` rows.
//!
//! Panels are built once per weight matrix and cached by the engine
//! (`nn::forward::Engine`), so panel prep amortizes across batches.

use crate::quant::codec;
use crate::quant::lut::{collapse_buckets, MAX_CODES};
use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

use super::bitserial::{WeightPlanes, BITSERIAL_MAX_BITS};
use super::gemm_i8::SyncPtr;
use super::gemm_packed::PackedMatrix;
use super::simd::{self, Kernel};

/// Microkernel width: output channels per weight tile (one cache line of
/// i8 codes; 16 i32 accumulator lanes = one AVX-512 / two AVX2 registers).
pub const NR: usize = 16;
/// Microkernel height: activation rows processed together. MR * NR = 64
/// i32 accumulators — comfortably register-resident at AVX2 widths.
pub const MR: usize = 4;

/// Weight codes + affine parameters repacked for the panel microkernel.
///
/// Built once per weight matrix (offline for deployed models); all three
/// quantized GEMM entry points consume this representation.
#[derive(Debug, Clone)]
pub struct WeightPanel {
    /// Output channels (rows of the source `W^T`, columns of the result).
    pub n: usize,
    /// Reduction length.
    pub k: usize,
    /// Code width in bits (1..=8).
    pub bits: u8,
    /// Region length along K (tail region may be shorter).
    pub group: usize,
    /// Regions per row.
    pub rpr: usize,
    /// Widened codes, `tiles * k * NR`, layout `[tile][p][jj]` — the jj-th
    /// column of tile `t` is output channel `t*NR + jj`. Channels past `n`
    /// are zero padding.
    codes: Vec<u8>,
    /// Per-region scales, `tiles * rpr * NR`, layout `[tile][r][jj]`.
    scales: Vec<f32>,
    /// Per-region minimums, same layout.
    mins: Vec<f32>,
    /// Per-region code sums (the `S_qw` term of eq. 7), same layout.
    code_sums: Vec<f32>,
    /// Region-aligned bit-plane streams of the same codes, kept beside the
    /// u8 tiles whenever `bits <= 4` — the operand of the bit-serial
    /// popcount GEMM (`super::bitserial`). `None` for wider codes.
    planes: Option<WeightPlanes>,
}

impl WeightPanel {
    /// Repack a quantized weight matrix (rows = output channels) into panels.
    pub fn from_quantized(q: &QuantizedMatrix) -> WeightPanel {
        let rpr = q.regions_per_row();
        let mut p = WeightPanel::empty(q.rows, q.k, q.bits, q.group_len(), rpr);
        for j in 0..q.rows {
            p.fill_column(j, q.row_codes(j), &q.scales, &q.mins, &q.code_sums);
        }
        p
    }

    /// Repack a bit-packed weight matrix, unpacking each row exactly once.
    pub fn from_packed(q: &PackedMatrix) -> WeightPanel {
        let mut p = WeightPanel::empty(q.rows, q.k, q.bits, q.group, q.regions_per_row);
        let mut rowbuf = vec![0u8; q.k];
        for j in 0..q.rows {
            codec::unpack_into(&q.rows_packed[j], &mut rowbuf);
            p.fill_column(j, &rowbuf, &q.scales, &q.mins, &q.code_sums);
        }
        p
    }

    fn empty(n: usize, k: usize, bits: u8, group: usize, rpr: usize) -> WeightPanel {
        let tiles = n.div_ceil(NR).max(1);
        WeightPanel {
            n,
            k,
            bits,
            group,
            rpr,
            codes: vec![0u8; tiles * k * NR],
            scales: vec![0.0f32; tiles * rpr * NR],
            mins: vec![0.0f32; tiles * rpr * NR],
            code_sums: vec![0.0f32; tiles * rpr * NR],
            planes: (bits <= BITSERIAL_MAX_BITS)
                .then(|| WeightPlanes::empty(n, k, bits, group, rpr)),
        }
    }

    /// Scatter one output channel's codes + affine params into its tile
    /// (and, for <= 4-bit codes, into its bit-plane slots).
    fn fill_column(&mut self, j: usize, codes: &[u8], scales: &[f32], mins: &[f32], sums: &[f32]) {
        let (t, jj) = (j / NR, j % NR);
        let base = t * self.k * NR;
        for (p, &c) in codes.iter().enumerate() {
            self.codes[base + p * NR + jj] = c;
        }
        for r in 0..self.rpr {
            let dst = (t * self.rpr + r) * NR + jj;
            let src = j * self.rpr + r;
            self.scales[dst] = scales[src];
            self.mins[dst] = mins[src];
            self.code_sums[dst] = sums[src];
        }
        let (k, group) = (self.k, self.group);
        if let Some(planes) = &mut self.planes {
            planes.fill_column(j, codes, k, group);
        }
    }

    /// Number of `NR`-wide tiles.
    pub fn tiles(&self) -> usize {
        self.n.div_ceil(NR).max(1)
    }

    /// Codes of tile `t`: `k * NR` bytes, `[p][jj]`.
    #[inline]
    pub fn tile_codes(&self, t: usize) -> &[u8] {
        &self.codes[t * self.k * NR..(t + 1) * self.k * NR]
    }

    /// `(scales, mins, code_sums)` of tile `t`, region `r`: `NR`-wide lines.
    #[inline]
    pub fn tile_affine(&self, t: usize, r: usize) -> (&[f32], &[f32], &[f32]) {
        let o = (t * self.rpr + r) * NR;
        (&self.scales[o..o + NR], &self.mins[o..o + NR], &self.code_sums[o..o + NR])
    }

    /// The region-aligned bit-plane layout of the codes, present whenever
    /// `bits <= 4` — what the bit-serial popcount GEMM reads.
    #[inline]
    pub fn bit_planes(&self) -> Option<&WeightPlanes> {
        self.planes.as_ref()
    }

    /// Resident bytes of the prepared panel (codes + affine params + any
    /// bit-plane streams).
    pub fn bytes(&self) -> usize {
        self.codes.len()
            + 4 * (self.scales.len() + self.mins.len() + self.code_sums.len())
            + self.planes.as_ref().map_or(0, |p| p.bytes())
    }

    /// `(start, end)` bounds of region `r` along K.
    #[inline]
    pub fn region_bounds(&self, r: usize) -> (usize, usize) {
        let start = r * self.group;
        (start, ((r + 1) * self.group).min(self.k))
    }
}

/// Activation-side view shared by the flat and bit-packed entry points.
struct ASide<'a> {
    rows: usize,
    k: usize,
    rpr: usize,
    codes: ACodes<'a>,
    scales: &'a [f32],
    mins: &'a [f32],
    code_sums: &'a [f32],
}

enum ACodes<'a> {
    /// One code per byte, row-major (`QuantizedMatrix::codes`).
    Flat(&'a [u8]),
    /// One packed stream per row (`PackedMatrix::rows_packed`).
    Bits(&'a [codec::Packed]),
}

impl ASide<'_> {
    /// Materialize `rows` activation rows starting at `i0` into `dst`
    /// (`rows * k` bytes, row-major). Packed streams unpack here, once per
    /// row per GEMM — never per output column.
    fn fill_rows(&self, i0: usize, rows: usize, dst: &mut [u8]) {
        match self.codes {
            ACodes::Flat(c) => {
                dst[..rows * self.k].copy_from_slice(&c[i0 * self.k..(i0 + rows) * self.k]);
            }
            ACodes::Bits(streams) => {
                for (r, s) in streams[i0..i0 + rows].iter().enumerate() {
                    codec::unpack_into(s, &mut dst[r * self.k..(r + 1) * self.k]);
                }
            }
        }
    }
}

/// Rows per M-block of the outer loop. Large enough that a weight tile's
/// codes amortize over many activation rows, small enough that a block's
/// activation codes (`mb * K` bytes) stay L2-resident and enough blocks
/// exist to spread across the pool.
fn m_block_rows(m: usize, threads: usize) -> usize {
    const MB_MAX: usize = 128;
    let target_blocks = threads.max(1) * 4;
    let mb = m.div_ceil(target_blocks).clamp(MR, MB_MAX);
    mb.div_ceil(MR) * MR
}

/// The shared panel GEMM: `A (M,K) x panel(W^T) -> (M,N)` with per-region
/// affine correction. M-block x N-tile schedule, parallel over M-blocks,
/// integer inner loop via the dispatched `kernel`.
fn gemm_panel_core(a: &ASide, wp: &WeightPanel, threads: usize, kernel: &Kernel) -> Tensor {
    assert_eq!(a.k, wp.k, "reduction dims differ: {} vs {}", a.k, wp.k);
    assert_eq!(a.rpr, wp.rpr, "operands must share the region size along K");
    let (m, n, k) = (a.rows, wp.n, a.k);
    let rpr = wp.rpr;
    let tiles = wp.tiles();
    let mut out = vec![0.0f32; m * n];

    let out_ptr = SyncPtr(out.as_mut_ptr());
    let mb = m_block_rows(m, threads);
    let nblocks = m.div_ceil(mb).max(1);
    scope_chunks(nblocks, threads, |nb0, nb1| {
        let out_ptr = &out_ptr;
        let mut abuf = vec![0u8; mb * k];
        for nb in nb0..nb1 {
            let i0 = nb * mb;
            let mrows = mb.min(m - i0);
            a.fill_rows(i0, mrows, &mut abuf);
            // SAFETY: rows [i0, i0+mrows) are written by exactly one chunk.
            let oblock =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i0 * n), mrows * n) };
            for t in 0..tiles {
                let j0 = t * NR;
                let nr_eff = NR.min(n - j0);
                let tcodes = wp.tile_codes(t);
                for r in 0..rpr {
                    let (start, end) = wp.region_bounds(r);
                    let lenf = (end - start) as f32;
                    let wseg = &tcodes[start * NR..end * NR];
                    let (sw, mw, sqw) = wp.tile_affine(t, r);
                    // The region segment stays L1-hot while every MR-row
                    // strip of the M-block streams through it.
                    let mut b0 = 0usize;
                    while b0 < mrows {
                        let rows = MR.min(mrows - b0);
                        let mut acc = [[0i32; NR]; MR];
                        kernel.run_micro(&abuf[b0 * k..], k, rows, start, end, wseg, &mut acc);
                        // Eq. 7 correction, vectorized over the NR tile columns.
                        for mr in 0..rows {
                            let i = i0 + b0 + mr;
                            let sa = a.scales[i * rpr + r];
                            let ma = a.mins[i * rpr + r];
                            let sqa = a.code_sums[i * rpr + r];
                            let lane = &acc[mr];
                            let o0 = (b0 + mr) * n + j0;
                            let orow = &mut oblock[o0..o0 + nr_eff];
                            for jj in 0..nr_eff {
                                orow[jj] += sa * sw[jj] * lane[jj] as f32
                                    + sa * mw[jj] * sqa
                                    + ma * sw[jj] * sqw[jj]
                                    + lenf * ma * mw[jj];
                            }
                        }
                        b0 += MR;
                    }
                }
            }
        }
    });
    Tensor::new(&[m, n], out)
}

/// Panel GEMM over byte-per-code activations (`A_q (M,K) x W^T -> (M,N)`),
/// on the host-dispatched SIMD kernel.
pub fn gemm_panel(aq: &QuantizedMatrix, wp: &WeightPanel, threads: usize) -> Tensor {
    gemm_panel_with(aq, wp, threads, simd::active())
}

/// [`gemm_panel`] with an explicit kernel — tests and benches pin the
/// scalar arm against the dispatched arm through this.
pub fn gemm_panel_with(
    aq: &QuantizedMatrix,
    wp: &WeightPanel,
    threads: usize,
    kernel: &Kernel,
) -> Tensor {
    assert_eq!(
        aq.group_len(),
        wp.group,
        "operands must share the region size along K"
    );
    let a = ASide {
        rows: aq.rows,
        k: aq.k,
        rpr: aq.regions_per_row(),
        codes: ACodes::Flat(&aq.codes),
        scales: &aq.scales,
        mins: &aq.mins,
        code_sums: &aq.code_sums,
    };
    gemm_panel_core(&a, wp, threads, kernel)
}

/// Panel GEMM over bit-packed activations: each activation row unpacks once
/// per GEMM (in its M-block), each weight row unpacked once at panel
/// build — never inside the inner loop.
pub fn gemm_panel_packed(aq: &PackedMatrix, wp: &WeightPanel, threads: usize) -> Tensor {
    gemm_panel_packed_with(aq, wp, threads, simd::active())
}

/// [`gemm_panel_packed`] with an explicit kernel.
pub fn gemm_panel_packed_with(
    aq: &PackedMatrix,
    wp: &WeightPanel,
    threads: usize,
    kernel: &Kernel,
) -> Tensor {
    assert_eq!(aq.group, wp.group, "operands must share the region size along K");
    let a = ASide {
        rows: aq.rows,
        k: aq.k,
        rpr: aq.regions_per_row,
        codes: ACodes::Bits(&aq.rows_packed),
        scales: &aq.scales,
        mins: &aq.mins,
        code_sums: &aq.code_sums,
    };
    gemm_panel_core(&a, wp, threads, kernel)
}

/// §V LUT panel GEMM: multiply-free inner loop for <= 4-bit activations.
///
/// Buckets one `NR`-wide weight tile per `(row, region)` — a single add-only
/// pass over the tile — then collapses buckets with `2^bits - 2` multiplies
/// per lane. Numerically identical to [`gemm_panel`].
pub fn gemm_lut_panel(aq: &QuantizedMatrix, wp: &WeightPanel, threads: usize) -> Tensor {
    gemm_lut_panel_with(aq, wp, threads, simd::active())
}

/// [`gemm_lut_panel`] with an explicit kernel (bucketing pass dispatch).
pub fn gemm_lut_panel_with(
    aq: &QuantizedMatrix,
    wp: &WeightPanel,
    threads: usize,
    kernel: &Kernel,
) -> Tensor {
    assert!(aq.bits <= 4, "LUT GEMM needs <= 4-bit activations, got {}", aq.bits);
    assert_eq!(aq.k, wp.k, "reduction dims differ: {} vs {}", aq.k, wp.k);
    assert_eq!(
        aq.group_len(),
        wp.group,
        "operands must share the region size along K"
    );
    let (m, n) = (aq.rows, wp.n);
    let rpr = wp.rpr;
    assert_eq!(aq.regions_per_row(), rpr, "operands must share the region size along K");
    let levels = 1usize << aq.bits;
    let tiles = wp.tiles();
    let mut out = vec![0.0f32; m * n];

    // Row-blocked like the integer core: a weight tile is bucketed for a
    // whole block of consecutive rows before the next tile streams in. The
    // block shrinks for small M so enough blocks exist for scope_chunks to
    // actually go parallel (its serial guard sees block count, not rows).
    const RB_MAX: usize = 32;
    let rb = m.div_ceil(threads.max(1) * 4).clamp(1, RB_MAX);
    let out_ptr = SyncPtr(out.as_mut_ptr());
    let nblocks = m.div_ceil(rb).max(1);
    scope_chunks(nblocks, threads, |nb0, nb1| {
        let out_ptr = &out_ptr;
        for nb in nb0..nb1 {
            let i0 = nb * rb;
            let i1 = (i0 + rb).min(m);
            // SAFETY: rows [i0, i1) are written by exactly one chunk.
            let oblock =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i0 * n), (i1 - i0) * n) };
            for t in 0..tiles {
                let j0 = t * NR;
                let nr_eff = NR.min(n - j0);
                let tcodes = wp.tile_codes(t);
                for r in 0..rpr {
                    let (start, end) = wp.region_bounds(r);
                    let lenf = (end - start) as f32;
                    let wseg = &tcodes[start * NR..end * NR];
                    let (sw, mw, sqw) = wp.tile_affine(t, r);
                    for i in i0..i1 {
                        let arow = aq.row_codes(i);
                        let mut buckets = [[0i32; NR]; MAX_CODES];
                        kernel.run_bucket(&arow[start..end], wseg, &mut buckets);
                        let qq = collapse_buckets::<NR>(&buckets, levels);
                        let sa = aq.scale(i, r);
                        let ma = aq.min(i, r);
                        let sqa = aq.code_sums[i * rpr + r];
                        let o0 = (i - i0) * n + j0;
                        let oseg = &mut oblock[o0..o0 + nr_eff];
                        for jj in 0..nr_eff {
                            oseg[jj] += sa * sw[jj] * qq[jj] as f32
                                + sa * mw[jj] * sqa
                                + ma * sw[jj] * sqw[jj]
                                + lenf * ma * mw[jj];
                        }
                    }
                }
            }
        }
    });
    Tensor::new(&[m, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_matrix, RegionSpec};
    use crate::util::prop;

    #[test]
    fn panel_roundtrips_columns() {
        // Every (channel, position) code and every (channel, region) affine
        // triple must land in the right tile slot.
        prop::check_named("panel-layout", 0x9A41, 24, |rng, _| {
            let n = rng.index(1, 40);
            let k = rng.index(1, 30);
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let q = quantize_matrix(&w, 8, region);
            let p = WeightPanel::from_quantized(&q);
            let rpr = q.regions_per_row();
            assert_eq!(p.rpr, rpr);
            for j in 0..n {
                let (t, jj) = (j / NR, j % NR);
                let tc = p.tile_codes(t);
                for pos in 0..k {
                    assert_eq!(tc[pos * NR + jj], q.codes[j * k + pos], "code ({j},{pos})");
                }
                for r in 0..rpr {
                    let (sw, mw, sqw) = p.tile_affine(t, r);
                    assert_eq!(sw[jj], q.scale(j, r));
                    assert_eq!(mw[jj], q.min(j, r));
                    assert_eq!(sqw[jj], q.code_sums[j * rpr + r]);
                }
            }
        });
    }

    #[test]
    fn packed_panel_equals_quantized_panel() {
        let mut rng = crate::util::rng::Rng::new(11);
        let w = Tensor::new(&[13, 29], rng.normal_vec(13 * 29));
        for bits in [2u8, 4, 8] {
            let q = quantize_matrix(&w, bits, RegionSpec::Size(7));
            let from_q = WeightPanel::from_quantized(&q);
            let from_p = WeightPanel::from_packed(&PackedMatrix::from_quantized(&q));
            assert_eq!(from_q.codes, from_p.codes, "bits={bits}");
            assert_eq!(from_q.scales, from_p.scales);
            assert_eq!(from_q.code_sums, from_p.code_sums);
            assert_eq!(from_q.planes, from_p.planes, "bits={bits}");
            // The bit-plane sidecar exists exactly for <= 4-bit codes.
            assert_eq!(from_q.bit_planes().is_some(), bits <= 4, "bits={bits}");
        }
    }

    #[test]
    fn region_bounds_cover_k_with_tail() {
        let q = quantize_matrix(&Tensor::zeros(&[1, 75]), 8, RegionSpec::Size(16));
        let p = WeightPanel::from_quantized(&q);
        assert_eq!(p.rpr, 5);
        assert_eq!(p.region_bounds(0), (0, 16));
        assert_eq!(p.region_bounds(4), (64, 75)); // short tail region
    }
}
