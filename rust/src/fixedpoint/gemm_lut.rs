//! §V — LUT GEMM: the multiply-free fixed-point GEMM for <= 4-bit inputs.
//!
//! Weights stay as (dequant-pending) integer codes; activations are low-bit
//! codes. The inner product is computed by code bucketing (see
//! [`crate::quant::lut`]): the per-region integer sum `S_qq` needs **zero**
//! multiplies in the inner loop — the paper's Table 3 claim — and the affine
//! correction adds the usual handful of per-region multiplies.
//!
//! Runs on the shared weight-panel core ([`super::panel`]): the weight codes
//! are widened once at panel build, and bucketing covers an `NR`-wide tile
//! of output channels per pass (the seed re-widened the full weight row and
//! re-bucketed per `(i, j)` pair — `N`x more passes over the same bytes).
//! The bucketing pass itself dispatches through [`super::simd`] (AVX2
//! widening adds where available, portable otherwise).

use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;

use super::panel::{gemm_lut_panel, WeightPanel};

/// `A_q (M,K) x W_q^T (N,K) -> (M,N)` with the bucketed (LUT) inner loop.
/// `aq.bits` must be <= 4. Numerically identical to `gemm_quantized`.
///
/// Builds the weight panel per call; layer-reusing callers should cache a
/// [`WeightPanel`] and call [`gemm_lut_panel`] directly (the engine does).
pub fn gemm_lut(aq: &QuantizedMatrix, wq: &QuantizedMatrix, threads: usize) -> Tensor {
    assert!(aq.bits <= 4, "LUT GEMM needs <= 4-bit activations, got {}", aq.bits);
    assert_eq!(aq.k, wq.k);
    assert_eq!(aq.group_len(), wq.group_len());
    let wp = WeightPanel::from_quantized(wq);
    gemm_lut_panel(aq, &wp, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::gemm_i8::gemm_quantized;
    use crate::quant::{quantize_matrix, RegionSpec};
    use crate::util::prop;

    #[test]
    fn lut_equals_integer_gemm() {
        prop::check_named("gemm-lut-vs-i8", 0x10F, 24, |rng, _| {
            let m = rng.index(1, 10);
            let n = rng.index(1, 10);
            let k = rng.index(1, 50);
            let bits = [1u8, 2, 4][rng.below(3) as usize];
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, 8, region); // paper: weights stay 8-bit
            let want = gemm_quantized(&aq, &wq, 1);
            let got = gemm_lut(&aq, &wq, 2);
            assert!(
                got.max_abs_diff(&want) <= 1e-5 * want.max_abs().max(1.0),
                "bits={bits} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    #[should_panic(expected = "4-bit")]
    fn rejects_high_bit_activations() {
        let a = Tensor::zeros(&[2, 8]);
        let q8 = quantize_matrix(&a, 8, RegionSpec::PerRow);
        gemm_lut(&q8, &q8, 1);
    }
}
