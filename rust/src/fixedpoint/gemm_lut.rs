//! §V — LUT GEMM: the multiply-free fixed-point GEMM for <= 4-bit inputs.
//!
//! Weights stay as (dequant-pending) integer codes; activations are low-bit
//! codes. The inner product is computed by code bucketing (see
//! [`crate::quant::lut`]): the per-region integer sum `S_qq` needs **zero**
//! multiplies in the inner loop — the paper's Table 3 claim — and the affine
//! correction adds the usual handful of per-region multiplies.

use crate::quant::lut::bucketed_dot;
use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

use super::gemm_i8::SyncPtr;

/// `A_q (M,K) x W_q^T (N,K) -> (M,N)` with the bucketed (LUT) inner loop.
/// `aq.bits` must be <= 4. Numerically identical to `gemm_quantized`.
pub fn gemm_lut(aq: &QuantizedMatrix, wq: &QuantizedMatrix, threads: usize) -> Tensor {
    assert!(aq.bits <= 4, "LUT GEMM needs <= 4-bit activations, got {}", aq.bits);
    assert_eq!(aq.k, wq.k);
    assert_eq!(aq.group_len(), wq.group_len());
    let (m, n, k) = (aq.rows, wq.rows, aq.k);
    let g = aq.group_len();
    let rpr = aq.regions_per_row();
    let mut out = vec![0.0f32; m * n];

    let out_ptr = SyncPtr(out.as_mut_ptr());
    scope_chunks(m, threads, |i0, i1| {
        let out_ptr = &out_ptr;
        // Per-thread scratch: weight codes widened once per (j, region) pass.
        let mut wbuf = vec![0i32; k];
        for i in i0..i1 {
            let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            let arow = &aq.codes[i * k..(i + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                let wrow = &wq.codes[j * k..(j + 1) * k];
                for (dst, &w) in wbuf.iter_mut().zip(wrow) {
                    *dst = w as i32;
                }
                let mut acc = 0.0f32;
                for r in 0..rpr {
                    let start = r * g;
                    let end = ((r + 1) * g).min(k);
                    let qq = bucketed_dot(&arow[start..end], &wbuf[start..end], aq.bits);
                    let sa = aq.scale(i, r);
                    let ma = aq.min(i, r);
                    let sw = wq.scale(j, r);
                    let mw = wq.min(j, r);
                    acc += sa * sw * qq as f32
                        + sa * mw * aq.code_sums[i * rpr + r]
                        + sw * ma * wq.code_sums[j * rpr + r]
                        + (end - start) as f32 * ma * mw;
                }
                *o = acc;
            }
        }
    });
    Tensor::new(&[m, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::gemm_i8::gemm_quantized;
    use crate::quant::{quantize_matrix, RegionSpec};
    use crate::util::prop;

    #[test]
    fn lut_equals_integer_gemm() {
        prop::check_named("gemm-lut-vs-i8", 0x10F, 24, |rng, _| {
            let m = rng.index(1, 10);
            let n = rng.index(1, 10);
            let k = rng.index(1, 50);
            let bits = [1u8, 2, 4][rng.below(3) as usize];
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, 8, region); // paper: weights stay 8-bit
            let want = gemm_quantized(&aq, &wq, 1);
            let got = gemm_lut(&aq, &wq, 2);
            assert!(
                got.max_abs_diff(&want) <= 1e-5 * want.max_abs().max(1.0),
                "bits={bits} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    #[should_panic(expected = "4-bit")]
    fn rejects_high_bit_activations() {
        let a = Tensor::zeros(&[2, 8]);
        let q8 = quantize_matrix(&a, 8, RegionSpec::PerRow);
        gemm_lut(&q8, &q8, 1);
    }
}
