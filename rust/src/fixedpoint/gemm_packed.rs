//! Packed low-bit GEMM: the eq. 7 pipeline reading bit-packed code streams.
//!
//! The paper's bandwidth argument (§III.C): SIMD/memory throughput scales
//! inversely with operand width, so 2-bit codes move 16x more elements per
//! load than f32. This kernel consumes [`crate::quant::codec::Packed`]
//! streams directly, unpacking one 64-bit word at a time in registers —
//! matching how an IoT-class core would stream packed weights from flash.

use crate::quant::codec::Packed;
use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

use super::gemm_i8::SyncPtr;

/// A [`QuantizedMatrix`] with its codes bit-packed.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub rows: usize,
    pub k: usize,
    pub bits: u8,
    /// One packed stream per row (row-aligned so rows can unpack independently).
    pub rows_packed: Vec<Packed>,
    pub scales: Vec<f32>,
    pub mins: Vec<f32>,
    pub code_sums: Vec<f32>,
    pub regions_per_row: usize,
    pub group: usize,
}

impl PackedMatrix {
    pub fn from_quantized(q: &QuantizedMatrix) -> PackedMatrix {
        let rows_packed = (0..q.rows)
            .map(|i| crate::quant::codec::pack(&q.codes[i * q.k..(i + 1) * q.k], q.bits))
            .collect();
        PackedMatrix {
            rows: q.rows,
            k: q.k,
            bits: q.bits,
            rows_packed,
            scales: q.scales.clone(),
            mins: q.mins.clone(),
            code_sums: q.code_sums.clone(),
            regions_per_row: q.regions_per_row(),
            group: q.group_len(),
        }
    }

    /// Total packed bytes (codes only).
    pub fn code_bytes(&self) -> usize {
        self.rows_packed.iter().map(|p| p.bytes()).sum()
    }
}

/// `A_packed (M,K) x W_packed^T (N,K) -> (M,N)` with per-region correction.
///
/// Unpacks codes on the fly into a per-row scratch buffer once per row pair
/// panel (A row reused across all N columns), so unpack cost amortizes.
pub fn gemm_packed(aq: &PackedMatrix, wq: &PackedMatrix, threads: usize) -> Tensor {
    assert_eq!(aq.k, wq.k);
    assert_eq!(aq.group, wq.group, "operands must share the region size");
    let (m, n, k) = (aq.rows, wq.rows, aq.k);
    let g = aq.group;
    let rpr = aq.regions_per_row;
    let mut out = vec![0.0f32; m * n];

    let out_ptr = SyncPtr(out.as_mut_ptr());
    scope_chunks(m, threads, |i0, i1| {
        let out_ptr = &out_ptr;
        let mut abuf = vec![0u8; k];
        let mut wbuf = vec![0u8; k];
        for i in i0..i1 {
            let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            abuf.copy_from_slice(&crate::quant::codec::unpack(&aq.rows_packed[i]));
            for (j, o) in orow.iter_mut().enumerate() {
                wbuf.copy_from_slice(&crate::quant::codec::unpack(&wq.rows_packed[j]));
                let mut acc = 0.0f32;
                for r in 0..rpr {
                    let start = r * g;
                    let end = ((r + 1) * g).min(k);
                    let mut qq: i32 = 0;
                    for (a, w) in abuf[start..end].iter().zip(&wbuf[start..end]) {
                        qq += (*a as i32) * (*w as i32);
                    }
                    let sa = aq.scales[i * rpr + r];
                    let ma = aq.mins[i * rpr + r];
                    let sw = wq.scales[j * rpr + r];
                    let mw = wq.mins[j * rpr + r];
                    acc += sa * sw * qq as f32
                        + sa * mw * aq.code_sums[i * rpr + r]
                        + sw * ma * wq.code_sums[j * rpr + r]
                        + (end - start) as f32 * ma * mw;
                }
                *o = acc;
            }
        }
    });
    Tensor::new(&[m, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::gemm_i8::gemm_quantized;
    use crate::quant::{quantize_matrix, RegionSpec};
    use crate::util::prop;

    #[test]
    fn packed_equals_unpacked_gemm() {
        prop::check_named("gemm-packed-vs-i8", 0x9A, 24, |rng, _| {
            let m = rng.index(1, 10);
            let n = rng.index(1, 10);
            let k = rng.index(1, 40);
            let bits = [2u8, 4, 8][rng.below(3) as usize];
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, bits, region);
            let want = gemm_quantized(&aq, &wq, 1);
            let got = gemm_packed(
                &PackedMatrix::from_quantized(&aq),
                &PackedMatrix::from_quantized(&wq),
                2,
            );
            assert!(got.max_abs_diff(&want) <= 1e-5 * want.max_abs().max(1.0));
        });
    }

    #[test]
    fn packed_bytes_ratio() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Tensor::new(&[8, 256], rng.normal_vec(8 * 256));
        let p8 = PackedMatrix::from_quantized(&quantize_matrix(&a, 8, RegionSpec::PerRow));
        let p2 = PackedMatrix::from_quantized(&quantize_matrix(&a, 2, RegionSpec::PerRow));
        let ratio = p8.code_bytes() as f64 / p2.code_bytes() as f64;
        assert!((3.0..=4.5).contains(&ratio), "8-bit/2-bit byte ratio {ratio}");
    }
}
