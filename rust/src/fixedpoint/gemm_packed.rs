//! Packed low-bit GEMM: the eq. 7 pipeline reading bit-packed code streams.
//!
//! The paper's bandwidth argument (§III.C): SIMD/memory throughput scales
//! inversely with operand width, so 2-bit codes move 16x more elements per
//! load than f32. This kernel consumes [`crate::quant::codec::Packed`]
//! streams — matching how an IoT-class core would stream packed weights from
//! flash — and runs on the shared weight-panel core ([`super::panel`]):
//! each weight stream is unpacked exactly **once** at panel build (the seed
//! re-unpacked every weight row for every one of the M activation rows), and
//! each activation stream unpacks once per GEMM into its M-block scratch,
//! after which the dispatched SIMD microkernel ([`super::simd`]) runs the
//! same integer tile as the flat path.

use crate::quant::codec::Packed;
use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;

use super::bitserial::{bitserial_eligible, force_u8panel, gemm_bitserial_packed};
use super::panel::{gemm_panel_packed, WeightPanel};

/// A [`QuantizedMatrix`] with its codes bit-packed.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    /// Number of rows.
    pub rows: usize,
    /// Reduction length (codes per row before packing).
    pub k: usize,
    /// Code width in bits (1..=8).
    pub bits: u8,
    /// One packed stream per row (row-aligned so rows can unpack independently).
    pub rows_packed: Vec<Packed>,
    /// Per-region scales, `rows * regions_per_row`, row-major.
    pub scales: Vec<f32>,
    /// Per-region minimums, same layout.
    pub mins: Vec<f32>,
    /// Per-region code sums (the `S_qw` term of eq. 7), same layout.
    pub code_sums: Vec<f32>,
    /// Regions per row.
    pub regions_per_row: usize,
    /// Region length along K (tail region may be shorter).
    pub group: usize,
}

impl PackedMatrix {
    /// Pack each row's codes into a dense bitstream, carrying the affine
    /// side-cars over unchanged.
    pub fn from_quantized(q: &QuantizedMatrix) -> PackedMatrix {
        let rows_packed = (0..q.rows)
            .map(|i| crate::quant::codec::pack(q.row_codes(i), q.bits))
            .collect();
        PackedMatrix {
            rows: q.rows,
            k: q.k,
            bits: q.bits,
            rows_packed,
            scales: q.scales.clone(),
            mins: q.mins.clone(),
            code_sums: q.code_sums.clone(),
            regions_per_row: q.regions_per_row(),
            group: q.group_len(),
        }
    }

    /// Total packed bytes (codes only).
    pub fn code_bytes(&self) -> usize {
        self.rows_packed.iter().map(|p| p.bytes()).sum()
    }
}

/// `A_packed (M,K) x W_packed^T (N,K) -> (M,N)` with per-region correction.
///
/// Builds the weight panel (one unpack pass over W) per call; callers that
/// reuse packed weights should build a [`WeightPanel`] via
/// [`WeightPanel::from_packed`] once and call [`gemm_panel_packed`].
///
/// When both operands are <= 4 bits the GEMM runs bit-serially on the
/// panel's bit-plane sidecar (`super::bitserial`) — compute scales with the
/// bit widths instead of running low-bit codes through the 8-bit tile.
/// Bit-exact either way; `LQR_FORCE_U8PANEL=1` opts out.
pub fn gemm_packed(aq: &PackedMatrix, wq: &PackedMatrix, threads: usize) -> Tensor {
    assert_eq!(aq.k, wq.k);
    assert_eq!(aq.group, wq.group, "operands must share the region size");
    let wp = WeightPanel::from_packed(wq);
    if bitserial_eligible(aq.bits, wq.bits) && !force_u8panel() {
        return gemm_bitserial_packed(aq, &wp, threads);
    }
    gemm_panel_packed(aq, &wp, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::gemm_i8::gemm_quantized;
    use crate::quant::{quantize_matrix, RegionSpec};
    use crate::util::prop;

    #[test]
    fn packed_equals_unpacked_gemm() {
        prop::check_named("gemm-packed-vs-i8", 0x9A, 24, |rng, _| {
            let m = rng.index(1, 10);
            let n = rng.index(1, 10);
            let k = rng.index(1, 40);
            let bits = [2u8, 4, 8][rng.below(3) as usize];
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, bits, region);
            let want = gemm_quantized(&aq, &wq, 1);
            let got = gemm_packed(
                &PackedMatrix::from_quantized(&aq),
                &PackedMatrix::from_quantized(&wq),
                2,
            );
            assert!(got.max_abs_diff(&want) <= 1e-5 * want.max_abs().max(1.0));
        });
    }

    #[test]
    fn packed_bytes_ratio() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Tensor::new(&[8, 256], rng.normal_vec(8 * 256));
        let p8 = PackedMatrix::from_quantized(&quantize_matrix(&a, 8, RegionSpec::PerRow));
        let p2 = PackedMatrix::from_quantized(&quantize_matrix(&a, 2, RegionSpec::PerRow));
        let ratio = p8.code_bytes() as f64 / p2.code_bytes() as f64;
        assert!((3.0..=4.5).contains(&ratio), "8-bit/2-bit byte ratio {ratio}");
    }
}
