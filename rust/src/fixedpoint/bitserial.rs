//! Bit-serial popcount GEMM: compute directly on packed 1/2/4-bit codes.
//!
//! The u8 panel path widens every low-bit code to a byte and runs the same
//! 8-bit microkernel regardless of width, so a 2-bit model runs at 8-bit
//! speed and only saves memory. This module realizes the paper's sub-8-bit
//! complexity claim (§III.C / Fig. 8) on commodity CPUs via bit-plane
//! decomposition — the standard trick surveyed in Guo 2018: writing each
//! operand as a weighted sum of bit-planes,
//!
//! ```text
//! a[p] = sum_i 2^i * a_i[p],   w[p] = sum_j 2^j * w_j[p]
//! ```
//!
//! turns the integer dot of a quantization region into
//!
//! ```text
//! sum_p a[p] * w[p] = sum_{i,j} 2^(i+j) * popcount(A_i & W_j)
//! ```
//!
//! where `A_i` / `W_j` are the planes as dense `u64` lane streams. One
//! 64-lane AND+popcount word op replaces 64 MACs per plane pair, so compute
//! cost scales as `bits_a * bits_w * K / 64` instead of `K` — 16x fewer
//! word ops than MACs at 2 bits.
//!
//! Layout: every quantization region's planes start **word-aligned**
//! ([`crate::quant::codec::pack_planes_into`] packs each region segment
//! separately at a shared `words_per_region` stride), so a region dot is a
//! whole-words popcount — the tail bits of a short region are zero in both
//! operands and contribute nothing. [`WeightPlanes`] carries that layout
//! per output channel beside the panel's u8 tiles; the activation side is
//! packed per row inside the GEMM (an `O(M * K)` pass, same order as the
//! u8 path's M-block scratch fill).
//!
//! The integer dot per `(row, column, region)` runs on the dispatched
//! [`Kernel::run_popdot`] arm (scalar `count_ones`, AVX2 `vpshufb`
//! nibble-LUT popcount, NEON `vcntq_u8` — see `super::simd` and
//! `docs/kernel-dispatch.md`), and the eq. 7 affine epilogue applies the
//! **identical** f32 expression in the identical region order as the shared
//! panel core, so the whole path is **bit-exact** against the u8 oracle —
//! pinned by `rust/tests/panel_kernels.rs`.
//!
//! The engine (`nn::forward`) selects this path per layer whenever both
//! operands are <= 4 bits (opt out with `LQR_FORCE_U8PANEL=1`); wider
//! configurations keep the u8 panel microkernel.

use std::sync::OnceLock;

use crate::quant::codec;
use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

use super::gemm_i8::SyncPtr;
use super::gemm_packed::PackedMatrix;
use super::panel::{WeightPanel, NR};
use super::simd::{self, Kernel};

/// Widest code the bit-serial path accepts on either operand. Past 4 bits
/// the `bits_a * bits_w` plane pairs cost more word ops than the u8
/// microkernel costs MACs, so the panel path keeps those widths.
pub const BITSERIAL_MAX_BITS: u8 = 4;

/// True when both operands are narrow enough for the bit-serial path.
#[inline]
pub fn bitserial_eligible(bits_a: u8, bits_w: u8) -> bool {
    bits_a <= BITSERIAL_MAX_BITS && bits_w <= BITSERIAL_MAX_BITS
}

/// `LQR_FORCE_U8PANEL=1`: opt out of the bit-serial path — eligible layers
/// run the widened u8 panel microkernel instead (read once, like
/// `LQR_FORCE_SCALAR`). Both paths are bit-exact, so this is a perf A/B
/// knob, not a numerics switch.
pub fn force_u8panel() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var("LQR_FORCE_U8PANEL")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// `u64` words per region per plane: regions are word-aligned so a region
/// dot never masks at the edges (the pad bits are zero in both operands).
pub(crate) fn words_per_region(group: usize, k: usize) -> usize {
    group.min(k).max(1).div_ceil(64)
}

/// Region-aligned bit-plane streams of a weight panel's codes: the operand
/// the bit-serial microkernel reads. Built once per weight matrix alongside
/// the u8 tiles (see [`WeightPanel`]) whenever the codes are <= 4 bits.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightPlanes {
    /// Code width in bits (1..=4) — one plane per bit.
    bits: u8,
    /// Regions per row.
    rpr: usize,
    /// Words per region per plane (tail regions zero-pad to this).
    wpr: usize,
    /// `n * rpr * bits * wpr` words, layout `[channel][region][plane][word]`.
    words: Vec<u64>,
}

impl WeightPlanes {
    pub(crate) fn empty(n: usize, k: usize, bits: u8, group: usize, rpr: usize) -> WeightPlanes {
        debug_assert!(bits <= BITSERIAL_MAX_BITS);
        let wpr = words_per_region(group, k);
        WeightPlanes { bits, rpr, wpr, words: vec![0u64; n * rpr * bits as usize * wpr] }
    }

    /// Pack one output channel's codes (`k` bytes) into its plane slots,
    /// one word-aligned plane block per region.
    pub(crate) fn fill_column(&mut self, j: usize, codes: &[u8], k: usize, group: usize) {
        let bits = self.bits as usize;
        for r in 0..self.rpr {
            let start = r * group;
            let end = ((r + 1) * group).min(k);
            let o = (j * self.rpr + r) * bits * self.wpr;
            codec::pack_planes_into(
                &codes[start..end],
                self.bits,
                self.wpr,
                &mut self.words[o..o + bits * self.wpr],
            );
        }
    }

    /// Plane words of output channel `j`, region `r`: `bits * wpr` words,
    /// `[plane][word]`.
    #[inline]
    pub fn col_region(&self, j: usize, r: usize) -> &[u64] {
        let bits = self.bits as usize;
        let o = (j * self.rpr + r) * bits * self.wpr;
        &self.words[o..o + bits * self.wpr]
    }

    /// Words per region per plane (shared with the activation side).
    #[inline]
    pub fn words_per_region(&self) -> usize {
        self.wpr
    }

    /// Resident bytes of the plane streams.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Activation codes the bit-serial core can read: flat byte-per-code rows
/// or bit-packed streams (unpacked once per row into scratch, exactly like
/// the u8 panel path's M-block fill).
enum ACodes<'a> {
    Flat(&'a QuantizedMatrix),
    Bits(&'a PackedMatrix),
}

impl ACodes<'_> {
    /// `(rows, k, bits, regions_per_row, scales, mins, code_sums)`.
    fn geometry(&self) -> (usize, usize, u8, usize, &[f32], &[f32], &[f32]) {
        match *self {
            ACodes::Flat(q) => (
                q.rows,
                q.k,
                q.bits,
                q.regions_per_row(),
                &q.scales[..],
                &q.mins[..],
                &q.code_sums[..],
            ),
            ACodes::Bits(p) => (
                p.rows,
                p.k,
                p.bits,
                p.regions_per_row,
                &p.scales[..],
                &p.mins[..],
                &p.code_sums[..],
            ),
        }
    }

    /// Codes of row `i`; packed streams unpack into `buf` (once per row per
    /// GEMM — never per output column).
    fn row_codes<'b>(&'b self, i: usize, buf: &'b mut [u8]) -> &'b [u8] {
        match *self {
            ACodes::Flat(q) => q.row_codes(i),
            ACodes::Bits(p) => {
                codec::unpack_into(&p.rows_packed[i], buf);
                &buf[..p.k]
            }
        }
    }
}

/// The bit-serial GEMM core: `A (M,K) x planes(W^T) -> (M,N)` with the
/// eq. 7 per-region affine correction. Parallel over M row blocks; each
/// row's activation planes pack once and stream against every output
/// channel's weight planes through the dispatched popcount kernel.
fn gemm_bitserial_core(a: &ACodes, wp: &WeightPanel, threads: usize, kernel: &Kernel) -> Tensor {
    let planes = wp
        .bit_planes()
        .expect("bit-serial GEMM needs a panel with bit planes (weight bits <= 4)");
    let (m, ak, bits_a, rpr_a, scales, mins, sums) = a.geometry();
    assert!(
        bitserial_eligible(bits_a, wp.bits),
        "bit-serial GEMM needs <= {BITSERIAL_MAX_BITS}-bit operands, got a{bits_a}/w{}",
        wp.bits
    );
    assert_eq!(ak, wp.k, "reduction dims differ: {} vs {}", ak, wp.k);
    assert_eq!(rpr_a, wp.rpr, "operands must share the region size along K");
    let (n, k) = (wp.n, wp.k);
    let (rpr, bits_w) = (wp.rpr, wp.bits);
    let ba = bits_a as usize;
    let wpr = planes.words_per_region();
    let mut out = vec![0.0f32; m * n];

    let out_ptr = SyncPtr(out.as_mut_ptr());
    // Row-blocked like the LUT path: small blocks so enough chunks exist
    // for scope_chunks to go parallel even at batch-sized M.
    const RB_MAX: usize = 32;
    let rb = m.div_ceil(threads.max(1) * 4).clamp(1, RB_MAX);
    let nblocks = m.div_ceil(rb).max(1);
    scope_chunks(nblocks, threads, |nb0, nb1| {
        let out_ptr = &out_ptr;
        let mut rowbuf = vec![0u8; k];
        let mut aplanes = vec![0u64; rpr * ba * wpr];
        for nb in nb0..nb1 {
            let i0 = nb * rb;
            let i1 = (i0 + rb).min(m);
            // SAFETY: rows [i0, i1) are written by exactly one chunk.
            let oblock =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i0 * n), (i1 - i0) * n) };
            for i in i0..i1 {
                let arow = a.row_codes(i, &mut rowbuf);
                for r in 0..rpr {
                    let (start, end) = wp.region_bounds(r);
                    codec::pack_planes_into(
                        &arow[start..end],
                        bits_a,
                        wpr,
                        &mut aplanes[r * ba * wpr..(r + 1) * ba * wpr],
                    );
                }
                let orow = &mut oblock[(i - i0) * n..(i - i0 + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    let (t, jj) = (j / NR, j % NR);
                    let mut acc = 0.0f32;
                    for r in 0..rpr {
                        let (start, end) = wp.region_bounds(r);
                        let lenf = (end - start) as f32;
                        let dot = kernel.run_popdot(
                            &aplanes[r * ba * wpr..(r + 1) * ba * wpr],
                            planes.col_region(j, r),
                            wpr,
                            bits_a,
                            bits_w,
                        );
                        let (sw, mw, sqw) = wp.tile_affine(t, r);
                        let sa = scales[i * rpr + r];
                        let ma = mins[i * rpr + r];
                        let sqa = sums[i * rpr + r];
                        // Eq. 7 — the exact expression and region order of
                        // the u8 panel core, so the paths stay bit-exact.
                        acc += sa * sw[jj] * dot as f32
                            + sa * mw[jj] * sqa
                            + ma * sw[jj] * sqw[jj]
                            + lenf * ma * mw[jj];
                    }
                    *o = acc;
                }
            }
        }
    });
    Tensor::new(&[m, n], out)
}

/// Bit-serial GEMM over byte-per-code activations, on the host-dispatched
/// popcount kernel. Both operands must be <= 4 bits; the panel must have
/// been built from <= 4-bit weight codes (it then carries [`WeightPlanes`]).
pub fn gemm_bitserial(aq: &QuantizedMatrix, wp: &WeightPanel, threads: usize) -> Tensor {
    gemm_bitserial_with(aq, wp, threads, simd::active())
}

/// [`gemm_bitserial`] with an explicit kernel — tests and benches pin every
/// dispatch arm against the u8 scalar oracle through this.
pub fn gemm_bitserial_with(
    aq: &QuantizedMatrix,
    wp: &WeightPanel,
    threads: usize,
    kernel: &Kernel,
) -> Tensor {
    assert_eq!(aq.group_len(), wp.group, "operands must share the region size along K");
    gemm_bitserial_core(&ACodes::Flat(aq), wp, threads, kernel)
}

/// Bit-serial GEMM over bit-packed activation streams: each row unpacks
/// once per GEMM, then rides the same plane repack as the flat path.
pub fn gemm_bitserial_packed(aq: &PackedMatrix, wp: &WeightPanel, threads: usize) -> Tensor {
    gemm_bitserial_packed_with(aq, wp, threads, simd::active())
}

/// [`gemm_bitserial_packed`] with an explicit kernel.
pub fn gemm_bitserial_packed_with(
    aq: &PackedMatrix,
    wp: &WeightPanel,
    threads: usize,
    kernel: &Kernel,
) -> Tensor {
    assert_eq!(aq.group, wp.group, "operands must share the region size along K");
    gemm_bitserial_core(&ACodes::Bits(aq), wp, threads, kernel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::panel::gemm_panel_with;
    use crate::quant::{quantize_matrix, RegionSpec};
    use crate::util::prop;

    #[test]
    fn weight_planes_hold_every_code_bit() {
        // Every (channel, position) code must be recoverable from the
        // region-aligned plane layout — including ragged K tails.
        prop::check_named("weight-planes-layout", 0xB175, 24, |rng, _| {
            let n = rng.index(1, 40);
            let k = rng.index(1, 200);
            let bits = [1u8, 2, 4][rng.below(3) as usize];
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let q = quantize_matrix(&w, bits, region);
            let p = WeightPanel::from_quantized(&q);
            let planes = p.bit_planes().expect("<=4-bit panel must carry planes");
            let wpr = planes.words_per_region();
            let group = q.group_len();
            for j in 0..n {
                for r in 0..q.regions_per_row() {
                    let (start, end) = (r * group, ((r + 1) * group).min(k));
                    let pw = planes.col_region(j, r);
                    for (pi, pos) in (start..end).enumerate() {
                        let mut code = 0u8;
                        for b in 0..bits as usize {
                            code |= (((pw[b * wpr + pi / 64] >> (pi % 64)) & 1) as u8) << b;
                        }
                        assert_eq!(code, q.codes[j * k + pos], "channel {j} pos {pos}");
                    }
                    // Pad bits past the region length stay zero.
                    for b in 0..bits as usize {
                        let seg_len = end - start;
                        if seg_len % 64 != 0 {
                            let last = pw[b * wpr + seg_len / 64];
                            assert_eq!(last >> (seg_len % 64), 0, "pad bits set");
                        }
                        for wi in seg_len.div_ceil(64)..wpr {
                            assert_eq!(pw[b * wpr + wi], 0, "pad word set");
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn bitserial_matches_u8_panel_bit_exactly() {
        prop::check_named("bitserial-vs-panel", 0xB176, 40, |rng, _| {
            let m = rng.index(1, 12);
            let n = rng.index(1, 40);
            let k = rng.index(1, 150);
            let bits_a = [1u8, 2, 4][rng.below(3) as usize];
            let bits_w = [1u8, 2, 4][rng.below(3) as usize];
            let region = match rng.below(3) {
                0 => RegionSpec::PerRow,
                1 => RegionSpec::PerTensor,
                _ => RegionSpec::Size(rng.index(1, k + 1)),
            };
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let aq = quantize_matrix(&a, bits_a, region);
            let wq = quantize_matrix(&w, bits_w, region);
            let wp = WeightPanel::from_quantized(&wq);
            let want = gemm_panel_with(&aq, &wp, 1, simd::scalar_kernel());
            for threads in [1usize, 3] {
                let got = gemm_bitserial_with(&aq, &wp, threads, simd::scalar_kernel());
                assert_eq!(
                    got.data(),
                    want.data(),
                    "m={m} n={n} k={k} a{bits_a}/w{bits_w} region={region} threads={threads}"
                );
            }
        });
    }

    #[test]
    fn packed_activations_match_flat() {
        let mut rng = crate::util::rng::Rng::new(77);
        let a = Tensor::new(&[9, 130], rng.normal_vec(9 * 130));
        let w = Tensor::new(&[21, 130], rng.normal_vec(21 * 130));
        for bits in [1u8, 2, 4] {
            let aq = quantize_matrix(&a, bits, RegionSpec::Size(50));
            let wq = quantize_matrix(&w, bits, RegionSpec::Size(50));
            let wp = WeightPanel::from_quantized(&wq);
            let flat = gemm_bitserial(&aq, &wp, 1);
            let packed = gemm_bitserial_packed(&PackedMatrix::from_quantized(&aq), &wp, 2);
            assert_eq!(flat.data(), packed.data(), "bits={bits}");
        }
    }

    #[test]
    fn eligibility_gate() {
        assert!(bitserial_eligible(1, 1));
        assert!(bitserial_eligible(2, 4));
        assert!(bitserial_eligible(4, 4));
        assert!(!bitserial_eligible(2, 8));
        assert!(!bitserial_eligible(8, 2));
        assert_eq!(words_per_region(75, 75), 2);
        assert_eq!(words_per_region(64, 800), 1);
        assert_eq!(words_per_region(800, 800), 13);
    }
}
