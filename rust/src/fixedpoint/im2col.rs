//! im2col conv lowering — layout-compatible with `python/compile/model.py`.
//!
//! Input NCHW `(B, C, H, W)` -> patch matrix `(B*Ho*Wo, C*k*k)` where one row
//! is one receptive field with channel-major patch order `(C, kh, kw)`. One
//! row therefore spans exactly one "kernel-sized" LQ region (the paper's
//! default region choice in §VI.D: 11x11x3 = 363 for AlexNet conv1).

use crate::tensor::Tensor;

/// Output spatial size for a conv dimension.
pub fn conv_output_size(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

/// Lower `(B,C,H,W)` to the `(B*Ho*Wo, C*k*k)` patch matrix.
pub fn im2col(x: &Tensor, k: usize, stride: usize, pad: usize) -> (Tensor, (usize, usize, usize)) {
    assert_eq!(x.rank(), 4, "im2col needs NCHW, got {:?}", x.shape());
    let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let ho = conv_output_size(h, k, stride, pad);
    let wo = conv_output_size(w, k, stride, pad);
    let patch = c * k * k;
    let mut out = vec![0.0f32; b * ho * wo * patch];
    let xd = x.data();
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * patch;
                // Horizontal clip shared by every (ci, ky): source columns
                // are ix = ox*stride + kx - pad, valid for kx in
                // [kx_lo, kx_hi). Interior positions clip to the full
                // [0, k) span, so each (ci, ky) line is one memcpy; padded
                // edge positions copy the clipped sub-span and leave the
                // zero-initialized padding untouched.
                let xbase = ox * stride;
                let kx_lo = pad.saturating_sub(xbase);
                let kx_hi = k.min((w + pad).saturating_sub(xbase));
                if kx_lo >= kx_hi {
                    continue; // patch entirely left/right of the image
                }
                let span = kx_hi - kx_lo;
                let ix0 = xbase + kx_lo - pad;
                for ci in 0..c {
                    let plane = &xd[(bi * c + ci) * h * w..(bi * c + ci + 1) * h * w];
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy as usize >= h {
                            continue; // vertical padding row stays zero
                        }
                        let src = iy as usize * w + ix0;
                        let dst = row + (ci * k + ky) * k + kx_lo;
                        out[dst..dst + span].copy_from_slice(&plane[src..src + span]);
                    }
                }
            }
        }
    }
    (Tensor::new(&[b * ho * wo, patch], out), (b, ho, wo))
}

/// Fold a `(B*Ho*Wo, O)` GEMM result back to NCHW `(B, O, Ho, Wo)`.
pub fn col2im_output(y: &Tensor, b: usize, ho: usize, wo: usize) -> Tensor {
    assert_eq!(y.rank(), 2);
    assert_eq!(y.dim(0), b * ho * wo);
    let o = y.dim(1);
    let mut out = vec![0.0f32; b * o * ho * wo];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (bi * ho + oy) * wo + ox;
                for oc in 0..o {
                    out[((bi * o + oc) * ho + oy) * wo + ox] = y.at2(row, oc);
                }
            }
        }
    }
    Tensor::new(&[b, o, ho, wo], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Direct (nested-loop) convolution oracle.
    fn conv_direct(x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Tensor {
        let (b, c, h, ww) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (o, _c2, k, _) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
        let ho = conv_output_size(h, k, stride, pad);
        let wo = conv_output_size(ww, k, stride, pad);
        let mut out = vec![0.0f32; b * o * ho * wo];
        for bi in 0..b {
            for oc in 0..o {
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut acc = 0.0f32;
                        for ci in 0..c {
                            for ky in 0..k {
                                for kx in 0..k {
                                    let iy = (oy * stride + ky) as isize - pad as isize;
                                    let ix = (ox * stride + kx) as isize - pad as isize;
                                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < ww
                                    {
                                        let xv = x.data()
                                            [((bi * c + ci) * h + iy as usize) * ww + ix as usize];
                                        let wv = w.data()[((oc * c + ci) * k + ky) * k + kx];
                                        acc += xv * wv;
                                    }
                                }
                            }
                        }
                        out[((bi * o + oc) * ho + oy) * wo + ox] = acc;
                    }
                }
            }
        }
        Tensor::new(&[b, o, ho, wo], out)
    }

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        let mut rng = crate::util::rng::Rng::new(2);
        for &(c, h, k, stride, pad) in
            &[(1usize, 5usize, 3usize, 1usize, 1usize), (3, 8, 5, 1, 2), (2, 9, 3, 2, 1), (4, 6, 1, 1, 0)]
        {
            let b = 2;
            let o = 3;
            let x = Tensor::new(&[b, c, h, h], rng.normal_vec(b * c * h * h));
            let w = Tensor::new(&[o, c, k, k], rng.normal_vec(o * c * k * k));
            let (cols, (bb, ho, wo)) = im2col(&x, k, stride, pad);
            // GEMM: (rows, patch) x (patch, O)
            let wmat = w.reshape(&[o, c * k * k]).unwrap().transpose2();
            let y = crate::fixedpoint::gemm_f32(&cols, &wmat, 1);
            let got = col2im_output(&y, bb, ho, wo);
            let want = conv_direct(&x, &w, stride, pad);
            assert!(
                got.max_abs_diff(&want) <= 1e-4 * want.max_abs().max(1.0),
                "c={c} h={h} k={k} s={stride} p={pad}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn output_size() {
        assert_eq!(conv_output_size(32, 5, 1, 2), 32);
        assert_eq!(conv_output_size(224, 11, 4, 0), 54); // AlexNet conv1 (paper Fig. 7)
        assert_eq!(conv_output_size(32, 2, 2, 0), 16);
    }

    /// Per-element reference (the seed's branchy formulation) — pins the
    /// span-copy rewrite byte-for-byte, including heavy-padding clips.
    fn im2col_reference(x: &Tensor, k: usize, stride: usize, pad: usize) -> Vec<f32> {
        let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let ho = conv_output_size(h, k, stride, pad);
        let wo = conv_output_size(w, k, stride, pad);
        let patch = c * k * k;
        let mut out = vec![0.0f32; b * ho * wo * patch];
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * patch;
                    for ci in 0..c {
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                    out[row + (ci * k + ky) * k + kx] = x.data()
                                        [((bi * c + ci) * h + iy as usize) * w + ix as usize];
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn span_copy_matches_per_element_reference() {
        let mut rng = crate::util::rng::Rng::new(9);
        // Includes pad >= k/2 and pad = k-1 cases where every border patch clips.
        for &(c, h, k, stride, pad) in &[
            (1usize, 4usize, 3usize, 1usize, 2usize),
            (2, 6, 5, 2, 4),
            (3, 7, 3, 3, 0),
            (1, 5, 5, 1, 1),
            (2, 8, 1, 1, 0),
        ] {
            let b = 2;
            let x = Tensor::new(&[b, c, h, h], rng.normal_vec(b * c * h * h));
            let (cols, _) = im2col(&x, k, stride, pad);
            assert_eq!(cols.data(), &im2col_reference(&x, k, stride, pad)[..],
                "c={c} h={h} k={k} s={stride} p={pad}");
        }
    }

    #[test]
    fn patch_matrix_shape() {
        let x = Tensor::zeros(&[2, 3, 32, 32]);
        let (cols, (b, ho, wo)) = im2col(&x, 5, 1, 2);
        assert_eq!((b, ho, wo), (2, 32, 32));
        assert_eq!(cols.shape(), &[2 * 32 * 32, 75]);
    }
}
