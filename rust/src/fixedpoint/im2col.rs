//! im2col conv lowering — layout-compatible with `python/compile/model.py`.
//!
//! Input NCHW `(B, C, H, W)` -> patch matrix `(B*Ho*Wo, C*k*k)` where one row
//! is one receptive field with channel-major patch order `(C, kh, kw)`. One
//! row therefore spans exactly one "kernel-sized" LQ region (the paper's
//! default region choice in §VI.D: 11x11x3 = 363 for AlexNet conv1).

use crate::quant::scheme::{encode_region, QuantizedMatrix};
use crate::quant::RegionSpec;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

use super::gemm_i8::SyncPtr;

/// Output spatial size for a conv dimension.
pub fn conv_output_size(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

/// Visit every contiguous source line of one receptive field: calls
/// `emit(patch_off, src)` for each clipped (ci, ky) row-span that lands
/// inside the image, in patch order. Positions not visited are implicit
/// zero padding.
///
/// The horizontal clip is shared by every (ci, ky): source columns are
/// `ix = ox*stride + kx - pad`, valid for kx in `[kx_lo, kx_hi)`. Interior
/// positions clip to the full `[0, k)` span, so each (ci, ky) line is one
/// memcpy-able slice; padded edge positions yield the clipped sub-span.
#[inline]
fn for_each_row_span(
    xd: &[f32],
    (c, h, w): (usize, usize, usize),
    k: usize,
    stride: usize,
    pad: usize,
    bi: usize,
    oy: usize,
    ox: usize,
    mut emit: impl FnMut(usize, &[f32]),
) {
    let xbase = ox * stride;
    let kx_lo = pad.saturating_sub(xbase);
    let kx_hi = k.min((w + pad).saturating_sub(xbase));
    if kx_lo >= kx_hi {
        return; // patch entirely left/right of the image
    }
    let span = kx_hi - kx_lo;
    let ix0 = xbase + kx_lo - pad;
    for ci in 0..c {
        let plane = &xd[(bi * c + ci) * h * w..(bi * c + ci + 1) * h * w];
        for ky in 0..k {
            let iy = (oy * stride + ky) as isize - pad as isize;
            if iy < 0 || iy as usize >= h {
                continue; // vertical padding row stays zero
            }
            let src = iy as usize * w + ix0;
            emit((ci * k + ky) * k + kx_lo, &plane[src..src + span]);
        }
    }
}

/// Lower `(B,C,H,W)` to the `(B*Ho*Wo, C*k*k)` patch matrix.
pub fn im2col(x: &Tensor, k: usize, stride: usize, pad: usize) -> (Tensor, (usize, usize, usize)) {
    assert_eq!(x.rank(), 4, "im2col needs NCHW, got {:?}", x.shape());
    let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let ho = conv_output_size(h, k, stride, pad);
    let wo = conv_output_size(w, k, stride, pad);
    let patch = c * k * k;
    let mut out = vec![0.0f32; b * ho * wo * patch];
    let xd = x.data();
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * patch;
                for_each_row_span(xd, (c, h, w), k, stride, pad, bi, oy, ox, |dst, src| {
                    out[row + dst..row + dst + src.len()].copy_from_slice(src);
                });
            }
        }
    }
    (Tensor::new(&[b * ho * wo, patch], out), (b, ho, wo))
}

/// Fused conv lowering + activation quantization: the quantized-path
/// replacement for `im2col` followed by `quantize_matrix`.
///
/// Per-region min/max folds ride along the clipped row-span copies into a
/// patch-sized scratch row (padding zeros are folded in from the per-region
/// written counts, never stored and re-read from a full matrix), then u8
/// codes are emitted straight into the activation code buffer the panel
/// GEMM consumes. The `(B*Ho*Wo, C*k*k)` f32 patch matrix never exists —
/// only one `C*k*k` scratch row per pass, which stays L1-resident. Output is
/// bit-identical to the unfused pipeline (both paths share
/// `quant::scheme::encode_region`; pinned by `rust/tests/panel_kernels.rs`).
///
/// `RegionSpec::PerTensor` (the DQ scheme) needs the global min/max before
/// any code can be emitted; that runs as a copy-free prepass over the same
/// span geometry — still no patch matrix.
///
/// Both passes chunk the `B*Ho*Wo` patch rows over
/// [`scope_chunks`] (`threads <= 1` runs inline on the caller): every row's
/// min/max, codes and affine params depend only on that row's source spans,
/// so the parallel output is **bit-identical** to the single-threaded one —
/// the DQ prepass merges per-chunk `(min, max, written)` partials, which is
/// exact because min/max are order-independent. Pinned by
/// `rust/tests/panel_kernels.rs`.
pub fn im2col_quantized(
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    bits: u8,
    region: RegionSpec,
    threads: usize,
) -> (QuantizedMatrix, (usize, usize, usize)) {
    assert_eq!(x.rank(), 4, "im2col needs NCHW, got {:?}", x.shape());
    assert!((1..=8).contains(&bits), "bits must be 1..=8, got {bits}");
    let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let ho = conv_output_size(h, k, stride, pad);
    let wo = conv_output_size(w, k, stride, pad);
    let patch = c * k * k;
    let rows = b * ho * wo;
    let g = region.group_len(patch);
    let rpr = region.regions_per_row(patch);
    let levels = ((1u32 << bits) - 1) as f32;
    let xd = x.data();
    // Flat row index -> output position; rows are the parallel unit.
    let row_pos = |row: usize| -> (usize, usize, usize) {
        (row / (ho * wo), (row / wo) % ho, row % wo)
    };

    // DQ prepass: global min/max folded over the source spans directly (no
    // writes at all), padding zeros accounted once via the written count.
    // Chunks fold privately and merge under the lock — min/max merging is
    // exact regardless of chunk order.
    let (global_min, global_max) = if region.per_tensor() {
        let merged = std::sync::Mutex::new((f32::INFINITY, f32::NEG_INFINITY, 0usize));
        scope_chunks(rows, threads, |r0, r1| {
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            let mut written = 0usize;
            for row in r0..r1 {
                let (bi, oy, ox) = row_pos(row);
                for_each_row_span(xd, (c, h, w), k, stride, pad, bi, oy, ox, |_, src| {
                    for &v in src {
                        mn = mn.min(v);
                        mx = mx.max(v);
                    }
                    written += src.len();
                });
            }
            let mut m = merged.lock().unwrap();
            m.0 = m.0.min(mn);
            m.1 = m.1.max(mx);
            m.2 += written;
        });
        let (mut mn, mut mx, written) = merged.into_inner().unwrap();
        if written < rows * patch {
            mn = mn.min(0.0);
            mx = mx.max(0.0);
        }
        (mn, mx)
    } else {
        (0.0, 0.0)
    };

    let mut codes = vec![0u8; rows * patch];
    let mut scales = vec![0.0f32; rows * rpr];
    let mut mins = vec![0.0f32; rows * rpr];
    let mut code_sums = vec![0.0f32; rows * rpr];

    let codes_ptr = SyncPtr(codes.as_mut_ptr());
    let scales_ptr = SyncPtr(scales.as_mut_ptr());
    let mins_ptr = SyncPtr(mins.as_mut_ptr());
    let sums_ptr = SyncPtr(code_sums.as_mut_ptr());
    scope_chunks(rows, threads, |r0, r1| {
        let (codes_ptr, scales_ptr) = (&codes_ptr, &scales_ptr);
        let (mins_ptr, sums_ptr) = (&mins_ptr, &sums_ptr);
        // One patch-sized scratch row per chunk — stays L1-resident.
        let mut scratch = vec![0.0f32; patch];
        let mut rmn = vec![f32::INFINITY; rpr];
        let mut rmx = vec![f32::NEG_INFINITY; rpr];
        let mut rcount = vec![0usize; rpr];
        for row in r0..r1 {
            let (bi, oy, ox) = row_pos(row);
            scratch.fill(0.0);
            rmn.fill(f32::INFINITY);
            rmx.fill(f32::NEG_INFINITY);
            rcount.fill(0);
            for_each_row_span(xd, (c, h, w), k, stride, pad, bi, oy, ox, |dst, src| {
                scratch[dst..dst + src.len()].copy_from_slice(src);
                if region.per_tensor() {
                    return; // DQ uses the global prepass min/max
                }
                // Fold min/max into each region the span overlaps while
                // the line is hot.
                let mut off = dst;
                let mut rem = src;
                while !rem.is_empty() {
                    let r = off / g;
                    let take = (((r + 1) * g).min(patch) - off).min(rem.len());
                    let (seg, rest) = rem.split_at(take);
                    let (mut mn, mut mx) = (rmn[r], rmx[r]);
                    for &v in seg {
                        mn = mn.min(v);
                        mx = mx.max(v);
                    }
                    rmn[r] = mn;
                    rmx[r] = mx;
                    rcount[r] += take;
                    off += take;
                    rem = rest;
                }
            });
            // SAFETY: row `row` is written by exactly one chunk — the
            // codes / scales / mins / code_sums slices below are disjoint
            // per row across the whole scope.
            let crow =
                unsafe { std::slice::from_raw_parts_mut(codes_ptr.0.add(row * patch), patch) };
            let srow =
                unsafe { std::slice::from_raw_parts_mut(scales_ptr.0.add(row * rpr), rpr) };
            let mrow = unsafe { std::slice::from_raw_parts_mut(mins_ptr.0.add(row * rpr), rpr) };
            let qrow = unsafe { std::slice::from_raw_parts_mut(sums_ptr.0.add(row * rpr), rpr) };
            for r in 0..rpr {
                let start = r * g;
                let end = ((r + 1) * g).min(patch);
                let (mn, mx) = if region.per_tensor() {
                    (global_min, global_max)
                } else {
                    let (mut mn, mut mx) = (rmn[r], rmx[r]);
                    if rcount[r] < end - start {
                        // Region contains padding zeros.
                        mn = mn.min(0.0);
                        mx = mx.max(0.0);
                    }
                    (mn, mx)
                };
                let (s, sum) =
                    encode_region(&scratch[start..end], mn, mx, levels, &mut crow[start..end]);
                srow[r] = s;
                mrow[r] = mn;
                qrow[r] = sum;
            }
        }
    });
    (
        QuantizedMatrix { rows, k: patch, bits, region, codes, scales, mins, code_sums },
        (b, ho, wo),
    )
}

/// Fold a `(B*Ho*Wo, O)` GEMM result back to NCHW `(B, O, Ho, Wo)`.
///
/// A blocked `TB`x`TB` transpose per image: the inner copy walks `y` rows
/// so every source cache line is consumed whole, instead of the seed's
/// per-element `at2` column walk (this runs right after every conv GEMM).
pub fn col2im_output(y: &Tensor, b: usize, ho: usize, wo: usize) -> Tensor {
    assert_eq!(y.rank(), 2);
    assert_eq!(y.dim(0), b * ho * wo);
    let o = y.dim(1);
    let hw = ho * wo;
    let yd = y.data();
    let mut out = vec![0.0f32; b * o * hw];
    const TB: usize = 32;
    for bi in 0..b {
        let src = &yd[bi * hw * o..(bi + 1) * hw * o];
        let dst = &mut out[bi * o * hw..(bi + 1) * o * hw];
        for p0 in (0..hw).step_by(TB) {
            let p1 = (p0 + TB).min(hw);
            for c0 in (0..o).step_by(TB) {
                let c1 = (c0 + TB).min(o);
                for p in p0..p1 {
                    let row = &src[p * o + c0..p * o + c1];
                    for (ci, &v) in row.iter().enumerate() {
                        dst[(c0 + ci) * hw + p] = v;
                    }
                }
            }
        }
    }
    Tensor::new(&[b, o, ho, wo], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Direct (nested-loop) convolution oracle.
    fn conv_direct(x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Tensor {
        let (b, c, h, ww) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (o, _c2, k, _) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
        let ho = conv_output_size(h, k, stride, pad);
        let wo = conv_output_size(ww, k, stride, pad);
        let mut out = vec![0.0f32; b * o * ho * wo];
        for bi in 0..b {
            for oc in 0..o {
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut acc = 0.0f32;
                        for ci in 0..c {
                            for ky in 0..k {
                                for kx in 0..k {
                                    let iy = (oy * stride + ky) as isize - pad as isize;
                                    let ix = (ox * stride + kx) as isize - pad as isize;
                                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < ww
                                    {
                                        let xv = x.data()
                                            [((bi * c + ci) * h + iy as usize) * ww + ix as usize];
                                        let wv = w.data()[((oc * c + ci) * k + ky) * k + kx];
                                        acc += xv * wv;
                                    }
                                }
                            }
                        }
                        out[((bi * o + oc) * ho + oy) * wo + ox] = acc;
                    }
                }
            }
        }
        Tensor::new(&[b, o, ho, wo], out)
    }

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        let mut rng = crate::util::rng::Rng::new(2);
        for &(c, h, k, stride, pad) in
            &[(1usize, 5usize, 3usize, 1usize, 1usize), (3, 8, 5, 1, 2), (2, 9, 3, 2, 1), (4, 6, 1, 1, 0)]
        {
            let b = 2;
            let o = 3;
            let x = Tensor::new(&[b, c, h, h], rng.normal_vec(b * c * h * h));
            let w = Tensor::new(&[o, c, k, k], rng.normal_vec(o * c * k * k));
            let (cols, (bb, ho, wo)) = im2col(&x, k, stride, pad);
            // GEMM: (rows, patch) x (patch, O)
            let wmat = w.reshape(&[o, c * k * k]).unwrap().transpose2();
            let y = crate::fixedpoint::gemm_f32(&cols, &wmat, 1);
            let got = col2im_output(&y, bb, ho, wo);
            let want = conv_direct(&x, &w, stride, pad);
            assert!(
                got.max_abs_diff(&want) <= 1e-4 * want.max_abs().max(1.0),
                "c={c} h={h} k={k} s={stride} p={pad}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn output_size() {
        assert_eq!(conv_output_size(32, 5, 1, 2), 32);
        assert_eq!(conv_output_size(224, 11, 4, 0), 54); // AlexNet conv1 (paper Fig. 7)
        assert_eq!(conv_output_size(32, 2, 2, 0), 16);
    }

    /// Per-element reference (the seed's branchy formulation) — pins the
    /// span-copy rewrite byte-for-byte, including heavy-padding clips.
    fn im2col_reference(x: &Tensor, k: usize, stride: usize, pad: usize) -> Vec<f32> {
        let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let ho = conv_output_size(h, k, stride, pad);
        let wo = conv_output_size(w, k, stride, pad);
        let patch = c * k * k;
        let mut out = vec![0.0f32; b * ho * wo * patch];
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * patch;
                    for ci in 0..c {
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                    out[row + (ci * k + ky) * k + kx] = x.data()
                                        [((bi * c + ci) * h + iy as usize) * w + ix as usize];
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn span_copy_matches_per_element_reference() {
        let mut rng = crate::util::rng::Rng::new(9);
        // Includes pad >= k/2 and pad = k-1 cases where every border patch clips.
        for &(c, h, k, stride, pad) in &[
            (1usize, 4usize, 3usize, 1usize, 2usize),
            (2, 6, 5, 2, 4),
            (3, 7, 3, 3, 0),
            (1, 5, 5, 1, 1),
            (2, 8, 1, 1, 0),
        ] {
            let b = 2;
            let x = Tensor::new(&[b, c, h, h], rng.normal_vec(b * c * h * h));
            let (cols, _) = im2col(&x, k, stride, pad);
            assert_eq!(cols.data(), &im2col_reference(&x, k, stride, pad)[..],
                "c={c} h={h} k={k} s={stride} p={pad}");
        }
    }

    #[test]
    fn blocked_col2im_matches_per_element_reference() {
        let mut rng = crate::util::rng::Rng::new(17);
        // Shapes crossing the TB=32 tile edge in both dimensions.
        for &(b, o, ho, wo) in &[(1usize, 3usize, 2usize, 2usize), (2, 33, 5, 7), (1, 8, 6, 6), (3, 40, 9, 4)] {
            let y = Tensor::new(&[b * ho * wo, o], rng.normal_vec(b * ho * wo * o));
            let got = col2im_output(&y, b, ho, wo);
            assert_eq!(got.shape(), &[b, o, ho, wo]);
            for bi in 0..b {
                for oc in 0..o {
                    for oy in 0..ho {
                        for ox in 0..wo {
                            let row = (bi * ho + oy) * wo + ox;
                            assert_eq!(
                                got.data()[((bi * o + oc) * ho + oy) * wo + ox],
                                y.at2(row, oc),
                                "b={bi} oc={oc} oy={oy} ox={ox}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn patch_matrix_shape() {
        let x = Tensor::zeros(&[2, 3, 32, 32]);
        let (cols, (b, ho, wo)) = im2col(&x, 5, 1, 2);
        assert_eq!((b, ho, wo), (2, 32, 32));
        assert_eq!(cols.shape(), &[2 * 32 * 32, 75]);
    }
}
