//! Runtime-dispatched SIMD microkernels for the panel GEMM core.
//!
//! The panel core ([`super::panel`]) is parameterized over a [`Kernel`]: a
//! set of function pointers covering the three inner loops of the quantized
//! ladder — the `MR`x`NR` u8 multiply-accumulate tile, the §V LUT
//! bucketing pass, and the bit-serial AND+popcount dot
//! ([`super::bitserial`]). [`active`] selects the widest implementation the
//! host CPU supports **once** (cached in a `OnceLock`) and every quantized
//! GEMM entry point routes through it; [`scalar_kernel`] is the portable
//! fallback and the force-disable target (`LQR_FORCE_SCALAR=1`, read at
//! first dispatch).
//!
//! The contract every arm satisfies — bit-exactness vs the scalar oracle,
//! the alignment/tail invariants an arm may assume, and the checklist for
//! adding the next ISA — is documented in `docs/kernel-dispatch.md` at the
//! repo root; read it before touching this table.
//!
//! Implementations:
//!
//! - **scalar** — the PR 1 loops, kept verbatim as the portable arm and the
//!   bit-exactness anchor (`rust/tests/panel_kernels.rs` pins every SIMD arm
//!   to it, and it to the seed naive oracle).
//! - **avx2-madd** — `_mm256_maddubs_epi16` is the obvious u8 pairing but
//!   *saturates* its i16 pair sums: with full 8-bit codes a pair reaches
//!   255*255*2 = 130050 > i16::MAX, so it cannot be bit-exact. The AVX2 arm
//!   instead interleaves two K lines, widens codes to i16
//!   (`_mm256_cvtepu8_epi16`) and uses `_mm256_madd_epi16`, whose pairwise
//!   i32 sums never saturate for non-negative 8-bit operands: 32 exact MACs
//!   per madd pair.
//! - **vnni-dpbusd** (cargo feature `avx512`, needs `avx512vnni` at runtime)
//!   — `vpdpbusd` computes u8 x s8 groups of four; weight codes are full u8,
//!   so the kernel bias-flips them to `w - 128` (one xor with 0x80) and adds
//!   the `128 * sum(a)` compensation back per activation row. 64 exact MACs
//!   per instruction. Feature-gated because the AVX-512 intrinsics need a
//!   recent stable toolchain; the portable and AVX2 arms build everywhere.
//! - **neon-umlal** (aarch64) — the ARM-class boards the paper targets. One
//!   16-byte weight line widens once (`vmovl_u8`) to two u16x8 vectors and
//!   each activation broadcasts as u16; `vmlal_u16` accumulates exact
//!   u16 x u16 products into u32 lanes. No saturation anywhere on this path,
//!   and the u32 totals stay below 2^31 (region < 2^15), so the final
//!   u32 -> i32 reinterpret is lossless.
//! - **neon-udot** (cargo feature `dotprod`, needs `dotprod`/`asimddp` at
//!   runtime) — `vdotq_u32` (`udot`) computes u8 x u8 groups of four, so
//!   unlike `vpdpbusd` it needs **no** bias-flip compensation: both operands
//!   are already unsigned. The 4x16 code block transposes with two zip
//!   rounds (same shuffle shape as the VNNI arm) so each 32-bit group holds
//!   one column's four codes. Feature-gated because the dotprod intrinsics
//!   stabilized later than the core NEON set.
//!
//! The bit-serial popcount slot ([`PopdotFn`], consumed by
//! [`super::bitserial`]) has its own per-ISA implementations: portable
//! `u64::count_ones`, an AVX2 `vpshufb` nibble-LUT byte popcount +
//! `vpsadbw` fold (`vpopcntq` needs AVX-512 VPOPCNTDQ, which the VNNI gate
//! does not cover — the VNNI kernel reuses the AVX2 arm), and a NEON
//! `vcntq_u8` + `vaddlvq_u8` arm shared by the umlal and udot kernels.
//!
//! All integer accumulation is exact (products fit i32 for regions shorter
//! than 2^15 — every model layer here), and the f32 affine correction in the
//! panel core is shared, so dispatch arms agree **bit-exactly**, not just to
//! a tolerance.

use std::sync::OnceLock;

use crate::quant::lut::MAX_CODES;

use super::panel::{MR, NR};

/// `acc[mr][jj] += a[mr][p] * w[p][jj]` over one region segment.
/// `(abuf, k, rows, start, end, wseg, acc)`: `abuf` holds `rows` activation
/// rows with stride `k`, `wseg` is the K-major `NR`-wide tile slice for
/// `p in start..end` (`(end-start) * NR` bytes).
pub type MicroFn = fn(&[u8], usize, usize, usize, usize, &[u8], &mut [[i32; NR]; MR]);

/// §V bucketing: add each `NR`-wide weight line of `wseg` into the bucket
/// row of its paired activation code (`qa`).
pub type BucketFn = fn(&[u8], &[u8], &mut [[i32; NR]; MAX_CODES]);

/// Bit-serial plane dot: `(a_planes, w_planes, words, bits_a, bits_w)` ->
/// `sum_{i,j} 2^(i+j) * popcount(a_planes[i] & w_planes[j])` over plane
/// streams of `words` u64 words each (`[plane][word]`, see
/// [`super::bitserial`]).
pub type PopdotFn = fn(&[u64], &[u64], usize, u8, u8) -> i32;

/// One dispatchable implementation set for the panel inner loops.
#[derive(Clone, Copy)]
pub struct Kernel {
    /// Implementation name (recorded in `BENCH_gemm.json`).
    pub name: &'static str,
    /// ISA tier the implementation requires.
    pub isa: &'static str,
    micro: MicroFn,
    bucket: BucketFn,
    popdot: PopdotFn,
}

impl Kernel {
    /// Run the integer MAC microkernel over one region segment.
    ///
    /// The bounds asserts here are release-mode and load-bearing: the SIMD
    /// arms use unchecked loads behind them, so this safe entry point must
    /// reject bad geometry the way the scalar arm's slice indexing would.
    /// One check per region call — noise next to the `len * NR * rows` MACs.
    #[inline]
    pub fn run_micro(
        &self,
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        assert!(rows <= MR, "run_micro: rows {rows} > MR {MR}");
        assert!(start <= end && end <= k, "run_micro: bad segment {start}..{end} for k={k}");
        assert!(wseg.len() >= (end - start) * NR, "run_micro: wseg too short");
        assert!(
            rows == 0 || abuf.len() >= (rows - 1) * k + end,
            "run_micro: abuf too short"
        );
        (self.micro)(abuf, k, rows, start, end, wseg, acc)
    }

    /// Run the LUT bucketing pass over one region segment. Same contract
    /// note as [`Kernel::run_micro`]: the assert guards unchecked SIMD loads.
    #[inline]
    pub fn run_bucket(&self, qa: &[u8], wseg: &[u8], buckets: &mut [[i32; NR]; MAX_CODES]) {
        assert!(wseg.len() >= qa.len() * NR, "run_bucket: wseg too short");
        (self.bucket)(qa, wseg, buckets)
    }

    /// Run the bit-serial plane dot over one region segment: `a_planes` /
    /// `w_planes` hold `bits_a` / `bits_w` plane streams of `words` u64
    /// words each, zero-padded past the region length; returns
    /// `sum_{i,j} 2^(i+j) * popcount(a_planes[i] & w_planes[j])`. Same
    /// contract note as [`Kernel::run_micro`]: the asserts guard unchecked
    /// SIMD loads. `bits <= 4` keeps the weighted total below 2^24 for
    /// regions shorter than 2^15 (the shared contract), far inside i32.
    #[inline]
    pub fn run_popdot(
        &self,
        a_planes: &[u64],
        w_planes: &[u64],
        words: usize,
        bits_a: u8,
        bits_w: u8,
    ) -> i32 {
        assert!(
            (1..=4).contains(&bits_a) && (1..=4).contains(&bits_w),
            "run_popdot: bits must be 1..=4, got a{bits_a}/w{bits_w}"
        );
        assert!(a_planes.len() >= bits_a as usize * words, "run_popdot: a_planes too short");
        assert!(w_planes.len() >= bits_w as usize * words, "run_popdot: w_planes too short");
        (self.popdot)(a_planes, w_planes, words, bits_a, bits_w)
    }
}

impl std::fmt::Debug for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Kernel({}/{})", self.name, self.isa)
    }
}

static SCALAR_K: Kernel = Kernel {
    name: "scalar",
    isa: "portable",
    micro: scalar_micro,
    bucket: scalar_bucket,
    popdot: scalar_popdot,
};

/// The portable kernel — always available on every target, and what
/// `LQR_FORCE_SCALAR=1` pins the dispatcher to.
pub fn scalar_kernel() -> &'static Kernel {
    &SCALAR_K
}

#[cfg(target_arch = "x86_64")]
static AVX2_K: Kernel = Kernel {
    name: "avx2-madd",
    isa: "avx2",
    micro: x86::micro_avx2_entry,
    bucket: x86::bucket_avx2_entry,
    popdot: x86::popdot_avx2_entry,
};

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
static VNNI_K: Kernel = Kernel {
    name: "vnni-dpbusd",
    isa: "avx512vnni",
    micro: x86::micro_vnni_entry,
    bucket: x86::bucket_avx2_entry,
    // avx512vnni implies avx2: the nibble-LUT popcount arm is sound here.
    popdot: x86::popdot_avx2_entry,
};

#[cfg(target_arch = "aarch64")]
static NEON_K: Kernel = Kernel {
    name: "neon-umlal",
    isa: "neon",
    micro: aarch64::micro_neon_entry,
    bucket: aarch64::bucket_neon_entry,
    popdot: aarch64::popdot_neon_entry,
};

#[cfg(all(target_arch = "aarch64", feature = "dotprod"))]
static DOTPROD_K: Kernel = Kernel {
    name: "neon-udot",
    isa: "neon-dotprod",
    micro: aarch64::micro_dotprod_entry,
    bucket: aarch64::bucket_neon_entry,
    popdot: aarch64::popdot_neon_entry,
};

/// The kernel the dispatcher selected for this host. Selection runs once:
/// scalar when forced via `LQR_FORCE_SCALAR=1`, otherwise the widest ISA
/// the target's feature-detection macro reports — `is_x86_feature_detected!`
/// on x86-64, `is_aarch64_feature_detected!` on aarch64, scalar elsewhere.
pub fn active() -> &'static Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    ACTIVE.get_or_init(select)
}

/// Widest integer-MAC ISA the host advertises, independent of the force
/// flag and of what this build can use — benches record it alongside the
/// selected kernel so results are comparable across hosts.
pub fn detected_isa() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f")
            && is_x86_feature_detected!("avx512bw")
            && is_x86_feature_detected!("avx512vnni")
        {
            "avx512vnni"
        } else if is_x86_feature_detected!("avx2") {
            "avx2"
        } else {
            "sse2"
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("dotprod") {
            "neon-dotprod"
        } else if std::arch::is_aarch64_feature_detected!("neon") {
            "neon"
        } else {
            "portable"
        }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "portable"
    }
}

fn force_scalar() -> bool {
    std::env::var("LQR_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

fn select() -> Kernel {
    if force_scalar() {
        return SCALAR_K;
    }
    // One detection ladder serves dispatch, tests and bench alike:
    // `supported_kernels` orders arms narrowest-first / widest-last, so the
    // dispatcher's pick is the last entry. A new arm registered there is
    // automatically dispatchable — and automatically pinned by the tests.
    **supported_kernels().last().expect("scalar arm is always present")
}

/// Every kernel this build can run on this host, ordered narrowest-first
/// (scalar) to widest-last (what [`active`] dispatches) — including arms
/// the dispatcher would *not* select (e.g. `neon-umlal` on a host where
/// `neon-udot` wins). Tests pin each arm against the scalar oracle through
/// this, so the non-default arms stay green instead of only the widest one;
/// the bench reports per-arm timings from the same list. Ignores
/// `LQR_FORCE_SCALAR` (that flag pins [`active`], not hardware capability).
pub fn supported_kernels() -> Vec<&'static Kernel> {
    #[allow(unused_mut)]
    let mut ks: Vec<&'static Kernel> = vec![&SCALAR_K];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            ks.push(&AVX2_K);
        }
        #[cfg(feature = "avx512")]
        {
            if is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512bw")
                && is_x86_feature_detected!("avx512vnni")
            {
                ks.push(&VNNI_K);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            ks.push(&NEON_K);
        }
        #[cfg(feature = "dotprod")]
        {
            if std::arch::is_aarch64_feature_detected!("dotprod") {
                ks.push(&DOTPROD_K);
            }
        }
    }
    ks
}

/// Portable `MR`x`NR` microkernel: fixed-width u8 x u8 -> i32 MACs that LLVM
/// lowers to widening SIMD multiplies where available. Products are at most
/// `255 * 255 * len`, which fits i32 for any region shorter than 2^15.
pub fn scalar_micro(
    abuf: &[u8],
    k: usize,
    rows: usize,
    start: usize,
    end: usize,
    wseg: &[u8],
    acc: &mut [[i32; NR]; MR],
) {
    debug_assert!(wseg.len() >= (end - start) * NR);
    for (pi, p) in (start..end).enumerate() {
        let wline = &wseg[pi * NR..(pi + 1) * NR];
        for mr in 0..rows {
            let av = abuf[mr * k + p] as i32;
            if av == 0 {
                continue; // ReLU-sparse activations quantize to code 0 often
            }
            let lane = &mut acc[mr];
            for (dst, &w) in lane.iter_mut().zip(wline) {
                *dst += av * w as i32;
            }
        }
    }
}

/// Portable bucketing pass — delegates to the §V tile bucketing primitive.
pub fn scalar_bucket(qa: &[u8], wseg: &[u8], buckets: &mut [[i32; NR]; MAX_CODES]) {
    crate::quant::lut::bucket_panel_segment::<NR>(qa, wseg, buckets);
}

/// Portable bit-serial plane dot: per plane pair, AND + `count_ones` per
/// u64 word, weighted by `2^(i+j)`. `count_ones` lowers to a single
/// `popcnt` where the target has one and an exact bit-twiddling sequence
/// otherwise, so this arm is the oracle on every host. Per-pair popcounts
/// are bounded by the region length (< 2^15) and the weighted total by
/// `15 * 15 * 2^15 < 2^23` — exact in i32 with huge margin.
pub fn scalar_popdot(a: &[u64], w: &[u64], words: usize, bits_a: u8, bits_w: u8) -> i32 {
    let mut total = 0u32;
    for bi in 0..bits_a as usize {
        let ap = &a[bi * words..(bi + 1) * words];
        for bj in 0..bits_w as usize {
            let wp = &w[bj * words..(bj + 1) * words];
            let mut c = 0u32;
            for (x, y) in ap.iter().zip(wp) {
                c += (x & y).count_ones();
            }
            total += c << (bi + bj);
        }
    }
    total as i32
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MAX_CODES, MR, NR};
    use std::arch::x86_64::*;

    // Safe entry shims: the dispatcher installs these fn pointers only after
    // runtime feature detection succeeded, so the unsafe target_feature call
    // inside each shim is sound (and plain `fn` pointers keep the dispatch
    // table buildable on toolchains without target_feature fn coercions).

    pub fn micro_avx2_entry(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        // SAFETY: selected only when is_x86_feature_detected!("avx2") held.
        unsafe { micro_avx2(abuf, k, rows, start, end, wseg, acc) }
    }

    pub fn bucket_avx2_entry(qa: &[u8], wseg: &[u8], buckets: &mut [[i32; NR]; MAX_CODES]) {
        // SAFETY: selected only when is_x86_feature_detected!("avx2") held.
        unsafe { bucket_avx2(qa, wseg, buckets) }
    }

    pub fn popdot_avx2_entry(a: &[u64], w: &[u64], words: usize, bits_a: u8, bits_w: u8) -> i32 {
        // SAFETY: selected only when is_x86_feature_detected!("avx2") held
        // (the VNNI kernel reuses this arm; avx512vnni implies avx2).
        unsafe { popdot_avx2(a, w, words, bits_a, bits_w) }
    }

    #[cfg(feature = "avx512")]
    pub fn micro_vnni_entry(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        // SAFETY: selected only when avx512f+avx512bw+avx512vnni detected.
        unsafe { micro_vnni(abuf, k, rows, start, end, wseg, acc) }
    }

    /// AVX2 microkernel: two K positions per step. The two `NR`-wide code
    /// lines are byte-interleaved so each i16 pair holds `(w[p][jj],
    /// w[p+1][jj])`, widened zero-extending, and `_mm256_madd_epi16` against
    /// the broadcast `(a[p], a[p+1])` pair accumulates both positions into
    /// the i32 lane of column `jj` — exact, unlike the saturating maddubs.
    #[target_feature(enable = "avx2")]
    unsafe fn micro_avx2(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        debug_assert!(NR == 16, "AVX2 microkernel assumes one 16-byte line per position");
        debug_assert!(wseg.len() >= (end - start) * NR);
        debug_assert!(rows <= MR && abuf.len() >= rows.saturating_sub(1) * k + end);
        let len = end - start;
        let wp = wseg.as_ptr();
        let mut vacc = [[_mm256_setzero_si256(); 2]; MR];
        let mut p = 0usize;
        while p + 1 < len {
            let w0 = _mm_loadu_si128(wp.add(p * NR) as *const __m128i);
            let w1 = _mm_loadu_si128(wp.add((p + 1) * NR) as *const __m128i);
            let wlo = _mm256_cvtepu8_epi16(_mm_unpacklo_epi8(w0, w1)); // jj 0..8
            let whi = _mm256_cvtepu8_epi16(_mm_unpackhi_epi8(w0, w1)); // jj 8..16
            for mr in 0..rows {
                let a0 = *abuf.get_unchecked(mr * k + start + p) as i32;
                let a1 = *abuf.get_unchecked(mr * k + start + p + 1) as i32;
                let av = _mm256_set1_epi32(a0 | (a1 << 16));
                let lane = vacc.get_unchecked_mut(mr);
                lane[0] = _mm256_add_epi32(lane[0], _mm256_madd_epi16(wlo, av));
                lane[1] = _mm256_add_epi32(lane[1], _mm256_madd_epi16(whi, av));
            }
            p += 2;
        }
        if p < len {
            // Odd tail position: pair with a zero line (zero products).
            let w0 = _mm_loadu_si128(wp.add(p * NR) as *const __m128i);
            let z = _mm_setzero_si128();
            let wlo = _mm256_cvtepu8_epi16(_mm_unpacklo_epi8(w0, z));
            let whi = _mm256_cvtepu8_epi16(_mm_unpackhi_epi8(w0, z));
            for mr in 0..rows {
                let a0 = *abuf.get_unchecked(mr * k + start + p) as i32;
                let av = _mm256_set1_epi32(a0);
                let lane = vacc.get_unchecked_mut(mr);
                lane[0] = _mm256_add_epi32(lane[0], _mm256_madd_epi16(wlo, av));
                lane[1] = _mm256_add_epi32(lane[1], _mm256_madd_epi16(whi, av));
            }
        }
        for mr in 0..rows {
            let mut tmp = [0i32; NR];
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, vacc[mr][0]);
            _mm256_storeu_si256(tmp.as_mut_ptr().add(8) as *mut __m256i, vacc[mr][1]);
            let lane = &mut acc[mr];
            for jj in 0..NR {
                lane[jj] += tmp[jj];
            }
        }
    }

    /// AVX2 bucketing: one 16-wide u8 weight line widens to two i32 vectors
    /// and adds into the bucket row its activation code selects — the §V
    /// add-only datapath at vector width.
    #[target_feature(enable = "avx2")]
    unsafe fn bucket_avx2(qa: &[u8], wseg: &[u8], buckets: &mut [[i32; NR]; MAX_CODES]) {
        debug_assert!(NR == 16);
        debug_assert!(wseg.len() >= qa.len() * NR);
        let wp = wseg.as_ptr();
        for (pi, &c) in qa.iter().enumerate() {
            let wv = _mm_loadu_si128(wp.add(pi * NR) as *const __m128i);
            let lo = _mm256_cvtepu8_epi32(wv);
            let hi = _mm256_cvtepu8_epi32(_mm_srli_si128::<8>(wv));
            // Checked: codes are caller data (all-pub QuantizedMatrix), and
            // the scalar arm panics on an out-of-range code — match it
            // rather than turn bad input into unchecked writes.
            let bp = buckets[c as usize].as_mut_ptr();
            let b0 = _mm256_loadu_si256(bp as *const __m256i);
            let b1 = _mm256_loadu_si256(bp.add(8) as *const __m256i);
            _mm256_storeu_si256(bp as *mut __m256i, _mm256_add_epi32(b0, lo));
            _mm256_storeu_si256(bp.add(8) as *mut __m256i, _mm256_add_epi32(b1, hi));
        }
    }

    /// Horizontal sum of four u64 lanes — popcount epilogue helper.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epu64(v: __m256i) -> u64 {
        let mut t = [0u64; 4];
        _mm256_storeu_si256(t.as_mut_ptr() as *mut __m256i, v);
        t[0] + t[1] + t[2] + t[3]
    }

    /// Byte-wise popcount of a 256-bit vector via the `vpshufb` nibble LUT
    /// (the Mula method) — exact, and portable to every AVX2 host, unlike
    /// `vpopcntq` which needs AVX-512 VPOPCNTDQ.
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_bytes_avx2(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi64::<4>(v), low);
        _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
    }

    /// AND+popcount over `words` u64 words of one plane pair: 4 words per
    /// step through the nibble-LUT byte popcount, `vpsadbw` folding the
    /// byte counts into u64 lanes; scalar `count_ones` tail.
    #[target_feature(enable = "avx2")]
    unsafe fn popcount_and_avx2(a: *const u64, w: *const u64, words: usize) -> u32 {
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 4 <= words {
            let v = _mm256_and_si256(
                _mm256_loadu_si256(a.add(i) as *const __m256i),
                _mm256_loadu_si256(w.add(i) as *const __m256i),
            );
            let cnt = popcnt_bytes_avx2(v);
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()));
            i += 4;
        }
        let mut c = hsum_epu64(acc) as u32;
        while i < words {
            c += (*a.add(i) & *w.add(i)).count_ones();
            i += 1;
        }
        c
    }

    /// AVX2 bit-serial plane dot. For 1/2-bit x 1/2-bit operands every
    /// plane pair's byte counts combine **before** the `vpsadbw` fold:
    /// per-byte counts are <= 8 and the pair weights sum to <= 9, so the
    /// weighted byte total stays <= 72 < 256 — one horizontal fold per
    /// 4-word block covers all pairs. Wider pairs (weights up to 64) would
    /// overflow the byte domain, so 4-bit operands take the per-pair path.
    #[target_feature(enable = "avx2")]
    unsafe fn popdot_avx2(a: &[u64], w: &[u64], words: usize, bits_a: u8, bits_w: u8) -> i32 {
        let (ba, bw) = (bits_a as usize, bits_w as usize);
        debug_assert!(a.len() >= ba * words && w.len() >= bw * words);
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let mut total = 0u32;
        if ba <= 2 && bw <= 2 {
            let zero = _mm256_setzero_si256();
            let mut acc = zero;
            let mut i = 0usize;
            while i + 4 <= words {
                let mut wsum = zero; // weighted byte counts, <= 72 per byte
                for bi in 0..ba {
                    let x = _mm256_loadu_si256(ap.add(bi * words + i) as *const __m256i);
                    for bj in 0..bw {
                        let y = _mm256_loadu_si256(wp.add(bj * words + i) as *const __m256i);
                        let mut cnt = popcnt_bytes_avx2(_mm256_and_si256(x, y));
                        // Scale by 2^(bi+bj) in the byte domain (exact:
                        // counts stay under the u8 ceiling, see above).
                        for _ in 0..bi + bj {
                            cnt = _mm256_add_epi8(cnt, cnt);
                        }
                        wsum = _mm256_add_epi8(wsum, cnt);
                    }
                }
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(wsum, zero));
                i += 4;
            }
            total += hsum_epu64(acc) as u32;
            for bi in 0..ba {
                for bj in 0..bw {
                    let mut c = 0u32;
                    for t in i..words {
                        c += (*ap.add(bi * words + t) & *wp.add(bj * words + t)).count_ones();
                    }
                    total += c << (bi + bj);
                }
            }
            return total as i32;
        }
        for bi in 0..ba {
            for bj in 0..bw {
                let c = popcount_and_avx2(ap.add(bi * words), wp.add(bj * words), words);
                total += c << (bi + bj);
            }
        }
        total as i32
    }

    /// AVX-512 VNNI microkernel: four K positions per `vpdpbusd`. The 4x16
    /// code block transposes (two unpack rounds) so each 32-bit group holds
    /// column `jj`'s four codes; weights bias-flip to s8 (`w ^ 0x80` ==
    /// `w - 128`) and the `128 * sum(a)` term is added back per row.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
    unsafe fn micro_vnni(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        debug_assert!(NR == 16);
        debug_assert!(wseg.len() >= (end - start) * NR);
        let len = end - start;
        let wp = wseg.as_ptr();
        let flip = _mm512_set1_epi8(-128i8);
        let mut vacc = [_mm512_setzero_si512(); MR];
        // Running sum of the vectorized activation bytes per row, gathered
        // while the main loop already holds them — feeds the bias-flip
        // compensation below without a second pass over `abuf`.
        let mut asum = [0u32; MR];
        let mut p = 0usize;
        while p + 4 <= len {
            let w0 = _mm_loadu_si128(wp.add(p * NR) as *const __m128i);
            let w1 = _mm_loadu_si128(wp.add((p + 1) * NR) as *const __m128i);
            let w2 = _mm_loadu_si128(wp.add((p + 2) * NR) as *const __m128i);
            let w3 = _mm_loadu_si128(wp.add((p + 3) * NR) as *const __m128i);
            let t0 = _mm_unpacklo_epi8(w0, w1);
            let t1 = _mm_unpackhi_epi8(w0, w1);
            let t2 = _mm_unpacklo_epi8(w2, w3);
            let t3 = _mm_unpackhi_epi8(w2, w3);
            let u0 = _mm_unpacklo_epi16(t0, t2); // columns 0..4
            let u1 = _mm_unpackhi_epi16(t0, t2); // columns 4..8
            let u2 = _mm_unpacklo_epi16(t1, t3); // columns 8..12
            let u3 = _mm_unpackhi_epi16(t1, t3); // columns 12..16
            let mut wv = _mm512_castsi128_si512(u0);
            wv = _mm512_inserti32x4::<1>(wv, u1);
            wv = _mm512_inserti32x4::<2>(wv, u2);
            wv = _mm512_inserti32x4::<3>(wv, u3);
            let ws = _mm512_xor_si512(wv, flip); // u8 -> s8: w - 128
            for mr in 0..rows {
                let ap = abuf.as_ptr().add(mr * k + start + p);
                let a = u32::from_le_bytes([*ap, *ap.add(1), *ap.add(2), *ap.add(3)]);
                asum[mr] += (a & 0xff) + ((a >> 8) & 0xff) + ((a >> 16) & 0xff) + (a >> 24);
                let av = _mm512_set1_epi32(a as i32);
                let lane = vacc.get_unchecked_mut(mr);
                *lane = _mm512_dpbusd_epi32(*lane, av, ws);
            }
            p += 4;
        }
        // Scalar tail (at most 3 positions — short tail regions only).
        for pt in p..len {
            for mr in 0..rows {
                let a = *abuf.get_unchecked(mr * k + start + pt) as i32;
                if a == 0 {
                    continue;
                }
                let lane = &mut acc[mr];
                for jj in 0..NR {
                    lane[jj] += a * *wseg.get_unchecked(pt * NR + jj) as i32;
                }
            }
        }
        for mr in 0..rows {
            let mut tmp = [0i32; NR];
            _mm512_storeu_epi32(tmp.as_mut_ptr(), vacc[mr]);
            // Bias-flip compensation over the vectorized positions:
            // sum(a * (w - 128)) + 128 * sum(a) == sum(a * w).
            let comp = asum[mr] as i32 * 128;
            let lane = &mut acc[mr];
            for jj in 0..NR {
                lane[jj] += tmp[jj] + comp;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod aarch64 {
    use super::{MAX_CODES, MR, NR};
    use std::arch::aarch64::*;

    // Safe entry shims, mirroring the x86 module: the dispatcher installs
    // these fn pointers only after `is_aarch64_feature_detected!` succeeded,
    // so the unsafe target_feature call inside each shim is sound.

    pub fn micro_neon_entry(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        // SAFETY: selected only when is_aarch64_feature_detected!("neon") held.
        unsafe { micro_neon(abuf, k, rows, start, end, wseg, acc) }
    }

    pub fn bucket_neon_entry(qa: &[u8], wseg: &[u8], buckets: &mut [[i32; NR]; MAX_CODES]) {
        // SAFETY: selected only when is_aarch64_feature_detected!("neon") held.
        unsafe { bucket_neon(qa, wseg, buckets) }
    }

    pub fn popdot_neon_entry(a: &[u64], w: &[u64], words: usize, bits_a: u8, bits_w: u8) -> i32 {
        // SAFETY: selected only when is_aarch64_feature_detected!("neon")
        // held (the dotprod kernel reuses this arm; dotprod implies neon).
        unsafe { popdot_neon(a, w, words, bits_a, bits_w) }
    }

    /// NEON bit-serial plane dot: `vcntq_u8` byte popcounts over the ANDed
    /// plane words, folded with the widening horizontal add `vaddlvq_u8`.
    /// Mirrors the AVX2 arm's structure: for 1/2-bit x 1/2-bit operands all
    /// plane pairs' byte counts combine before one fold per 2-word block
    /// (weighted byte totals <= 72 < 256, exact in u8); wider pairs take
    /// the per-pair path.
    #[target_feature(enable = "neon")]
    unsafe fn popdot_neon(a: &[u64], w: &[u64], words: usize, bits_a: u8, bits_w: u8) -> i32 {
        let (ba, bw) = (bits_a as usize, bits_w as usize);
        debug_assert!(a.len() >= ba * words && w.len() >= bw * words);
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let mut total = 0u32;
        if ba <= 2 && bw <= 2 {
            let mut i = 0usize;
            while i + 2 <= words {
                let mut wsum = vdupq_n_u8(0); // weighted byte counts, <= 72
                for bi in 0..ba {
                    let x = vreinterpretq_u8_u64(vld1q_u64(ap.add(bi * words + i)));
                    for bj in 0..bw {
                        let y = vreinterpretq_u8_u64(vld1q_u64(wp.add(bj * words + i)));
                        let mut cnt = vcntq_u8(vandq_u8(x, y));
                        for _ in 0..bi + bj {
                            cnt = vaddq_u8(cnt, cnt);
                        }
                        wsum = vaddq_u8(wsum, cnt);
                    }
                }
                total += vaddlvq_u8(wsum) as u32;
                i += 2;
            }
            for bi in 0..ba {
                for bj in 0..bw {
                    let mut c = 0u32;
                    for t in i..words {
                        c += (*ap.add(bi * words + t) & *wp.add(bj * words + t)).count_ones();
                    }
                    total += c << (bi + bj);
                }
            }
            return total as i32;
        }
        for bi in 0..ba {
            for bj in 0..bw {
                let pa = ap.add(bi * words);
                let pw = wp.add(bj * words);
                let mut c = 0u32;
                let mut i = 0usize;
                while i + 2 <= words {
                    let v = vandq_u64(vld1q_u64(pa.add(i)), vld1q_u64(pw.add(i)));
                    c += vaddlvq_u8(vcntq_u8(vreinterpretq_u8_u64(v))) as u32;
                    i += 2;
                }
                while i < words {
                    c += (*pa.add(i) & *pw.add(i)).count_ones();
                    i += 1;
                }
                total += c << (bi + bj);
            }
        }
        total as i32
    }

    #[cfg(feature = "dotprod")]
    pub fn micro_dotprod_entry(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        // SAFETY: selected only when is_aarch64_feature_detected!("dotprod") held.
        unsafe { micro_dotprod(abuf, k, rows, start, end, wseg, acc) }
    }

    /// Store the `[4 x u32x4]` vector accumulators of each row out into the
    /// caller's i32 lanes — shared epilogue of both aarch64 tiles. The
    /// u32 -> i32 reinterpret is lossless: per-region totals stay below
    /// 2^31 for regions shorter than 2^15, the shared contract.
    #[target_feature(enable = "neon")]
    unsafe fn store_acc(
        vacc: &[[uint32x4_t; 4]; MR],
        rows: usize,
        acc: &mut [[i32; NR]; MR],
    ) {
        for mr in 0..rows {
            let mut tmp = [0u32; NR];
            vst1q_u32(tmp.as_mut_ptr(), vacc[mr][0]);
            vst1q_u32(tmp.as_mut_ptr().add(4), vacc[mr][1]);
            vst1q_u32(tmp.as_mut_ptr().add(8), vacc[mr][2]);
            vst1q_u32(tmp.as_mut_ptr().add(12), vacc[mr][3]);
            let lane = &mut acc[mr];
            for jj in 0..NR {
                lane[jj] += tmp[jj] as i32;
            }
        }
    }

    /// NEON microkernel: one K position per step. The 16-byte weight line
    /// widens once (`vmovl_u8`, amortized over the MR rows) to two u16x8
    /// vectors; each activation broadcasts as u16 and `vmlal_u16` widens
    /// u16 x u16 products into the u32 accumulators — exact at every step
    /// (255 * 255 = 65025 fits u16, and the per-region u32 totals stay
    /// below 2^31 for regions shorter than 2^15, the shared contract).
    #[target_feature(enable = "neon")]
    unsafe fn micro_neon(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        debug_assert!(NR == 16, "NEON microkernel assumes one 16-byte line per position");
        debug_assert!(wseg.len() >= (end - start) * NR);
        debug_assert!(rows <= MR && abuf.len() >= rows.saturating_sub(1) * k + end);
        let len = end - start;
        let wp = wseg.as_ptr();
        let mut vacc = [[vdupq_n_u32(0); 4]; MR];
        for p in 0..len {
            let w = vld1q_u8(wp.add(p * NR));
            let wlo = vmovl_u8(vget_low_u8(w)); // jj 0..8 as u16
            let whi = vmovl_u8(vget_high_u8(w)); // jj 8..16 as u16
            for mr in 0..rows {
                let a = *abuf.get_unchecked(mr * k + start + p);
                if a == 0 {
                    continue; // ReLU-sparse activations quantize to code 0 often
                }
                let av = vdup_n_u16(a as u16);
                let lane = vacc.get_unchecked_mut(mr);
                lane[0] = vmlal_u16(lane[0], vget_low_u16(wlo), av);
                lane[1] = vmlal_u16(lane[1], vget_high_u16(wlo), av);
                lane[2] = vmlal_u16(lane[2], vget_low_u16(whi), av);
                lane[3] = vmlal_u16(lane[3], vget_high_u16(whi), av);
            }
        }
        store_acc(&vacc, rows, acc);
    }

    /// NEON bucketing: one 16-wide u8 weight line widens to four i32x4
    /// vectors and adds into the bucket row its activation code selects —
    /// the §V add-only datapath at vector width.
    #[target_feature(enable = "neon")]
    unsafe fn bucket_neon(qa: &[u8], wseg: &[u8], buckets: &mut [[i32; NR]; MAX_CODES]) {
        debug_assert!(NR == 16);
        debug_assert!(wseg.len() >= qa.len() * NR);
        let wp = wseg.as_ptr();
        for (pi, &c) in qa.iter().enumerate() {
            let w = vld1q_u8(wp.add(pi * NR));
            let wlo = vmovl_u8(vget_low_u8(w));
            let whi = vmovl_u8(vget_high_u8(w));
            let w0 = vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(wlo)));
            let w1 = vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(wlo)));
            let w2 = vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(whi)));
            let w3 = vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(whi)));
            // Checked index: match the scalar arm's panic on an out-of-range
            // code instead of turning bad caller data into unchecked writes
            // (same policy as the AVX2 bucketing arm).
            let bp = buckets[c as usize].as_mut_ptr();
            vst1q_s32(bp, vaddq_s32(vld1q_s32(bp), w0));
            vst1q_s32(bp.add(4), vaddq_s32(vld1q_s32(bp.add(4)), w1));
            vst1q_s32(bp.add(8), vaddq_s32(vld1q_s32(bp.add(8)), w2));
            vst1q_s32(bp.add(12), vaddq_s32(vld1q_s32(bp.add(12)), w3));
        }
    }

    /// Dotprod microkernel: four K positions per step via `udot`
    /// (`vdotq_u32`), which sums u8 x u8 groups of four into u32 lanes.
    /// Both operands are unsigned, so unlike the VNNI arm there is no
    /// bias-flip and no `128 * sum(a)` compensation — `udot` is exact on the
    /// raw codes. The 4x16 code block transposes with two zip rounds
    /// (`vzip1q_u8`/`vzip2q_u8` then the u16 pair) so each 32-bit group
    /// holds one column's four consecutive codes, matching the 4-byte
    /// activation broadcast.
    #[cfg(feature = "dotprod")]
    #[target_feature(enable = "neon,dotprod")]
    unsafe fn micro_dotprod(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
        acc: &mut [[i32; NR]; MR],
    ) {
        debug_assert!(NR == 16);
        debug_assert!(wseg.len() >= (end - start) * NR);
        let len = end - start;
        let wp = wseg.as_ptr();
        let mut vacc = [[vdupq_n_u32(0); 4]; MR];
        let mut p = 0usize;
        while p + 4 <= len {
            let w0 = vld1q_u8(wp.add(p * NR));
            let w1 = vld1q_u8(wp.add((p + 1) * NR));
            let w2 = vld1q_u8(wp.add((p + 2) * NR));
            let w3 = vld1q_u8(wp.add((p + 3) * NR));
            let t0 = vzip1q_u8(w0, w1);
            let t1 = vzip2q_u8(w0, w1);
            let t2 = vzip1q_u8(w2, w3);
            let t3 = vzip2q_u8(w2, w3);
            let (t0, t1) = (vreinterpretq_u16_u8(t0), vreinterpretq_u16_u8(t1));
            let (t2, t3) = (vreinterpretq_u16_u8(t2), vreinterpretq_u16_u8(t3));
            // columns 0..4 (each lane-group = 4 consecutive codes), 4..8,
            // 8..12, 12..16:
            let u0 = vreinterpretq_u8_u16(vzip1q_u16(t0, t2));
            let u1 = vreinterpretq_u8_u16(vzip2q_u16(t0, t2));
            let u2 = vreinterpretq_u8_u16(vzip1q_u16(t1, t3));
            let u3 = vreinterpretq_u8_u16(vzip2q_u16(t1, t3));
            for mr in 0..rows {
                let ap = abuf.as_ptr().add(mr * k + start + p);
                let a = u32::from_le_bytes([*ap, *ap.add(1), *ap.add(2), *ap.add(3)]);
                let av = vreinterpretq_u8_u32(vdupq_n_u32(a));
                let lane = vacc.get_unchecked_mut(mr);
                lane[0] = vdotq_u32(lane[0], av, u0);
                lane[1] = vdotq_u32(lane[1], av, u1);
                lane[2] = vdotq_u32(lane[2], av, u2);
                lane[3] = vdotq_u32(lane[3], av, u3);
            }
            p += 4;
        }
        // Scalar tail (at most 3 positions — short tail regions only).
        for pt in p..len {
            for mr in 0..rows {
                let a = *abuf.get_unchecked(mr * k + start + pt) as i32;
                if a == 0 {
                    continue;
                }
                let lane = &mut acc[mr];
                for jj in 0..NR {
                    lane[jj] += a * *wseg.get_unchecked(pt * NR + jj) as i32;
                }
            }
        }
        store_acc(&vacc, rows, acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ref_acc(
        abuf: &[u8],
        k: usize,
        rows: usize,
        start: usize,
        end: usize,
        wseg: &[u8],
    ) -> [[i32; NR]; MR] {
        let mut acc = [[0i32; NR]; MR];
        for p in start..end {
            for mr in 0..rows {
                let a = abuf[mr * k + p] as i32;
                for jj in 0..NR {
                    acc[mr][jj] += a * wseg[(p - start) * NR + jj] as i32;
                }
            }
        }
        acc
    }

    #[test]
    fn every_supported_kernel_matches_scalar_on_random_segments() {
        // Covers the dispatched arm AND the non-default arms (e.g. both the
        // neon-umlal and neon-udot tiles on a dotprod-capable aarch64 host,
        // avx2-madd on a VNNI host) — bit-exact, per the dispatch contract.
        for kernel in supported_kernels() {
            let mut rng = Rng::new(0x51D0);
            for case in 0..200 {
                let k = 1 + (rng.below(96) as usize);
                let rows = 1 + (rng.below(MR as u64) as usize);
                let start = rng.below(k as u64) as usize;
                let end = start + 1 + rng.below((k - start) as u64) as usize;
                let abuf: Vec<u8> = (0..rows * k).map(|_| rng.below(256) as u8).collect();
                let wseg: Vec<u8> =
                    (0..(end - start) * NR).map(|_| rng.below(256) as u8).collect();
                let want = ref_acc(&abuf, k, rows, start, end, &wseg);
                let mut got = [[0i32; NR]; MR];
                kernel.run_micro(&abuf, k, rows, start, end, &wseg, &mut got);
                assert_eq!(
                    got, want,
                    "kernel {} case {case} k={k} rows={rows} seg={start}..{end}",
                    kernel.name
                );
            }
        }
    }

    #[test]
    fn every_supported_bucket_matches_scalar() {
        for kernel in supported_kernels() {
            let mut rng = Rng::new(0x51D1);
            for bits in [1u8, 2, 4] {
                let len = 1 + (rng.below(120) as usize);
                let qa: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
                let wseg: Vec<u8> = (0..len * NR).map(|_| rng.below(256) as u8).collect();
                let mut want = [[0i32; NR]; MAX_CODES];
                scalar_kernel().run_bucket(&qa, &wseg, &mut want);
                let mut got = [[0i32; NR]; MAX_CODES];
                kernel.run_bucket(&qa, &wseg, &mut got);
                assert_eq!(got, want, "kernel {} bits={bits} len={len}", kernel.name);
            }
        }
    }

    /// Oracle for the popdot slot: decode each position's code from the
    /// planes and take the plain integer dot — independent of the
    /// bit-plane algebra the arms implement.
    fn ref_popdot(a: &[u64], w: &[u64], words: usize, ba: u8, bw: u8) -> i32 {
        let mut total = 0i64;
        for p in 0..words * 64 {
            let (wi, bit) = (p / 64, p % 64);
            let mut ac = 0u32;
            let mut wc = 0u32;
            for bi in 0..ba as usize {
                ac |= (((a[bi * words + wi] >> bit) & 1) as u32) << bi;
            }
            for bj in 0..bw as usize {
                wc |= (((w[bj * words + wi] >> bit) & 1) as u32) << bj;
            }
            total += (ac * wc) as i64;
        }
        total as i32
    }

    #[test]
    fn every_supported_popdot_matches_decode_oracle() {
        // Random dense plane words (not just plausible code streams): the
        // arms must be exact on any bit pattern, including full-weight
        // regions where every popcount saturates to the word width.
        for kernel in supported_kernels() {
            let mut rng = Rng::new(0x51D8);
            for case in 0..300 {
                let words = 1 + rng.below(24) as usize;
                let ba = 1 + rng.below(4) as u8;
                let bw = 1 + rng.below(4) as u8;
                let a: Vec<u64> = (0..ba as usize * words).map(|_| rng.next_u64()).collect();
                let w: Vec<u64> = (0..bw as usize * words).map(|_| rng.next_u64()).collect();
                let want = ref_popdot(&a, &w, words, ba, bw);
                assert_eq!(
                    scalar_popdot(&a, &w, words, ba, bw),
                    want,
                    "scalar case {case} words={words} a{ba}/w{bw}"
                );
                let got = kernel.run_popdot(&a, &w, words, ba, bw);
                assert_eq!(
                    got, want,
                    "kernel {} case {case} words={words} a{ba}/w{bw}",
                    kernel.name
                );
            }
        }
    }

    #[test]
    fn scalar_is_always_available() {
        let s = scalar_kernel();
        assert_eq!(s.name, "scalar");
        assert_eq!(s.isa, "portable");
        // detection never panics and returns a non-empty tag
        assert!(!detected_isa().is_empty());
        assert!(!active().name.is_empty());
        // the supported list always starts with the scalar arm, names unique
        let ks = supported_kernels();
        assert_eq!(ks[0].name, "scalar");
        let names: std::collections::HashSet<_> = ks.iter().map(|k| k.name).collect();
        assert_eq!(names.len(), ks.len(), "kernel names must be unique");
        // the dispatcher's pick is always one of the supported arms
        assert!(names.contains(active().name) || active().name == "scalar");
    }
}
