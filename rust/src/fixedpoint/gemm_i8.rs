//! Eq. 7 — the LQ fixed-point GEMM.
//!
//! `out = A_q * W_q^T` where both operands are [`QuantizedMatrix`] with the
//! *same* region size along K. The inner loop is pure integer multiply-
//! accumulate over u8 codes (what the Edison's SIMD lanes / the FPGA CUs
//! execute); the per-region affine correction uses the precomputed code sums:
//!
//! ```text
//! dot(a_i, w_j) = sum_r [ sa_ir*sw_jr*S_qq + sa_ir*mw_jr*S_qa
//!                       + sw_jr*ma_ir*S_qw + len_r*ma_ir*mw_jr ]
//! ```
//!
//! [`gemm_quantized`] runs on the shared packed weight-panel core
//! ([`super::panel`]): the weight codes are widened once into `NR`-wide
//! K-major tiles and the integer MACs run in an `MR`x`NR` register tile
//! whose implementation the SIMD dispatcher ([`super::simd`]) selects at
//! runtime (AVX2 / AVX-512-VNNI / portable scalar), for any regions-per-row
//! and any K (the seed's `rpr == 1 && k <= 128` axpy special case is
//! subsumed). [`gemm_quantized_naive`] preserves the seed's scalar
//! dot-per-output formulation as the bit-exactness oracle and the perf
//! baseline `benches/gemm_micro.rs` measures speedups against.
//!
//! Bit-exact vs the python oracle `quant.lq_matmul_reference` (pinned by
//! `rust/tests/quant_parity.rs`) up to f32 summation order.

use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

use super::panel::{gemm_panel, WeightPanel};

/// Compute `A_q (M,K) x W_q^T (N,K) -> (M,N)` on the panel core.
///
/// `wq` holds the weights transposed — row j is output channel j — matching
/// the offline layout the paper uses (kernels quantized per region offline).
/// The weight panel is built per call here; callers that reuse a weight
/// matrix (every model layer) should build a [`WeightPanel`] once and call
/// [`gemm_panel`] directly — `nn::forward::Engine` caches panels that way.
pub fn gemm_quantized(aq: &QuantizedMatrix, wq: &QuantizedMatrix, threads: usize) -> Tensor {
    assert_eq!(aq.k, wq.k, "reduction dims differ: {} vs {}", aq.k, wq.k);
    assert_eq!(
        aq.group_len(),
        wq.group_len(),
        "operands must share the region size along K"
    );
    let wp = WeightPanel::from_quantized(wq);
    gemm_panel(aq, &wp, threads)
}

/// The seed scalar formulation: one u8 dot product per `(i, j, region)`.
///
/// Kept as (a) the oracle the panel kernels are property-tested against and
/// (b) the baseline `benches/gemm_micro.rs` reports panel speedups over.
pub fn gemm_quantized_naive(aq: &QuantizedMatrix, wq: &QuantizedMatrix, threads: usize) -> Tensor {
    assert_eq!(aq.k, wq.k, "reduction dims differ: {} vs {}", aq.k, wq.k);
    assert_eq!(
        aq.group_len(),
        wq.group_len(),
        "operands must share the region size along K"
    );
    let m = aq.rows;
    let n = wq.rows;
    let k = aq.k;
    let rpr = aq.regions_per_row();
    let mut out = vec![0.0f32; m * n];

    let out_ptr = SyncPtr(out.as_mut_ptr());
    scope_chunks(m, threads, |i0, i1| {
        let out_ptr = &out_ptr;
        for i in i0..i1 {
            // SAFETY: row i is written by exactly one chunk.
            let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            let arow = aq.row_codes(i);
            let (sa_r, ma_r, sqa_r) = aq.affine_row(i);
            for (j, o) in orow.iter_mut().enumerate() {
                let wrow = wq.row_codes(j);
                let (sw_r, mw_r, sqw_r) = wq.affine_row(j);
                let mut acc = 0.0f32;
                for r in 0..rpr {
                    let (start, end) = aq.region_bounds(r);
                    // Integer MAC over the region (the fixed-point datapath).
                    let qq = dot_u8(&arow[start..end], &wrow[start..end]);
                    let len = (end - start) as f32;
                    acc += sa_r[r] * sw_r[r] * qq as f32
                        + sa_r[r] * mw_r[r] * sqa_r[r]
                        + sw_r[r] * ma_r[r] * sqw_r[r]
                        + len * ma_r[r] * mw_r[r];
                }
                *o = acc;
            }
        }
    });
    Tensor::new(&[m, n], out)
}

/// Vectorizable u8 dot product with i32 accumulation — the 8-bit integer
/// datapath the paper exploits (the Edison's `pmaddubsw` lanes; with
/// `target-cpu=native` LLVM lowers this reduction to AVX-512 widening MACs
/// at ~15 GMAC/s on the build host, vs ~1.5 for a scalar f32 dot).
/// Products fit i32 with huge headroom (255*255*K, K < 2^15).
#[inline]
pub(crate) fn dot_u8(a: &[u8], b: &[u8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as i32 * y as i32).sum()
}

/// Raw output pointer shared across `scope_chunks` workers.
pub(crate) struct SyncPtr<T>(pub *mut T);
// SAFETY: callers partition the output rows disjointly across threads.
unsafe impl<T: Send> Sync for SyncPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant, quantize_matrix, RegionSpec};
    use crate::util::prop;

    /// Oracle: fake-quant both operands, then exact f32 matmul.
    fn oracle(a: &Tensor, w_t: &Tensor, bits: u8, region: RegionSpec) -> Tensor {
        let aq = fake_quant(a, bits, region);
        let wq = fake_quant(w_t, bits, region);
        // (M,K) x (N,K)^T
        let mut out = vec![0.0f32; a.dim(0) * w_t.dim(0)];
        for i in 0..a.dim(0) {
            for j in 0..w_t.dim(0) {
                let mut acc = 0.0f64;
                for p in 0..a.dim(1) {
                    acc += (aq.at2(i, p) as f64) * (wq.at2(j, p) as f64);
                }
                out[i * w_t.dim(0) + j] = acc as f32;
            }
        }
        Tensor::new(&[a.dim(0), w_t.dim(0)], out)
    }

    #[test]
    fn equals_fakequant_oracle() {
        prop::check_named("gemm-i8-vs-oracle", 0x17, 40, |rng, _| {
            let m = rng.index(1, 12);
            let n = rng.index(1, 12);
            let k = rng.index(1, 48);
            let bits = prop::gen_bits(rng) as u8;
            let region = match rng.below(3) {
                0 => RegionSpec::PerRow,
                1 => RegionSpec::Size(rng.index(1, k + 1)),
                _ => RegionSpec::PerTensor,
            };
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, bits, region);
            for threads in [1, 3] {
                let got = gemm_quantized(&aq, &wq, threads);
                let want = oracle(&a, &w, bits, region);
                let tol = 1e-3 * want.max_abs().max(1.0) + 1e-4;
                assert!(
                    got.max_abs_diff(&want) <= tol,
                    "m={m} n={n} k={k} bits={bits} region={region} diff={}",
                    got.max_abs_diff(&want)
                );
            }
        });
    }

    #[test]
    fn eight_bit_close_to_f32() {
        // 8-bit LQ should track the f32 product tightly (Table 1's mechanism).
        let mut rng = crate::util::rng::Rng::new(5);
        let a = Tensor::new(&[16, 75], rng.normal_vec(16 * 75));
        let w = Tensor::new(&[32, 75], rng.normal_vec(32 * 75));
        let aq = quantize_matrix(&a, 8, RegionSpec::PerRow);
        let wq = quantize_matrix(&w, 8, RegionSpec::PerRow);
        let got = gemm_quantized(&aq, &wq, 1);
        let exact = super::super::gemm_f32::gemm_naive(&a, &w.transpose2());
        let rel = got.max_abs_diff(&exact) / exact.max_abs();
        assert!(rel < 0.01, "8-bit LQ relative error {rel}");
    }

    #[test]
    fn naive_matches_panel() {
        // The seed formulation and the panel core are the same math; pin
        // them together tightly (f32 association differs, hence the epsilon).
        prop::check_named("gemm-naive-vs-panel", 0x18, 32, |rng, _| {
            let m = rng.index(1, 20);
            let n = rng.index(1, 40); // cross NR tile boundaries
            let k = rng.index(1, 60);
            let bits = prop::gen_bits(rng) as u8;
            let region = RegionSpec::Size(rng.index(1, k + 1));
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, bits, region);
            let want = gemm_quantized_naive(&aq, &wq, 1);
            let got = gemm_quantized(&aq, &wq, 2);
            assert!(
                got.max_abs_diff(&want) <= 1e-5 * want.max_abs().max(1.0),
                "m={m} n={n} k={k} bits={bits} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    #[should_panic(expected = "region size")]
    fn mismatched_regions_panic() {
        let a = Tensor::zeros(&[2, 8]);
        let aq = quantize_matrix(&a, 8, RegionSpec::Size(4));
        let wq = quantize_matrix(&a, 8, RegionSpec::Size(2));
        gemm_quantized(&aq, &wq, 1);
    }
}
