//! Eq. 7 — the LQ fixed-point GEMM.
//!
//! `out = A_q * W_q^T` where both operands are [`QuantizedMatrix`] with the
//! *same* region size along K. The inner loop is pure integer multiply-
//! accumulate over u8 codes (what the Edison's SIMD lanes / the FPGA CUs
//! execute); the per-region affine correction uses the precomputed code sums:
//!
//! ```text
//! dot(a_i, w_j) = sum_r [ sa_ir*sw_jr*S_qq + sa_ir*mw_jr*S_qa
//!                       + sw_jr*ma_ir*S_qw + len_r*ma_ir*mw_jr ]
//! ```
//!
//! Bit-exact vs the python oracle `quant.lq_matmul_reference` (pinned by
//! `rust/tests/quant_parity.rs`) up to f32 summation order.

use crate::quant::scheme::QuantizedMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

/// Compute `A_q (M,K) x W_q^T (N,K) -> (M,N)`.
///
/// `wq` holds the weights transposed — row j is output channel j — matching
/// the offline layout the paper uses (kernels quantized per region offline).
pub fn gemm_quantized(aq: &QuantizedMatrix, wq: &QuantizedMatrix, threads: usize) -> Tensor {
    assert_eq!(aq.k, wq.k, "reduction dims differ: {} vs {}", aq.k, wq.k);
    assert_eq!(
        aq.group_len(),
        wq.group_len(),
        "operands must share the region size along K"
    );
    let m = aq.rows;
    let n = wq.rows;
    let k = aq.k;
    let g = aq.group_len();
    let rpr = aq.regions_per_row();
    let mut out = vec![0.0f32; m * n];

    // Fast path for the paper's default configuration (one region per row,
    // i.e. kernel-sized regions): the integer GEMM runs axpy-style over an
    // i32-widened W panel — no per-element reduction, so the compiler
    // vectorizes the full N width — and the affine correction collapses to
    // one vectorized pass per output row.
    // Short reductions can't amortize the SIMD prologue of the dot-product
    // formulation; the axpy path wins there. Long reductions prefer the
    // dot path (pmaddubsw-style u8 reduction, no W-panel widening cost).
    if rpr == 1 && k <= 128 {
        return gemm_rpr1(aq, wq, threads, out);
    }

    let out_ptr = SyncPtr(out.as_mut_ptr());
    scope_chunks(m, threads, |i0, i1| {
        let out_ptr = &out_ptr;
        for i in i0..i1 {
            // SAFETY: row i is written by exactly one chunk.
            let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            let arow = &aq.codes[i * k..(i + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                let wrow = &wq.codes[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for r in 0..rpr {
                    let start = r * g;
                    let end = ((r + 1) * g).min(k);
                    // Integer MAC over the region (the fixed-point datapath).
                    let qq = dot_u8(&arow[start..end], &wrow[start..end]);
                    let sa = aq.scale(i, r);
                    let ma = aq.min(i, r);
                    let sw = wq.scale(j, r);
                    let mw = wq.min(j, r);
                    let s_qa = aq.code_sums[i * rpr + r];
                    let s_qw = wq.code_sums[j * rpr + r];
                    let len = (end - start) as f32;
                    acc += sa * sw * qq as f32 + sa * mw * s_qa + sw * ma * s_qw + len * ma * mw;
                }
                *o = acc;
            }
        }
    });
    Tensor::new(&[m, n], out)
}

/// rpr == 1 fast path: axpy-formulated integer GEMM + fused correction.
fn gemm_rpr1(aq: &QuantizedMatrix, wq: &QuantizedMatrix, threads: usize, mut out: Vec<f32>) -> Tensor {
    let m = aq.rows;
    let n = wq.rows;
    let k = aq.k;
    // Widen W^T (N, K) codes into a (K, N) i32 panel once per call.
    let mut wpanel = vec![0i32; k * n];
    for j in 0..n {
        let wrow = &wq.codes[j * k..(j + 1) * k];
        for (p, &c) in wrow.iter().enumerate() {
            wpanel[p * n + j] = c as i32;
        }
    }
    let out_ptr = SyncPtr(out.as_mut_ptr());
    scope_chunks(m, threads, |i0, i1| {
        let out_ptr = &out_ptr;
        let mut acc = vec![0i32; n];
        for i in i0..i1 {
            // SAFETY: row i is written by exactly one chunk.
            let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            let arow = &aq.codes[i * k..(i + 1) * k];
            acc.fill(0);
            for (p, &a) in arow.iter().enumerate() {
                if a == 0 {
                    continue; // ReLU-sparse activations quantize to code 0 often
                }
                let av = a as i32;
                let wrow = &wpanel[p * n..(p + 1) * n];
                for (dst, &w) in acc.iter_mut().zip(wrow) {
                    *dst += av * w;
                }
            }
            // Correction (eq. 7, single region): fused vectorized pass.
            let sa = aq.scales[i];
            let ma = aq.mins[i];
            let s_qa = aq.code_sums[i];
            let len = k as f32;
            for (j, o) in orow.iter_mut().enumerate() {
                let sw = wq.scales[j];
                let mw = wq.mins[j];
                *o = sa * sw * acc[j] as f32
                    + sa * mw * s_qa
                    + sw * ma * wq.code_sums[j]
                    + len * ma * mw;
            }
        }
    });
    Tensor::new(&[m, n], out)
}

/// Vectorizable u8 dot product with i32 accumulation — the 8-bit integer
/// datapath the paper exploits (the Edison's `pmaddubsw` lanes; with
/// `target-cpu=native` LLVM lowers this reduction to AVX-512 widening MACs
/// at ~15 GMAC/s on the build host, vs ~1.5 for a scalar f32 dot).
/// Products fit i32 with huge headroom (255*255*K, K < 2^15).
#[inline]
pub(crate) fn dot_u8(a: &[u8], b: &[u8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as i32 * y as i32).sum()
}

pub(crate) struct SyncPtr(pub *mut f32);
// SAFETY: callers partition the output rows disjointly across threads.
unsafe impl Sync for SyncPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant, quantize_matrix, RegionSpec};
    use crate::util::prop;

    /// Oracle: fake-quant both operands, then exact f32 matmul.
    fn oracle(a: &Tensor, w_t: &Tensor, bits: u8, region: RegionSpec) -> Tensor {
        let aq = fake_quant(a, bits, region);
        let wq = fake_quant(w_t, bits, region);
        // (M,K) x (N,K)^T
        let mut out = vec![0.0f32; a.dim(0) * w_t.dim(0)];
        for i in 0..a.dim(0) {
            for j in 0..w_t.dim(0) {
                let mut acc = 0.0f64;
                for p in 0..a.dim(1) {
                    acc += (aq.at2(i, p) as f64) * (wq.at2(j, p) as f64);
                }
                out[i * w_t.dim(0) + j] = acc as f32;
            }
        }
        Tensor::new(&[a.dim(0), w_t.dim(0)], out)
    }

    #[test]
    fn equals_fakequant_oracle() {
        prop::check_named("gemm-i8-vs-oracle", 0x17, 40, |rng, _| {
            let m = rng.index(1, 12);
            let n = rng.index(1, 12);
            let k = rng.index(1, 48);
            let bits = prop::gen_bits(rng) as u8;
            let region = match rng.below(3) {
                0 => RegionSpec::PerRow,
                1 => RegionSpec::Size(rng.index(1, k + 1)),
                _ => RegionSpec::PerTensor,
            };
            let a = Tensor::new(&[m, k], prop::gen_values(rng, m * k));
            let w = Tensor::new(&[n, k], prop::gen_values(rng, n * k));
            let aq = quantize_matrix(&a, bits, region);
            let wq = quantize_matrix(&w, bits, region);
            for threads in [1, 3] {
                let got = gemm_quantized(&aq, &wq, threads);
                let want = oracle(&a, &w, bits, region);
                let tol = 1e-3 * want.max_abs().max(1.0) + 1e-4;
                assert!(
                    got.max_abs_diff(&want) <= tol,
                    "m={m} n={n} k={k} bits={bits} region={region} diff={}",
                    got.max_abs_diff(&want)
                );
            }
        });
    }

    #[test]
    fn eight_bit_close_to_f32() {
        // 8-bit LQ should track the f32 product tightly (Table 1's mechanism).
        let mut rng = crate::util::rng::Rng::new(5);
        let a = Tensor::new(&[16, 75], rng.normal_vec(16 * 75));
        let w = Tensor::new(&[32, 75], rng.normal_vec(32 * 75));
        let aq = quantize_matrix(&a, 8, RegionSpec::PerRow);
        let wq = quantize_matrix(&w, 8, RegionSpec::PerRow);
        let got = gemm_quantized(&aq, &wq, 1);
        let exact = super::super::gemm_f32::gemm_naive(&a, &w.transpose2());
        let rel = got.max_abs_diff(&exact) / exact.max_abs();
        assert!(rel < 0.01, "8-bit LQ relative error {rel}");
    }

    #[test]
    #[should_panic(expected = "region size")]
    fn mismatched_regions_panic() {
        let a = Tensor::zeros(&[2, 8]);
        let aq = quantize_matrix(&a, 8, RegionSpec::Size(4));
        let wq = quantize_matrix(&a, 8, RegionSpec::Size(2));
        gemm_quantized(&aq, &wq, 1);
    }
}
