//! Blocked f32 GEMM — the 32-bit floating-point baseline (MKL stand-in).
//!
//! C[M,N] = A[M,K] * B[K,N] with cache-blocked loops, a vectorizable
//! micro-kernel over contiguous rows of B, and row-parallelism across
//! threads. Not peak-BLAS, but a fair same-effort baseline for the
//! fixed-point comparison (both sides get the same blocking + threading).

use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;

/// Tile sizes tuned for ~32 KiB L1d: 8 rows of A x 256-wide K panel.
const MC: usize = 8;
const KC: usize = 256;

/// C = A (M,K) * B (K,N), multi-threaded over rows when `threads > 1`.
pub fn gemm_f32_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    let c_cell = CellSlice(c.as_mut_ptr());
    scope_chunks(m.div_ceil(MC), threads, |blk_start, blk_end| {
        let c = &c_cell;
        for blk in blk_start..blk_end {
            let i0 = blk * MC;
            let i1 = (i0 + MC).min(m);
            for p0 in (0..k).step_by(KC) {
                let p1 = (p0 + KC).min(k);
                for i in i0..i1 {
                    // SAFETY: each row i belongs to exactly one chunk.
                    let crow = unsafe {
                        std::slice::from_raw_parts_mut(c.0.add(i * n), n)
                    };
                    for p in p0..p1 {
                        let av = a[i * k + p];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[p * n..p * n + n];
                        // Vectorizable axpy over the contiguous B row.
                        for (cj, bj) in crow.iter_mut().zip(brow) {
                            *cj += av * bj;
                        }
                    }
                }
            }
        }
    });
}

struct CellSlice(*mut f32);
// SAFETY: disjoint row ranges are written by different threads (chunked by
// row block), so no two threads alias the same element.
unsafe impl Sync for CellSlice {}

/// Tensor wrapper: C = A * B.
pub fn gemm_f32(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2, "gemm {:?} x {:?}", a.shape(), b.shape());
    let mut c = vec![0.0f32; m * n];
    gemm_f32_into(a.data(), b.data(), &mut c, m, k, n, threads);
    Tensor::new(&[m, n], c)
}

/// Naive triple loop for testing.
pub fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let n = b.dim(1);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a.at2(i, p) * b.at2(p, j);
            }
            c[i * n + j] = acc;
        }
    }
    Tensor::new(&[m, n], c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matches_naive() {
        prop::check_named("gemm-f32-vs-naive", 0xF32, 32, |rng, _| {
            let m = rng.index(1, 20);
            let k = rng.index(1, 40);
            let n = rng.index(1, 20);
            let a = Tensor::new(&[m, k], rng.normal_vec(m * k));
            let b = Tensor::new(&[k, n], rng.normal_vec(k * n));
            for threads in [1, 4] {
                let c = gemm_f32(&a, &b, threads);
                let r = gemm_naive(&a, &b);
                let scale = r.max_abs().max(1.0);
                assert!(
                    c.max_abs_diff(&r) <= 1e-4 * scale,
                    "m={m} k={k} n={n} threads={threads}"
                );
            }
        });
    }

    #[test]
    fn identity() {
        let n = 16;
        let eye = Tensor::from_fn(&[n, n], |i| if i / n == i % n { 1.0 } else { 0.0 });
        let a = Tensor::from_fn(&[n, n], |i| i as f32 * 0.1);
        assert_eq!(gemm_f32(&a, &eye, 2), a);
    }

    #[test]
    fn large_k_blocking() {
        // K > KC exercises the panel loop.
        let m = 3;
        let k = 700;
        let n = 5;
        let a = Tensor::from_fn(&[m, k], |i| ((i % 13) as f32 - 6.0) * 0.1);
        let b = Tensor::from_fn(&[k, n], |i| ((i % 7) as f32 - 3.0) * 0.2);
        let c = gemm_f32(&a, &b, 3);
        let r = gemm_naive(&a, &b);
        assert!(c.max_abs_diff(&r) <= 1e-3);
    }
}
