//! S6 — fixed-point GEMM kernels: the Edison-side hot path.
//!
//! The paper's Fig. 8 speedup comes from replacing the f32 GEMM (offloaded to
//! MKL on the Edison board) with integer GEMMs over quantized operands. This
//! module provides the same ladder on the host CPU:
//!
//! - [`gemm_f32`]   — blocked, multi-threaded f32 baseline (the MKL stand-in).
//! - [`gemm_i8`]    — eq. 7: integer accumulation over 8-bit codes with
//!   per-region affine correction (the LQ hot path, any bits <= 8).
//! - [`gemm_packed`] — the same pipeline reading *bit-packed* 4/2-bit code
//!   streams (the paper's bandwidth claim: codes travel packed).
//! - [`gemm_lut`]   — §V look-up-table GEMM: multiplies replaced by
//!   table-indexed adds for <= 4-bit activations.
//! - [`im2col`]     — conv lowering; layout matches `python/compile/model.py`
//!   so one row = one receptive field = one LQ region.
pub mod gemm_f32;
pub mod gemm_i8;
pub mod gemm_lut;
pub mod gemm_packed;
pub mod im2col;

pub use gemm_f32::gemm_f32;
pub use gemm_i8::gemm_quantized;
pub use im2col::{conv_output_size, im2col};
