//! S6 — fixed-point GEMM kernels: the Edison-side hot path.
//!
//! The paper's Fig. 8 speedup comes from replacing the f32 GEMM (offloaded to
//! MKL on the Edison board) with integer GEMMs over quantized operands. This
//! module provides the same ladder on the host CPU, and every quantized rung
//! shares one packed weight-panel core ([`panel`]).
//!
//! # The kernel ladder, and when each rung wins
//!
//! | kernel | operands | inner loop | wins when |
//! |---|---|---|---|
//! | [`gemm_f32`] | f32 | blocked f32 axpy | baseline (the MKL stand-in); accuracy reference |
//! | [`gemm_quantized`] / [`panel::gemm_panel`] | u8 codes | dispatched `MR`x`NR` integer tile ([`simd`]): AVX2 `madd`, AVX-512 `vpdpbusd`, or the portable scalar MAC | the default quantized path, any bits <= 8; ~4x the f32 element throughput per SIMD load |
//! | [`gemm_lut`] / [`panel::gemm_lut_panel`] | <= 4-bit act codes | §V code bucketing (dispatched): add-only pass + `2^bits - 2` multiplies per region-tile | multiply-starved targets (the FPGA CUs, MCU cores); on SIMD CPUs it trades multiplies for a data-dependent bucket index, so it wins on op *count*, not wall clock |
//! | [`gemm_packed`] / [`panel::gemm_panel_packed`] | bit-packed streams | same integer tile after one unpack per stream | memory-bound shapes: codes travel packed (the §III.C bandwidth claim), unpack cost is O(M*K + N*K), amortized over O(M*N*K) MACs |
//! | [`bitserial::gemm_bitserial`] / [`bitserial::gemm_bitserial_packed`] | <= 4-bit codes *both sides* | bit-plane AND+popcount (dispatched): `bits_a * bits_w * K/64` word ops per output | the default for <= 4-bit weights+activations (`LQR_FORCE_U8PANEL=1` opts out): compute finally scales with bit width — 16x fewer word ops than MACs at 2 bits. Bit-exact vs the u8 panel path |
//!
//! # The shared panel core
//!
//! [`panel::WeightPanel`] widens / bit-unpacks weight codes **once** into
//! N-tiles of [`panel::NR`] output channels stored K-major, with the
//! per-region scales / mins / code-sums transposed alongside, K blocked on
//! quantization-region boundaries (the panel layout matches the LQ
//! granularity). All three quantized entry points run the same microkernel
//! over that layout; build the panel once per weight matrix and the prep
//! cost amortizes across every batch (`nn::forward::Engine` caches panels).
//! The outer loops run an M-block x N-tile schedule so weight tiles stay
//! L2-resident across a whole block of activation rows.
//!
//! # SIMD dispatch
//!
//! [`simd`] selects the microkernel implementation **once per process** via
//! runtime feature detection: on x86-64 an exact AVX2 widening-`madd` tile
//! or an AVX-512 VNNI `vpdpbusd` tile (cargo feature `avx512`), on aarch64
//! a NEON widening-`umlal` tile or a `udot` tile (cargo feature `dotprod`)
//! — the ISA of the IoT-class boards the paper targets — and everywhere the
//! portable scalar loop, which is also what `LQR_FORCE_SCALAR=1` pins, so
//! the fallback arm stays testable on SIMD hosts. All arms are bit-exact
//! against each other (pinned by `rust/tests/panel_kernels.rs`); the
//! contract each arm satisfies is documented in `docs/kernel-dispatch.md`.
//!
//! # Conv lowering
//!
//! - [`im2col`] — f32 patch matrix; layout matches `python/compile/model.py`
//!   so one row = one receptive field = one LQ region. Interior rows copy as
//!   whole row spans (pad-free fast path); padded edges copy clipped spans.
//! - [`im2col_quantized`] — the quantized-path lowering: per-region min/max
//!   and u8 code emission fused into the span copies, so runtime activation
//!   quantization costs no extra pass over a materialized patch matrix (the
//!   paper's §VI overhead concern). Patch rows chunk over the shared thread
//!   pool, so the lowering parallelizes like the GEMM it feeds — and stays
//!   bit-identical to the single-threaded path.
pub mod bitserial;
pub mod gemm_f32;
pub mod gemm_i8;
pub mod gemm_lut;
pub mod gemm_packed;
pub mod im2col;
pub mod panel;
pub mod simd;

pub use bitserial::{
    bitserial_eligible, gemm_bitserial, gemm_bitserial_packed, gemm_bitserial_packed_with,
    gemm_bitserial_with,
};
pub use gemm_f32::gemm_f32;
pub use gemm_i8::{gemm_quantized, gemm_quantized_naive};
pub use im2col::{col2im_output, conv_output_size, im2col, im2col_quantized};
pub use panel::{
    gemm_lut_panel, gemm_lut_panel_with, gemm_panel, gemm_panel_packed, gemm_panel_packed_with,
    gemm_panel_with, WeightPanel,
};
