//! `artifacts/manifest.json` — the contract between `python -m compile.aot`
//! and the rust runtime. Parsed with the in-tree JSON parser.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One lowered HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub model: String,
    /// "f32" or "lq".
    pub variant: String,
    /// Activation bits for lq variants (0 for f32).
    pub bits: usize,
    pub batch: usize,
}

/// Per-model metadata: weight file + parameter order/shapes.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub weights_file: String,
    pub param_order: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    /// (C, H, W).
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).context("manifest: artifacts[]")? {
            artifacts.push(ArtifactMeta {
                name: a.get("name").and_then(Json::as_str).context("artifact.name")?.into(),
                file: a.get("file").and_then(Json::as_str).context("artifact.file")?.into(),
                model: a.get("model").and_then(Json::as_str).context("artifact.model")?.into(),
                variant: a.get("variant").and_then(Json::as_str).context("variant")?.into(),
                bits: a.get("bits").and_then(Json::as_usize).unwrap_or(0),
                batch: a.get("batch").and_then(Json::as_usize).context("artifact.batch")?,
            });
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).context("manifest: models{}")? {
            let order: Vec<String> = m
                .get("param_order")
                .and_then(Json::as_arr)
                .context("param_order")?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect();
            let mut shapes = BTreeMap::new();
            if let Some(obj) = m.get("param_shapes").and_then(Json::as_obj) {
                for (k, v) in obj {
                    let dims = v
                        .as_arr()
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default();
                    shapes.insert(k.clone(), dims);
                }
            }
            let ishape = m.get("input_shape").and_then(Json::as_arr).context("input_shape")?;
            anyhow::ensure!(ishape.len() == 3, "input_shape must be CHW");
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    weights_file: m.get("weights").and_then(Json::as_str).context("weights")?.into(),
                    param_order: order,
                    param_shapes: shapes,
                    input_shape: (
                        ishape[0].as_usize().unwrap(),
                        ishape[1].as_usize().unwrap(),
                        ishape[2].as_usize().unwrap(),
                    ),
                    num_classes: m.get("num_classes").and_then(Json::as_usize).unwrap_or(0),
                },
            );
        }
        Ok(Manifest { dir, artifacts, models })
    }

    /// Artifacts for a given model + variant, sorted by batch size.
    pub fn variants(&self, model: &str, variant: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.variant == variant)
            .collect();
        v.sort_by_key(|a| a.batch);
        v
    }

    /// Find one artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn hlo_path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }

    pub fn weights_path(&self, m: &ModelMeta) -> PathBuf {
        self.dir.join(&m.weights_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("lqr_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                 {"name": "m_f32_b1", "file": "m_f32_b1.hlo.txt", "model": "m",
                  "variant": "f32", "bits": 0, "batch": 1},
                 {"name": "m_f32_b8", "file": "m_f32_b8.hlo.txt", "model": "m",
                  "variant": "f32", "bits": 0, "batch": 8}
               ],
               "models": {"m": {"weights": "w.npz", "param_order": ["a.w"],
                 "param_shapes": {"a.w": [2, 3]},
                 "input_shape": [3, 32, 32], "num_classes": 16}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.variants("m", "f32").len(), 2);
        assert_eq!(m.variants("m", "f32")[1].batch, 8);
        assert_eq!(m.models["m"].input_shape, (3, 32, 32));
        assert_eq!(m.models["m"].param_shapes["a.w"], vec![2, 3]);
        assert!(m.by_name("m_f32_b1").is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_is_error() {
        let e = Manifest::load("/nonexistent_dir_xyz").unwrap_err();
        assert!(format!("{e:#}").contains("make artifacts"));
    }
}
