//! PJRT session: one client + compiled executables + device-resident weights.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest, ModelMeta};
use crate::tensor::{read_npz, Tensor};

/// A PJRT CPU client plus everything compiled on it. **Not Send** — create
/// one per worker thread.
pub struct Session {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Weights per model, uploaded once as device buffers in param order.
    weights: HashMap<String, Vec<xla::PjRtBuffer>>,
}

/// One compiled model variant, ready to run.
pub struct ModelRunner {
    pub meta: ArtifactMeta,
    pub input_elems: usize,
    pub num_classes: usize,
    exe: xla::PjRtLoadedExecutable,
    /// Number of weight parameters preceding the input parameter.
    n_params: usize,
    model: String,
}

impl Session {
    /// Create a CPU session over an artifacts directory.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Session> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(anyhow::Error::from)?;
        Ok(Session { client, manifest, weights: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Upload a model's npz weights to device buffers (once, in param order).
    fn ensure_weights(&mut self, model: &ModelMeta) -> Result<()> {
        if self.weights.contains_key(&model.name) {
            return Ok(());
        }
        let path = self.manifest.weights_path(model);
        let entries = read_npz(&path)?;
        let by_name: HashMap<String, Tensor> = entries
            .into_iter()
            .map(|mut e| (std::mem::take(&mut e.name), e.into_tensor()))
            .collect();
        let mut bufs = Vec::with_capacity(model.param_order.len());
        for name in &model.param_order {
            let t = by_name
                .get(name)
                .with_context(|| format!("{}: weight {name} missing", path.display()))?;
            bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
                    .map_err(anyhow::Error::from)?,
            );
        }
        self.weights.insert(model.name.clone(), bufs);
        Ok(())
    }

    /// Replace one weight tensor for a model (e.g. a rust-side dequantized
    /// variant) — used by the quantization experiments over the PJRT path.
    pub fn override_weight(&mut self, model: &str, name: &str, t: &Tensor) -> Result<()> {
        let meta = self.manifest.models.get(model).context("unknown model")?.clone();
        self.ensure_weights(&meta)?;
        let idx = meta
            .param_order
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("unknown weight {name}"))?;
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
            .map_err(anyhow::Error::from)?;
        self.weights.get_mut(model).unwrap()[idx] = buf;
        Ok(())
    }

    /// Compile one artifact (HLO text -> executable) and bind its weights.
    pub fn load(&mut self, artifact_name: &str) -> Result<ModelRunner> {
        let meta = self
            .manifest
            .by_name(artifact_name)
            .with_context(|| format!("artifact {artifact_name} not in manifest"))?
            .clone();
        let model = self
            .manifest
            .models
            .get(&meta.model)
            .with_context(|| format!("model {} not in manifest", meta.model))?
            .clone();
        self.ensure_weights(&model)?;

        let t0 = Instant::now();
        let path = self.manifest.hlo_path(&meta);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(anyhow::Error::from)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(anyhow::Error::from)?;
        log::info!(
            "compiled {artifact_name} ({}) in {:.2}s",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
        let (c, h, w) = model.input_shape;
        Ok(ModelRunner {
            input_elems: meta.batch * c * h * w,
            num_classes: model.num_classes,
            n_params: model.param_order.len(),
            model: meta.model.clone(),
            meta,
            exe,
        })
    }

    /// Execute a runner on a `(batch, C, H, W)` input tensor; returns logits
    /// `(batch, num_classes)`.
    pub fn run(&self, runner: &ModelRunner, input: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(
            input.len() == runner.input_elems,
            "input has {} elems, artifact {} wants {}",
            input.len(),
            runner.meta.name,
            runner.input_elems
        );
        let weights = &self.weights[&runner.model];
        debug_assert_eq!(weights.len(), runner.n_params);
        let input_buf = self
            .client
            .buffer_from_host_buffer::<f32>(input.data(), input.shape(), None)
            .map_err(anyhow::Error::from)?;
        let mut args: Vec<&xla::PjRtBuffer> = weights.iter().collect();
        args.push(&input_buf);
        let result = runner.exe.execute_b(&args).map_err(anyhow::Error::from)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow::Error::from)?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(anyhow::Error::from)?;
        let data = out.to_vec::<f32>().map_err(anyhow::Error::from)?;
        anyhow::ensure!(
            data.len() == runner.meta.batch * runner.num_classes,
            "unexpected output size {}",
            data.len()
        );
        Ok(Tensor::new(&[runner.meta.batch, runner.num_classes], data))
    }
}

// Integration tests that need real artifacts live in rust/tests/runtime_e2e.rs
// (they require `make artifacts` to have run).
