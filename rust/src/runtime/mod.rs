//! S7 — PJRT runtime: load AOT artifacts and execute them on the hot path.
//!
//! The build-time python side (`make artifacts`) lowers each model variant to
//! HLO text; this module loads the text (`HloModuleProto::from_text_file`, the
//! only interchange that works with xla_extension 0.5.1 — see DESIGN.md),
//! compiles it on a PJRT CPU client and executes it with the npz weights as
//! runtime parameters.
//!
//! Thread model: `PjRtClient` (and everything derived from it) is
//! reference-counted and **not Send** — a [`Session`] must be created and
//! used on one thread. The coordinator gives each worker thread its own
//! session (see `coordinator::worker`).
pub mod manifest;
pub mod session;

pub use manifest::{ArtifactMeta, Manifest, ModelMeta};
pub use session::{ModelRunner, Session};
