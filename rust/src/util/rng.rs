//! Deterministic PRNG: SplitMix64 core with convenience samplers.
//!
//! Used by the dataset generator, the property-test harness, workload
//! generators and the benches. Deterministic across platforms so every
//! experiment is reproducible from its seed.

/// SplitMix64 (Steele, Lea, Flood 2014). Passes BigCrush when used as a
/// 64-bit generator; plenty for test-data generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal from the last Box-Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, spare_normal: None }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn index(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of standard-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Vector of uniform f32 in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }
}
