//! Hand-rolled infrastructure substrates.
//!
//! The build environment is fully offline with a small vendored crate set
//! (no clap / serde / criterion / proptest / rayon / tokio), so the support
//! machinery a framework normally pulls from crates.io is implemented here:
//!
//! - [`rng`] — deterministic SplitMix64 PRNG (uniforms, normals, shuffles).
//! - [`json`] — minimal JSON parser/serializer (manifest + config files).
//! - [`cli`] — declarative flag parser for the `lqr` binary and examples.
//! - [`stats`] — timers, latency histograms, summary statistics.
//! - [`threadpool`] — fixed-size worker pool (coordinator workers).
//! - [`prop`] — tiny property-testing harness (deterministic, seed-logged).
//! - [`logging`] — env-filtered logger for the `log` facade.
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
