//! Minimal logger for the `log` facade, filtered by the LQR_LOG env var
//! (error|warn|info|debug|trace; default info). Timestamps are relative to
//! process start — enough for coordinator traces without pulling in time
//! formatting dependencies.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct Logger {
    start: Instant,
}

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("LQR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now() });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
