//! Timing + summary statistics for benches and the coordinator's metrics.

use std::time::{Duration, Instant};

/// Measure wall time of `f`, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Run `f` repeatedly: `warmup` discarded iterations then `iters` measured,
/// returning per-iteration durations.
pub fn bench_iters<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<Duration> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed()
        })
        .collect()
}

/// Nearest-rank percentile over an unsorted sample (`p` in [0, 1]).
/// The single percentile definition shared by [`Summary`], the serving
/// examples and the saturation bench, so their tail numbers agree.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    assert!(!values.is_empty(), "percentile of empty sample");
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted[(((sorted.len() - 1) as f64) * p).round() as usize]
}

/// Summary statistics over a sample of durations or values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty(), "Summary::of(empty)");
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| sorted[(((n - 1) as f64) * p).round() as usize];
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            p999: pct(0.999),
            max: sorted[n - 1],
        }
    }

    pub fn of_durations(ds: &[Duration]) -> Summary {
        let vals: Vec<f64> = ds.iter().map(|d| d.as_secs_f64()).collect();
        Summary::of(&vals)
    }

    /// Render with a unit scale, e.g. `fmt(1e3, "ms")`.
    pub fn fmt(&self, scale: f64, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} p99={:.3}{u} p999={:.3}{u} \
             min={:.3}{u} max={:.3}{u}",
            self.n,
            self.mean * scale,
            self.p50 * scale,
            self.p95 * scale,
            self.p99 * scale,
            self.p999 * scale,
            self.min * scale,
            self.max * scale,
            u = unit
        )
    }
}

/// Fixed-bucket log-scale latency histogram (lock-free-ish: callers own it or
/// wrap in a mutex; the coordinator keeps one per stream).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket i counts samples in [2^i, 2^{i+1}) microseconds; 64 buckets.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; 64], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(63);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1).min(63));
            }
        }
        self.max()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p999, 5.0);
    }

    #[test]
    fn percentile_matches_summary_definition() {
        let vals: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = Summary::of(&vals);
        assert_eq!(percentile(&vals, 0.50), s.p50);
        assert_eq!(percentile(&vals, 0.99), s.p99);
        assert_eq!(percentile(&vals, 0.999), s.p999);
        // Tail order on a big sample: p50 < p99 < p999 <= max.
        assert!(s.p50 < s.p99 && s.p99 < s.p999 && s.p999 <= s.max);
    }

    #[test]
    fn histogram_records() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 4);
        assert!(h.mean() >= Duration::from_micros(2000));
        assert!(h.quantile(0.5) >= Duration::from_micros(100));
        assert!(h.quantile(1.0) >= Duration::from_micros(10_000));
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), Duration::from_micros(500));
    }

    #[test]
    fn bench_iters_runs() {
        let mut calls = 0;
        let ds = bench_iters(2, 5, || calls += 1);
        assert_eq!(ds.len(), 5);
        assert_eq!(calls, 7);
    }
}
